"""Quickstart: GraphH PageRank on a synthetic power-law graph, end to end.

    PYTHONPATH=src python examples/quickstart.py

Pipeline: R-MAT generator -> SPE two-stage partitioning -> tile store
("DFS") -> out-of-core GAB engine with edge cache + hybrid communication.
"""
import tempfile
import time

import numpy as np

from repro.core.apps import PageRank
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe, synth
from repro.graphio.formats import TileStore


def main():
    nv, ne = 50_000, 500_000
    print(f"1. generating R-MAT graph: |V|={nv:,} |E|={ne:,}")
    store = TileStore(tempfile.mkdtemp(prefix="quickstart_"))

    print("2. SPE two-stage partitioning (degree pass -> splitter -> CSR tiles)")
    t0 = time.time()
    plan = spe.preprocess(lambda: synth.rmat_edges(nv, ne, seed=1),
                          nv, store, tile_size=32768)
    print(f"   {plan.num_tiles} tiles of <= {plan.edge_cap} edges "
          f"in {time.time()-t0:.1f}s")

    print("3. GAB supersteps on 4 emulated servers (AA replication, "
          "edge cache, hybrid broadcast)")
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=4, cache_capacity_bytes=1 << 28, cache_mode="auto",
        comm_mode="hybrid", max_supersteps=100))
    t0 = time.time()
    res = eng.run(PageRank(update_tol=1e-9))
    print(f"   converged={res.converged} in {res.supersteps} supersteps, "
          f"{time.time()-t0:.1f}s "
          f"({res.mean_superstep_seconds()*1000:.0f} ms/superstep)")

    top = np.argsort(-res.values)[:5]
    print("4. top-5 vertices by rank:", [(int(v), round(float(res.values[v]), 2))
                                         for v in top])
    h = res.history[2]
    print(f"   cache hit ratio {h.cache_hit_ratio:.2f} | broadcast mode "
          f"density {h.density:.2f} | wire {h.wire_bytes/1e6:.2f} MB/superstep")

    print("5. serial vs pipelined engine under memory pressure "
          "(cache << working set; DESIGN.md §7)")
    plan2 = store.load_plan()
    disk = sum(store.tile_disk_bytes(t) for t in range(plan2.num_tiles))
    pressed = dict(num_servers=4, cache_capacity_bytes=int(disk * 0.15) // 4,
                   cache_mode=3, tile_skipping=False, max_supersteps=10)
    runs = {}
    for pipe in (False, True):
        eng_c = OutOfCoreEngine(store, EngineConfig(
            pipeline=pipe, prefetch_depth=4, stack_size=4, **pressed))
        runs[pipe] = eng_c.run(PageRank(update_tol=1e-9))
    ser, pip = runs[False], runs[True]
    same = np.array_equal(ser.values, pip.values)
    print(f"   serial    {ser.mean_superstep_seconds()*1000:5.0f} ms/superstep, "
          f"disk-stall {ser.disk_stall_fraction()*100:.0f}%")
    print(f"   pipelined {pip.mean_superstep_seconds()*1000:5.0f} ms/superstep, "
          f"disk-stall {pip.disk_stall_fraction()*100:.0f}%, "
          f"bit-identical to serial: {same}")


if __name__ == "__main__":
    main()
