"""Quickstart: GraphH PageRank on a synthetic power-law graph, end to end.

    PYTHONPATH=src python examples/quickstart.py

Pipeline: R-MAT generator -> SPE two-stage partitioning -> tile store
("DFS") -> out-of-core GAB engine with edge cache + hybrid communication.
"""
import tempfile
import time

import numpy as np

from repro.core.apps import PageRank
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe, synth
from repro.graphio.formats import TileStore


def main():
    nv, ne = 50_000, 500_000
    print(f"1. generating R-MAT graph: |V|={nv:,} |E|={ne:,}")
    store = TileStore(tempfile.mkdtemp(prefix="quickstart_"))

    print("2. SPE two-stage partitioning (degree pass -> splitter -> CSR tiles)")
    t0 = time.time()
    plan = spe.preprocess(lambda: synth.rmat_edges(nv, ne, seed=1),
                          nv, store, tile_size=32768)
    print(f"   {plan.num_tiles} tiles of <= {plan.edge_cap} edges "
          f"in {time.time()-t0:.1f}s")

    print("3. GAB supersteps on 4 emulated servers (AA replication, "
          "edge cache, hybrid broadcast)")
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=4, cache_capacity_bytes=1 << 28, cache_mode="auto",
        comm_mode="hybrid", max_supersteps=100))
    t0 = time.time()
    res = eng.run(PageRank(update_tol=1e-9))
    print(f"   converged={res.converged} in {res.supersteps} supersteps, "
          f"{time.time()-t0:.1f}s "
          f"({res.mean_superstep_seconds()*1000:.0f} ms/superstep)")

    top = np.argsort(-res.values)[:5]
    print("4. top-5 vertices by rank:", [(int(v), round(float(res.values[v]), 2))
                                         for v in top])
    h = res.history[2]
    print(f"   cache hit ratio {h.cache_hit_ratio:.2f} | broadcast mode "
          f"density {h.density:.2f} | wire {h.wire_bytes/1e6:.2f} MB/superstep")


if __name__ == "__main__":
    main()
