"""End-to-end LM training driver example: a ~100M-param qwen3-family model
for a few hundred steps on the synthetic pipeline, with checkpointing and
preemption-safe resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The same driver runs any of the 10 assigned architectures via --arch;
at full config on a real pod you'd add --mesh single/multi.)
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_100m")
    args = ap.parse_args()

    # ~100M params: 12 layers x d_model 512 + 32k vocab (tied embeddings)
    train.main([
        "--arch", "qwen3-1.7b", "--reduced",
        "--d-model", "512", "--layers", "12", "--vocab", "32768",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256", "--microbatch", "2",
        "--lr", "6e-4",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
