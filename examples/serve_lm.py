"""Batched serving example: continuous batching over 24 requests with
4 cache slots, loading weights from examples/train_lm.py when present.

    PYTHONPATH=src python examples/serve_lm.py [--ckpt-dir /tmp/train_lm_100m]
"""
import argparse
import os

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_100m")
    args = ap.parse_args()

    argv = [
        "--arch", "qwen3-1.7b", "--reduced",
        # same shape overrides as examples/train_lm.py (the ~100M model)
        "--d-model", "512", "--layers", "12", "--vocab", "32768",
        "--requests", "24", "--slots", "4",
        "--max-new", "24", "--max-len", "256", "--prompt-len", "16",
        "--temperature", "0.8",
    ]
    if os.path.isdir(args.ckpt_dir) and os.listdir(args.ckpt_dir):
        argv += ["--ckpt-dir", args.ckpt_dir]
    else:
        print("(no checkpoint found — serving randomly initialized weights; "
              "run examples/train_lm.py first)")
    serve.main(argv)


if __name__ == "__main__":
    main()
