"""Out-of-core graph analytics under memory pressure — the paper's core
scenario: edges >> cache, compressed edge cache, bloom-filter tile
skipping, and a comparison against the four baseline engine mechanisms.

    PYTHONPATH=src python examples/graph_analytics.py
"""
import tempfile
import time

import numpy as np

from repro.core.apps import SSSP, WCC
from repro.core.baselines import ENGINES
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe, synth
from repro.graphio.formats import TileStore


def main():
    nv, ne = 80_000, 800_000
    print(f"R-MAT |V|={nv:,} |E|={ne:,} (weighted)")
    store = TileStore(tempfile.mkdtemp(prefix="analytics_"))
    spe.preprocess(lambda: synth.rmat_edges(nv, ne, seed=2, weighted=True),
                   nv, store, tile_size=32768, weighted=True)
    plan = store.load_plan()
    tile_bytes = sum(store.tile_disk_bytes(t) for t in range(plan.num_tiles))
    print(f"{plan.num_tiles} tiles, {tile_bytes/1e6:.0f} MB on disk")

    # constrained cache: only ~30% of tiles fit raw -> auto mode compresses
    cap = int(tile_bytes * 0.3)
    print(f"\n--- SSSP with {cap/1e6:.0f} MB cache/server "
          f"(auto-selected compression mode) ---")
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=2, cache_capacity_bytes=cap // 2, cache_mode="auto",
        comm_mode="hybrid", tile_skipping=True, max_supersteps=100))
    print(f"cache mode selected: {eng.cache_mode} "
          f"(1=raw 2=zstd-1 3=zstd-3 4=zstd-9)")
    t0 = time.time()
    res = eng.run(SSSP(source=0))
    reached = int(np.isfinite(res.values).sum())
    skipped = sum(h.tiles_skipped for h in res.history)
    print(f"SSSP: {res.supersteps} supersteps {time.time()-t0:.1f}s, "
          f"{reached:,} reachable, {skipped} tile loads skipped, "
          f"hit ratio {res.history[-1].cache_hit_ratio:.2f}")

    print("\n--- WCC on the symmetrized graph ---")
    store2 = TileStore(tempfile.mkdtemp(prefix="analytics_sym_"))
    spe.preprocess(
        lambda: synth.symmetrized(synth.rmat_edges(nv, ne, seed=2)),
        nv, store2, tile_size=65536)
    eng2 = OutOfCoreEngine(store2, EngineConfig(num_servers=2,
                                                max_supersteps=100))
    res2 = eng2.run(WCC())
    n_comp = len(np.unique(res2.values))
    print(f"WCC: {res2.supersteps} supersteps, {n_comp:,} components")

    print("\n--- baseline engine comparison (SSSP, same graph) ---")
    srcs, dsts, vals = [], [], []
    for s, d, v in synth.rmat_edges(nv, ne, seed=2, weighted=True):
        srcs.append(s), dsts.append(d), vals.append(v)
    src, dst, val = (np.concatenate(x) for x in (srcs, dsts, vals))
    rows = [("graphh", res.mean_superstep_seconds(),
             sum(h.network_bytes for h in res.history),
             sum(h.disk_bytes_read for h in res.history))]
    for name, cls in ENGINES.items():
        e = cls(src, dst, val, nv, num_servers=2)
        r = e.run(SSSP(source=0), max_supersteps=40)
        rows.append((name, r.mean_superstep_seconds(),
                     sum(h.network_bytes for h in r.history),
                     sum(h.disk_read_bytes + h.disk_write_bytes
                         for h in r.history)))
    print(f"{'engine':12s} {'ms/superstep':>14s} {'net MB':>8s} {'disk MB':>8s}")
    for name, sec, net, disk in rows:
        print(f"{name:12s} {sec*1000:14.1f} {net/1e6:8.1f} {disk/1e6:8.1f}")


if __name__ == "__main__":
    main()
