"""Multi-tenant fair admission + result cache (serve/graph_service.py,
DESIGN.md §16).

  * deficit-round-robin windows: admitted shares track configured
    weights within ±1 of weight-proportional, a hot tenant cannot
    starve others under 10:1 offered-load skew, fractional weights
    still admit within bounded rounds, idle tenants forfeit credit;
  * :class:`ResultCache`: hits are bit-identical defensive copies,
    LRU eviction, counters, and — keyed by graph fingerprint — one
    shared cache never serves a result across differing graphs;
  * the submit-vs-drain race drill: threads storm ``submit`` while the
    service drains — every call yields a resolved ticket or a clean
    ``RuntimeError`` refusal, and at drain
    ``submitted == done + timeout + failed + refused`` with no ticket
    leaked in a pending queue.
"""
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core.engine import EngineConfig
from repro.graphio import spe
from repro.graphio.formats import TileStore
from repro.serve.graph_service import (GraphService, ResultCache,
                                       parse_tenants)

SS = 200
NV = 220


def _make_store(nv=NV, ne=1400, tile_size=96, seed=7):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    root = tempfile.mkdtemp(prefix="fair_admission_store_")
    spe.preprocess_arrays(src[i], dst[i], None, nv, TileStore(root),
                          tile_size)
    store = TileStore(root)
    store.load_meta()
    return store


@pytest.fixture(scope="module")
def store():
    return _make_store()


def _cfg():
    return EngineConfig(num_servers=2, max_supersteps=SS)


def _svc(store, **kw):
    return GraphService(store, _cfg(), max_supersteps=SS, **kw)


# -- deficit round-robin windows ---------------------------------------------

def test_drr_shares_track_weights_within_one(store):
    """Every admission window of a sustained 3:1-weighted backlog splits
    within ±1 of weight-proportional (8 slots -> 6:2)."""
    svc = _svc(store, tenants={"a": 3.0, "b": 1.0})
    for i in range(48):
        svc.submit("ppr", i % NV, tenant="a")
    for i in range(16):
        svc.submit("ppr", i, tenant="b")
    with svc._lock:
        for _ in range(8):        # 8 windows x (6a + 2b) drains both
            batch = svc._drr_take("ppr", 8)
            n_a = sum(t.tenant == "a" for t in batch)
            assert len(batch) == 8
            assert abs(n_a - 6) <= 1, n_a
        assert svc._pending_count("ppr") == 0


def test_hot_tenant_cannot_starve_under_10x_skew(store):
    """Equal weights, 10x offered-load skew: the small tenant still gets
    half of every window while it is backlogged."""
    svc = _svc(store)             # no tenant map: everyone weight 1
    for i in range(100):
        svc.submit("msbfs", i % NV, tenant="hog")
    for i in range(10):
        svc.submit("msbfs", i, tenant="mouse")
    with svc._lock:
        for _ in range(5):
            batch = svc._drr_take("msbfs", 4)
            assert sum(t.tenant == "mouse" for t in batch) == 2


def test_fractional_weight_admits_within_bounded_rounds(store):
    """A weight-0.25 tenant accumulates credit across rounds and lands
    its weight-proportional share (5 slots at 1.0:0.25 -> 4:1)."""
    svc = _svc(store, tenants={"fast": 1.0, "slow": 0.25})
    for i in range(20):
        svc.submit("ppr", i, tenant="fast")
        svc.submit("ppr", 100 + i, tenant="slow")
    with svc._lock:
        batch = svc._drr_take("ppr", 5)
    assert sum(t.tenant == "fast" for t in batch) == 4
    assert sum(t.tenant == "slow" for t in batch) == 1


def test_idle_tenant_forfeits_banked_credit(store):
    """Credit banked while a tenant goes idle is dropped as soon as a
    later window runs without it (work-conserving fairness)."""
    svc = _svc(store, tenants={"a": 4.0, "b": 1.0})
    for i in range(3):
        svc.submit("ppr", i, tenant="a")
    for i in range(10):
        svc.submit("ppr", 10 + i, tenant="b")
    with svc._lock:
        svc._drr_take("ppr", 4)   # a admits all 3, banks 1.0 credit
        svc._drr_take("ppr", 2)   # a idle: its banked credit is cleared
        assert "a" not in svc._deficit["ppr"]


def test_parse_tenants_spec():
    assert parse_tenants("alice:3,bob:1") == {"alice": 3.0, "bob": 1.0}
    assert parse_tenants("solo") == {"solo": 1.0}
    assert parse_tenants(" a : 2 , b ") == {"a": 2.0, "b": 1.0}
    for bad in ("a:0", "a:-2", "", ":3", "a:x"):
        with pytest.raises(ValueError):
            parse_tenants(bad)


def test_service_rejects_nonpositive_weights_and_bad_seeds(store):
    with pytest.raises(ValueError):
        _svc(store, tenants={"a": 0.0})
    svc = _svc(store)
    with pytest.raises(ValueError):
        svc.submit("ppr", -1)
    with pytest.raises(ValueError):
        svc.submit("ppr", NV)
    with pytest.raises(ValueError):
        svc.submit("pagerank", 0)


# -- result cache -------------------------------------------------------------

def test_result_cache_bit_identity_and_defensive_copies():
    c = ResultCache(capacity=4)
    vals = np.array([np.pi, np.inf, -0.0, np.nan])
    frozen = vals.tobytes()
    c.put("ppr", 1, "fp", vals, 7)
    vals[0] = 99.0                       # caller mutates after put
    got, supersteps = c.get("ppr", 1, "fp")
    assert supersteps == 7
    assert got.tobytes() == frozen
    got[1] = 0.0                         # caller mutates the hit
    again, _ = c.get("ppr", 1, "fp")
    assert again.tobytes() == frozen


def test_result_cache_lru_eviction_and_counters():
    c = ResultCache(capacity=2)
    a = np.arange(3.0)
    c.put("ppr", 1, "fp", a, 1)
    c.put("ppr", 2, "fp", a, 2)
    assert c.get("ppr", 1, "fp") is not None   # touch: 2 becomes LRU
    c.put("ppr", 3, "fp", a, 3)                # evicts 2
    assert c.get("ppr", 2, "fp") is None
    assert c.get("ppr", 1, "fp") is not None
    assert c.get("ppr", 3, "fp") is not None
    assert c.snapshot() == dict(hits=3, misses=1, entries=2, capacity=2)


def test_result_cache_never_crosses_keys():
    c = ResultCache()
    c.put("ppr", 1, "fp-a", np.arange(3.0), 5)
    assert c.get("ppr", 1, "fp-b") is None     # other graph
    assert c.get("msbfs", 1, "fp-a") is None   # other app
    assert c.get("ppr", 2, "fp-a") is None     # other seed
    assert c.get("ppr", 1, "fp-a") is not None


def test_shared_cache_isolated_across_stores(store):
    """One ResultCache fronting two services over DIFFERENT graphs:
    each service hits only its own fingerprint's entries."""
    other = _make_store(seed=99)
    assert store.fingerprint() != other.fingerprint()
    cache = ResultCache(capacity=32)
    results = {}
    for name, s in (("one", store), ("two", other)):
        svc = GraphService(s, _cfg(), q_slots=2, max_wait_s=0.01,
                           max_supersteps=SS, result_cache=cache)
        svc.start()
        t = svc.submit("msbfs", 11)
        assert t.wait(120) and t.status == "done" and not t.cache_hit
        hit = svc.submit("msbfs", 11)
        assert hit.wait(120) and hit.cache_hit
        assert np.array_equal(hit.result, t.result)
        results[name] = t.result
        svc.request_drain()
        svc.join(120)
    # different graphs produced different columns, and neither service
    # ever saw the other's (a cross-fingerprint hit would have made the
    # second service's cold result equal the first's)
    assert not np.array_equal(results["one"], results["two"])


def test_cache_hit_consumes_no_slot(store):
    svc = _svc(store, q_slots=2, max_wait_s=0.01, result_cache=8)
    svc.start()
    try:
        t = svc.submit("ppr", 5)
        assert t.wait(120) and t.status == "done"
        opened = svc.stats_snapshot()["stats"]["sessions_opened"]
        hit = svc.submit("ppr", 5)
        assert hit.cache_hit and hit.status == "done" and hit.wait(0)
        assert hit.supersteps == t.supersteps
        assert np.array_equal(hit.result, t.result)
        snap = svc.stats_snapshot()["stats"]
        assert snap["sessions_opened"] == opened    # no admission happened
        assert snap["cache_hits"] == 1
        assert snap["cache_misses"] == 1
    finally:
        svc.request_drain()
        svc.join(120)


# -- weighted fairness end-to-end ---------------------------------------------

def test_first_admission_window_respects_weights_end_to_end(store):
    """Queue 3:1-weighted tenants before the serve loop starts: the
    session's opening batch is the DRR split, and everyone completes."""
    svc = _svc(store, q_slots=4, max_wait_s=0.01,
               tenants={"gold": 3.0, "free": 1.0})
    golds = [svc.submit("msbfs", i, tenant="gold") for i in range(8)]
    frees = [svc.submit("msbfs", 50 + i, tenant="free") for i in range(8)]
    svc.start()
    try:
        for t in golds + frees:
            assert t.wait(120) and t.status == "done", t
        ts = svc.stats_snapshot()["tenants"]
        assert ts["gold"] == dict(submitted=8, admitted=8, done=8,
                                  refused=0)
        assert ts["free"] == dict(submitted=8, admitted=8, done=8,
                                  refused=0)
        # the 4 tickets sharing the earliest admission timestamp are the
        # opening batch — DRR split 3 gold : 1 free
        first = sorted(golds + frees, key=lambda t: t.admitted_s)[:4]
        assert sum(t.tenant == "gold" for t in first) == 3
    finally:
        svc.request_drain()
        svc.join(120)


# -- submit-vs-drain race drill -----------------------------------------------

def test_submit_vs_drain_race_drill(store):
    """Threads storm submit() while the service drains: every call ends
    in a resolved ticket or a clean RuntimeError, and the drain
    invariant submitted == done+timeout+failed+refused holds with no
    ticket leaked in a pending queue."""
    svc = _svc(store, q_slots=4, max_wait_s=0.005)
    svc.start()
    tickets, refusals, unexpected = [], [], []
    tlock = threading.Lock()
    stop = threading.Event()

    def storm(tid):
        rng = np.random.default_rng(tid)
        while not stop.is_set():
            try:
                t = svc.submit("msbfs", int(rng.integers(NV)),
                               tenant=f"t{tid % 3}")
                with tlock:
                    tickets.append(t)
            except RuntimeError:          # clean refusal: drain latched
                with tlock:
                    refusals.append(tid)
                return
            except Exception as e:        # pragma: no cover - must not happen
                with tlock:
                    unexpected.append(e)
                return
            time.sleep(0.003)

    threads = [threading.Thread(target=storm, args=(i,)) for i in range(5)]
    for th in threads:
        th.start()
    time.sleep(0.4)
    svc.request_drain()
    time.sleep(0.3)                       # give every storm a post-drain try
    stop.set()
    for th in threads:
        th.join(60)
    svc.join(180)
    assert not unexpected, unexpected
    assert refusals, "no thread observed the drain refusal"
    for t in tickets:
        assert t.wait(60), t
        assert t.status in ("done", "timeout", "failed"), t
    s = svc.stats_snapshot()
    stats = s["stats"]
    assert stats["submitted"] == (stats["done"] + stats["timeout"]
                                  + stats["failed"] + stats["refused"])
    assert stats["submitted"] == len(tickets) + len(refusals)
    assert stats["refused"] == len(refusals)
    assert all(n == 0 for n in s["pending"].values())
