"""Deterministic stand-ins for the tiny slice of the hypothesis API this
suite uses (``given``/``settings`` + integers/floats/lists/binary/
sampled_from strategies).

Imported only when ``hypothesis`` is not installed, so property tests
degrade to a fixed-seed random sweep instead of being skipped wholesale.
Install the real thing (``pip install -e .[test]``) for shrinking and a
proper example database.
"""
from __future__ import annotations


import random
import sys


class _Strategy:
    def __init__(self, draw):
        self.draw = draw  # draw(rng) -> value

    def boundary(self):
        return []  # overridden per strategy where bounds exist


def integers(lo: int, hi: int) -> _Strategy:
    s = _Strategy(lambda rng: rng.randint(lo, hi))
    s.boundary = lambda: [lo, hi]
    return s


def floats(lo: float, hi: float) -> _Strategy:
    s = _Strategy(lambda rng: rng.uniform(lo, hi))
    s.boundary = lambda: [lo, hi]
    return s


def binary(min_size: int = 0, max_size: int = 100) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return bytes(rng.randrange(256) for _ in range(n))

    s = _Strategy(draw)
    s.boundary = lambda: [b"\x00" * min_size]
    return s


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elem.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def settings(**kw):
    def deco(fn):
        fn._max_examples = kw.get("max_examples", 20)
        return fn

    return deco


def given(*strats):
    def deco(fn):
        # NB: no functools.wraps — pytest must see the zero-arg wrapper
        # signature, not the strategy parameters (they'd look like fixtures).
        def run(*args, **kwargs):
            rng = random.Random(0)
            n = getattr(fn, "_max_examples", 20)
            # one pass over per-strategy boundary values, then random draws
            bounds = [s.boundary() for s in strats]
            for i in range(max(len(b) for b in bounds) if bounds else 0):
                if all(len(b) > i for b in bounds):
                    fn(*args, *[b[i] for b in bounds], **kwargs)
            for _ in range(n):
                fn(*args, *[s.draw(rng) for s in strats], **kwargs)

        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run

    return deco


# ``from _hypothesis_compat import strategies as st`` mirrors the real layout
strategies = sys.modules[__name__]
