"""repro-lint fixture tests (tools/analyze.py + tools/analyzers/).

Each checker gets three fixture snippets: one seeding a violation the
checker must catch (true positive), one following the invariant (clean),
and one carrying a justified ``# lint: allow(...)`` suppression.  On top
of that: suppression hygiene (GH001/GH002), the self-run test asserting
the real tree is clean, and a CLI smoke test of the exit-code contract.

Fixtures are written under ``tmp_path`` and linted with
``all_files=True`` (the per-checker ``TARGET_SUFFIXES`` filters would
otherwise skip files outside ``src/repro``).
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from analyze import run               # noqa: E402
from analyzers import CHECKERS        # noqa: E402
from analyzers.shapes import parse_shape_tokens  # noqa: E402


def _lint(tmp_path, code, checks, name="fixture.py"):
    """Write one fixture module and run the named checkers over it."""
    p = tmp_path / name
    p.write_text(textwrap.dedent(code))
    return run([str(p)], checks, all_files=True)


def _codes(findings):
    return [f.code for f in findings]


# ------------------------------ locks (GH1xx) ------------------------------

LOCKED_CLASS = '''
    """m."""
    import threading

    class C:
        """c."""
        _guarded_by = {"_x": "_lock"}

        def __init__(self):
            self._lock = threading.Lock()
            self._x = 0

        def bump(self):
            """b."""
            @BODY@
'''


def test_locks_flags_unguarded_access(tmp_path):
    findings, _ = _lint(tmp_path, LOCKED_CLASS.replace(
        "@BODY@", "self._x += 1"), ["locks"])
    assert _codes(findings) == ["GH101"]
    assert "C.bump" in findings[0].message


def test_locks_clean_when_held(tmp_path):
    findings, _ = _lint(tmp_path, LOCKED_CLASS.replace(
        "@BODY@", "with self._lock:\n                self._x += 1"), ["locks"])
    assert findings == []


def test_locks_suppressed_with_justification(tmp_path):
    findings, suppressed = _lint(tmp_path, LOCKED_CLASS.replace(
        "@BODY@", "self._x += 1  "
        "# lint: allow(GH101): fixture is single-threaded"), ["locks"])
    assert findings == []
    assert suppressed == 1


def test_locks_private_helper_inherits_callers_lock(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import threading

        class C:
            """c."""
            _guarded_by = {"_x": "_lock"}

            def __init__(self):
                self._lock = threading.Lock()
                self._x = 0

            def bump(self):
                """b."""
                with self._lock:
                    self._incr()

            def _incr(self):
                self._x += 1
    ''', ["locks"])
    assert findings == []


def test_locks_nested_def_is_an_unlocked_entry(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import threading

        class C:
            """c."""
            _guarded_by = {"_x": "_lock"}

            def __init__(self):
                self._lock = threading.Lock()
                self._x = 0

            def start(self):
                """worker body runs later, on another thread, unlocked."""
                def worker():
                    self._x += 1
                return worker
    ''', ["locks"])
    assert _codes(findings) == ["GH101"]


def test_locks_unused_declaration_and_malformed(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        class C:
            """c."""
            _guarded_by = {"_ghost": "_lock"}
    ''', ["locks"])
    assert _codes(findings) == ["GH102"]

    findings, _ = _lint(tmp_path, '''
        """m."""
        class C:
            """c."""
            _guarded_by = ["_x"]
    ''', ["locks"], name="malformed.py")
    assert _codes(findings) == ["GH103"]


def test_locks_tuple_alias_accepts_either_lock(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import threading

        class C:
            """c."""
            _guarded_by = {"_x": ("_lock", "_cond")}

            def __init__(self):
                self._lock = threading.Lock()
                self._cond = threading.Condition(self._lock)
                self._x = 0

            def bump(self):
                """b."""
                with self._cond:
                    self._x += 1
    ''', ["locks"])
    assert findings == []


# --------------------------- determinism (GH2xx) ---------------------------

def test_determinism_set_iteration(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        def f():
            """f."""
            items = {3, 1, 2}
            return [x for x in items]
    ''', ["determinism"])
    assert _codes(findings) == ["GH201"]


def test_determinism_sorted_clears_set_iteration(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        def f():
            """f."""
            items = {3, 1, 2}
            return [x for x in sorted(items)]
    ''', ["determinism"])
    assert findings == []


def test_determinism_unsorted_listdir(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import os

        def f(d):
            """f."""
            return [n for n in os.listdir(d)]
    ''', ["determinism"])
    assert _codes(findings) == ["GH202"]


def test_determinism_wallclock_and_rng(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import random
        import time

        def f():
            """f."""
            return time.time() + random.random()
    ''', ["determinism"])
    assert sorted(_codes(findings)) == ["GH203", "GH203"]


def test_determinism_sum_over_dict_values(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        def f(d):
            """f."""
            return sum(d.values())
    ''', ["determinism"])
    assert _codes(findings) == ["GH204"]


def test_determinism_dict_view_iteration_and_suppression(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        def f(d):
            """f."""
            out = []
            for k, v in d.items():
                out.append((k, v))
            return out
    ''', ["determinism"])
    assert _codes(findings) == ["GH205"]

    findings, suppressed = _lint(tmp_path, '''
        """m."""
        def f(d):
            """f."""
            out = []
            # lint: allow(GH205): d is built in rank order by the caller
            for k, v in d.items():
                out.append((k, v))
            return out
    ''', ["determinism"], name="suppressed.py")
    assert findings == []
    assert suppressed == 1


# ---------------------------- atomicity (GH3xx) ----------------------------

def test_atomicity_bare_durable_write(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        def save(path, data):
            """s."""
            with open(path, "w") as f:
                f.write(data)
    ''', ["atomicity"])
    assert _codes(findings) == ["GH301"]


def test_atomicity_staged_protocol_clean(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import os

        def save(path, data):
            """s."""
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
    ''', ["atomicity"])
    assert findings == []


def test_atomicity_replace_without_fsync(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import os

        def save(path, data):
            """s."""
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(data)
            os.replace(tmp, path)
    ''', ["atomicity"])
    assert _codes(findings) == ["GH302"]


def test_atomicity_np_saver_through_staged_handle_clean(tmp_path):
    # np.savez("x.npz.tmp") would write x.npz.tmp.npz — staging must go
    # through a file object, and the checker must not flag that idiom
    findings, _ = _lint(tmp_path, '''
        """m."""
        import os

        import numpy as np

        def save(path, arr):
            """s."""
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                np.savez(f, arr=arr)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
    ''', ["atomicity"])
    assert findings == []


def test_atomicity_bytesio_is_not_a_durable_write(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import io

        import numpy as np

        def pack(arr):
            """p."""
            bio = io.BytesIO()
            np.save(bio, arr)
            return bio.getvalue()
    ''', ["atomicity"])
    assert findings == []


def test_atomicity_suppressed(tmp_path):
    findings, suppressed = _lint(tmp_path, '''
        """m."""
        def save(path, data):
            """s."""
            # lint: allow(GH301): caller stages path inside the tmp dir
            with open(path, "w") as f:
                f.write(data)
    ''', ["atomicity"])
    assert findings == []
    assert suppressed == 1


# ------------------------------ shapes (GH4xx) -----------------------------

def test_shape_token_grammar():
    assert parse_shape_tokens("values ``[V, Q]`` and splitter ``[K+1]``") \
        == [("V", "Q"), ("K",)]
    assert parse_shape_tokens("``[V(, Q)]`` optional axis") == [("V", "Q")]
    # prose brackets are not shape tokens
    assert parse_shape_tokens("range [lo, hi) and list[Tile]") == []


def test_shapes_public_array_api_without_token(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import numpy as np

        def scale(x: np.ndarray) -> np.ndarray:
            """Doubles the values."""
            return x * 2
    ''', ["shapes"])
    assert _codes(findings) == ["GH401"]


def test_shapes_clean_with_token_and_unknown_axis(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        import numpy as np

        def scale(x: np.ndarray) -> np.ndarray:
            """Doubles ``[V, Q]`` values."""
            return x * 2
    ''', ["shapes"])
    assert findings == []

    findings, _ = _lint(tmp_path, '''
        """m."""
        import numpy as np

        def scale(x: np.ndarray) -> np.ndarray:
            """Doubles ``[V, Z]`` values."""
            return x * 2
    ''', ["shapes"], name="badaxis.py")
    assert _codes(findings) == ["GH403"]
    assert "'Z'" in findings[0].message


def test_shapes_axis_order_mismatch(tmp_path):
    code = '''
        """m."""
        def callee(x):
            """Reduces ``[Q, V]`` blocks."""
            return x

        def caller(x):
            """Walks ``[V, Q]`` blocks."""
            return callee(x){transpose}
    '''
    findings, _ = _lint(tmp_path, code.format(transpose=""), ["shapes"])
    assert _codes(findings) == ["GH402"]
    findings, _ = _lint(tmp_path, code.format(transpose=".T"), ["shapes"],
                        name="transposed.py")
    assert findings == []


# ---------------------------- docstrings (GH5xx) ---------------------------

def test_docstrings_missing_module_class_def(tmp_path):
    findings, _ = _lint(tmp_path, '''
        class Pub:
            def meth(self):
                return 1

            def _private(self):
                return 2

        def _helper():
            return 3
    ''', ["docstrings"])
    # module + class + public method; privates are skipped
    assert _codes(findings) == ["GH501", "GH501", "GH501"]


def test_docstrings_clean(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        class Pub:
            """c."""
            def meth(self):
                """d."""
                return 1
    ''', ["docstrings"])
    assert findings == []


# --------------------------- suppression hygiene ---------------------------

def test_allow_without_justification_is_gh001(tmp_path):
    findings, _ = _lint(tmp_path, '''
        """m."""
        def f(d):
            """f."""
            # lint: allow(GH205)
            for k in d.items():
                pass
    ''', ["determinism"])
    assert "GH001" in _codes(findings)


def test_unused_allow_is_gh002_only_on_full_runs(tmp_path):
    code = '''
        """m."""
        # lint: allow(GH205): justified but matches nothing
        X = 1
    '''
    findings, _ = _lint(tmp_path, code, sorted(CHECKERS))
    assert _codes(findings) == ["GH002"]
    # a subset run legitimately leaves other checkers' allows unmatched
    findings, _ = _lint(tmp_path, code, ["docstrings"], name="subset.py")
    assert findings == []


def test_syntax_error_is_gh000(tmp_path):
    findings, _ = _lint(tmp_path, "def broken(:\n", sorted(CHECKERS))
    assert _codes(findings) == ["GH000"]


# ------------------------------ self-run gate ------------------------------

def test_repro_tree_is_lint_clean():
    """The real tree must stay clean: every invariant violation is either
    fixed or carries a justified suppression (src/repro/core and
    src/repro/kernels must in particular be clean — CI enforces the whole
    package)."""
    findings, suppressed = run([os.path.join(REPO, "src", "repro")],
                               sorted(CHECKERS))
    assert findings == [], "\n".join(f.render() for f in findings)
    assert suppressed > 0   # the recorded justifications stay matched


def test_cli_exit_codes(tmp_path):
    clean = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "analyze.py"),
         "--check", "docstrings", os.path.join(REPO, "src", "repro",
                                               "kernels")],
        capture_output=True, text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    assert "repro-lint: 0 finding(s)" in clean.stdout

    bad = tmp_path / "bad.py"
    bad.write_text('"""m."""\n\n\ndef save(path, data):\n'
                   '    """s."""\n'
                   '    with open(path, "w") as f:\n'
                   '        f.write(data)\n')
    dirty = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "analyze.py"),
         "--check", "atomicity", "--all-files", str(bad)],
        capture_output=True, text=True)
    assert dirty.returncode == 1
    assert "GH301" in dirty.stdout
