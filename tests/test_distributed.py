"""Multi-device tests (subprocess with 8 forced host devices): distributed
GAB equivalence across comm modes, mesh train/serve lower+compile."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> dict:
    """Run `code` in a subprocess with N forced devices; it must print a
    JSON dict on the last line."""
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code)
    )
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_distributed_gab_matches_oracle_all_modes():
    out = run_sub("""
    import json, tempfile
    import numpy as np, jax
    from repro.graphio.formats import TileStore
    from repro.graphio import spe
    from repro.core.distributed import DistributedGABEngine, DistConfig
    from repro.core.apps import PageRank
    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(0)
    nv, ne = 400, 3000
    src = rng.integers(0, nv, ne); dst = rng.integers(0, nv, ne)
    k = src*nv+dst; _, i = np.unique(k, return_index=True); src, dst = src[i], dst[i]
    store = TileStore(tempfile.mkdtemp())
    plan = spe.preprocess_arrays(src, dst, None, nv, store, tile_size=150)
    tiles = [store.read_tile(t) for t in range(plan.num_tiles)]
    ind, outd = store.load_degrees()

    import networkx as nx
    G = nx.DiGraph(); G.add_nodes_from(range(nv))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    pr = nx.pagerank(G, alpha=0.85, tol=1e-12, max_iter=500)
    ref = np.array([pr[i] for i in range(nv)])

    mesh = make_mesh((4, 2), ("data", "model"))
    errs = {}
    for mode in ("dense", "sparse", "hybrid"):
        eng = DistributedGABEngine(mesh, ("data", "model"),
                                   DistConfig(comm_mode=mode))
        vals, hist = eng.run(PageRank(update_tol=1e-10), tiles, nv,
                             outd, ind, plan.row_cap, max_supersteps=80)
        errs[mode] = float(np.abs(vals/vals.sum() - ref).max())
    print(json.dumps(errs))
    """)
    for mode, err in out.items():
        assert err < 1e-7, (mode, err)


@pytest.mark.slow
def test_distributed_multi_query_matches_out_of_core():
    """[V, Q] vertex state through the shard_map superstep (DESIGN.md §9):
    the device-mesh engine must reproduce the out-of-core engine's batched
    results exactly for every comm mode (2-D payloads flatten to
    (vertex, query) cells on the sparse path)."""
    out = run_sub("""
    import json, tempfile
    import numpy as np, jax
    from repro.graphio.formats import TileStore
    from repro.graphio import spe
    from repro.core.distributed import DistributedGABEngine, DistConfig
    from repro.core.engine import EngineConfig, OutOfCoreEngine
    from repro.core.apps import MultiSourceBFS, PersonalizedPageRank
    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(0)
    nv, ne = 400, 3000
    src = rng.integers(0, nv, ne); dst = rng.integers(0, nv, ne)
    k = src*nv+dst; _, i = np.unique(k, return_index=True); src, dst = src[i], dst[i]
    store = TileStore(tempfile.mkdtemp())
    plan = spe.preprocess_arrays(src, dst, None, nv, store, tile_size=150)
    tiles = [store.read_tile(t) for t in range(plan.num_tiles)]
    ind, outd = store.load_degrees()

    seeds = (0, 7, 113, 250)
    ref = OutOfCoreEngine(store, EngineConfig(num_servers=2)).run(
        MultiSourceBFS(sources=seeds))
    ref_ppr = OutOfCoreEngine(store, EngineConfig(num_servers=2)).run(
        PersonalizedPageRank(seeds=seeds))

    mesh = make_mesh((4, 2), ("data", "model"))
    res = {}
    for mode in ("dense", "sparse", "hybrid"):
        eng = DistributedGABEngine(mesh, ("data", "model"),
                                   DistConfig(comm_mode=mode))
        vals, hist = eng.run(MultiSourceBFS(sources=seeds), tiles, nv,
                             outd, ind, plan.row_cap, max_supersteps=80)
        res[mode] = bool(np.array_equal(
            np.where(np.isinf(vals), -1, vals),
            np.where(np.isinf(ref.values), -1, ref.values)))
    eng = DistributedGABEngine(mesh, ("data", "model"), DistConfig())
    vals, _ = eng.run(PersonalizedPageRank(seeds=seeds), tiles, nv,
                      outd, ind, plan.row_cap, max_supersteps=200)
    res["ppr_err"] = float(np.abs(vals - ref_ppr.values).max())
    print(json.dumps(res))
    """)
    for mode in ("dense", "sparse", "hybrid"):
        assert out[mode], mode
    # PPR crosses a different superstep schedule (no retirement on the mesh
    # engine), so allow float accumulation-order noise
    assert out["ppr_err"] < 1e-6


@pytest.mark.slow
def test_mesh_train_step_compiles_and_runs():
    out = run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.train import train_step as ts
    from repro.train.optimizer import OptConfig
    from repro.launch.mesh import make_mesh

    cfg = registry.get_config("qwen3-1.7b", reduced=True)
    run = RunConfig(remat="block", microbatch=1, q_chunk=16, kv_chunk=16,
                    loss_chunk=16, compute_dtype="float32",
                    sharding_mode="fsdp")
    mesh = make_mesh((4, 2), ("data", "model"))
    step, init, sh = ts.build_train_step(cfg, run, OptConfig(), mesh=mesh)
    state = jax.jit(init, out_shardings=sh["state"])(jax.random.key(0))
    batch = registry.synthetic_batch(
        cfg, registry.SHAPE_CELLS["train_4k"], batch=8, seq=32)
    batch = {k: jax.device_put(jnp.asarray(v), sh["batch"]) for k, v in batch.items()}
    losses = []
    for _ in range(4):
        state, stats = step(state, batch)
        losses.append(float(stats["loss"]))
    # params sharded over the mesh?
    wq = state["params"]["cycles"]["0G"]["attn"]["wq"]
    print(json.dumps({"losses": losses,
                      "n_shards": len(wq.sharding.device_set)}))
    """)
    assert all(np.isfinite(v) for v in out["losses"])
    assert out["losses"][-1] < out["losses"][0]
    assert out["n_shards"] == 8


@pytest.mark.slow
def test_mesh_serve_fns_run():
    out = run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.serve.serve_step import build_serve_fns
    from repro.launch.mesh import make_mesh

    cfg = registry.get_config("qwen3-1.7b", reduced=True)
    run = RunConfig(remat="none", q_chunk=16, kv_chunk=16,
                    compute_dtype="float32")
    mesh = make_mesh((4, 2), ("data", "model"))
    fns = build_serve_fns(cfg, run, mesh=mesh, max_len=64, batch=8)
    from repro.models.model_zoo import build_model
    params = jax.jit(build_model(cfg, run).init,
                     out_shardings=fns["shardings"]["params"])(jax.random.key(0))
    cache = jax.jit(fns["init_cache"],
                    out_shardings=fns["shardings"]["cache"])()
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32))
    cache, logits = fns["prefill"](params, cache, {"tokens": toks})
    tok = toks[:, -1:]
    cache, logits2 = fns["decode"](params, cache, tok, jnp.int32(16))
    ok = bool(jnp.all(jnp.isfinite(logits))) and bool(jnp.all(jnp.isfinite(logits2)))
    print(json.dumps({"ok": ok, "shape": list(logits2.shape)}))
    """)
    assert out["ok"]
    assert out["shape"][0] == 8


@pytest.mark.slow
def test_elastic_reshard_across_meshes():
    out = run_sub("""
    import json, tempfile, numpy as np, jax, jax.numpy as jnp
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.train import train_step as ts
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import OptConfig
    from repro.launch.mesh import make_mesh

    cfg = registry.get_config("qwen3-1.7b", reduced=True)
    run = RunConfig(remat="none", microbatch=1, q_chunk=16, kv_chunk=16,
                    loss_chunk=16, compute_dtype="float32")
    mesh_a = make_mesh((8, 1), ("data", "model"))
    mesh_b = make_mesh((2, 4), ("data", "model"))
    step_a, init, sh_a = ts.build_train_step(cfg, run, OptConfig(), mesh=mesh_a)
    step_b, _, sh_b = ts.build_train_step(cfg, run, OptConfig(), mesh=mesh_b)
    state = jax.jit(init, out_shardings=sh_a["state"])(jax.random.key(0))
    batch = registry.synthetic_batch(
        cfg, registry.SHAPE_CELLS["train_4k"], batch=8, seq=32)
    ba = {k: jax.device_put(jnp.asarray(v), sh_a["batch"]) for k, v in batch.items()}
    state, s1 = step_a(state, ba)

    mgr = CheckpointManager(tempfile.mkdtemp())
    mgr.save(1, state)
    # rescale: restore the same checkpoint onto a different mesh shape
    _, state_b = mgr.restore(1, shardings=sh_b["state"])
    bb = {k: jax.device_put(jnp.asarray(v), sh_b["batch"]) for k, v in batch.items()}
    state_b, s2 = step_b(state_b, bb)

    # and continue on mesh A for reference
    state, s1b = step_a(state, ba)
    print(json.dumps({"loss_b": float(s2["loss"]), "loss_a": float(s1b["loss"])}))
    """)
    assert abs(out["loss_b"] - out["loss_a"]) < 1e-3
