"""GAB vertex programs vs networkx oracles through the out-of-core engine."""
import numpy as np
import pytest

from repro.core.apps import BFS, SSSP, WCC, InDegree, PageRank
from repro.core.engine import EngineConfig, OutOfCoreEngine


def run(store, prog, servers=3, **kw):
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=servers,
                                              max_supersteps=200, **kw))
    return eng.run(prog)


def test_pagerank_matches_networkx(small_store, nx_pagerank):
    store, plan, _ = small_store
    res = run(store, PageRank(update_tol=1e-10))
    assert res.converged
    ours = res.values / res.values.sum()
    assert np.abs(ours - nx_pagerank).max() < 1e-7


def test_pagerank_server_count_invariant(small_store):
    store, plan, _ = small_store
    r1 = run(store, PageRank(update_tol=1e-10), servers=1)
    r5 = run(store, PageRank(update_tol=1e-10), servers=5)
    np.testing.assert_allclose(r1.values, r5.values, rtol=1e-6)


def test_sssp_matches_dijkstra(tmp_path, small_graph):
    import networkx as nx
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=100)
    res = run(store, SSSP(source=0))
    G = nx.DiGraph()
    G.add_nodes_from(range(nv))
    for s, d, w in zip(src.tolist(), dst.tolist(), val.tolist()):
        G.add_edge(s, d, weight=w)
    dist = nx.single_source_dijkstra_path_length(G, 0)
    ref = np.array([dist.get(i, np.inf) for i in range(nv)], np.float32)
    fin = np.isfinite(ref)
    assert np.array_equal(np.isfinite(res.values), fin)
    assert np.abs(res.values[fin] - ref[fin]).max() < 1e-4


def test_wcc_on_symmetrized(tmp_path, small_graph):
    import networkx as nx
    from repro.graphio import spe, synth
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    store = TileStore(str(tmp_path / "sym"))
    spe.preprocess(
        lambda: synth.symmetrized(synth.from_arrays(src, dst)),
        nv, store, tile_size=128)
    res = run(store, WCC())
    G = nx.Graph()
    G.add_nodes_from(range(nv))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    for comp in nx.connected_components(G):
        labels = {int(res.values[v]) for v in comp}
        assert len(labels) == 1, "one label per component"
        assert min(comp) == min(labels)


def test_bfs_levels(small_store, small_graph):
    import networkx as nx

    store, plan, (nv, src, dst) = small_store
    res = run(store, BFS(source=1))
    G = nx.DiGraph()
    G.add_nodes_from(range(nv))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    lv = nx.single_source_shortest_path_length(G, 1)
    ref = np.array([lv.get(i, np.inf) for i in range(nv)])
    fin = np.isfinite(ref)
    assert np.array_equal(np.isfinite(res.values), fin)
    assert np.abs(res.values[fin] - ref[fin]).max() == 0


def test_indegree_one_superstep(small_store, small_graph):
    store, plan, (nv, src, dst) = small_store
    res = run(store, InDegree(), servers=2)
    want = np.bincount(dst, minlength=nv).astype(np.float32)
    np.testing.assert_allclose(res.values, want)


def test_tile_skipping_sssp_correct_and_skips(tmp_path, small_graph):
    """SSSP touches few vertices late in the run — tiles must be skipped
    without changing the result (paper §III-C-4)."""
    import networkx as nx
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w2"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=64)
    # block_shift=2: 4-vertex bitmap blocks (default 256-vertex blocks are
    # too coarse to discriminate on a 300-vertex graph)
    res_skip = run(store, SSSP(source=0), tile_skipping=True,
                   skip_density_threshold=0.9, block_shift=2)
    res_noskip = run(store, SSSP(source=0), tile_skipping=False)
    np.testing.assert_allclose(res_skip.values, res_noskip.values)
    assert sum(h.tiles_skipped for h in res_skip.history) > 0


def test_bloom_filter_skipping_matches_bitmap(tmp_path, small_graph):
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    store = TileStore(str(tmp_path / "b"))
    spe.preprocess_arrays(src, dst, None, nv, store, tile_size=64)
    res_bloom = run(store, BFS(source=0), tile_skipping=True,
                    skip_filter="bloom", skip_density_threshold=0.9)
    res_bitmap = run(store, BFS(source=0), tile_skipping=True,
                     skip_filter="bitmap", skip_density_threshold=0.9)
    np.testing.assert_allclose(res_bloom.values, res_bitmap.values)


def test_single_superstep_run_result_stats(small_store):
    """Regression: RunResult.mean_superstep_seconds(skip_first=True) /
    disk_stall_fraction on a run whose history holds a single superstep
    must fall back to that superstep (never average / divide an empty
    slice into nan)."""
    import warnings

    store, plan, _ = small_store
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=2,
                                              max_supersteps=1))
    res = eng.run(PageRank())
    assert res.supersteps == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # np.mean([]) would warn
        m = res.mean_superstep_seconds(skip_first=True)
        f = res.disk_stall_fraction(skip_first=True)
    assert m == res.history[0].seconds
    assert np.isfinite(m) and np.isfinite(f)
    assert 0.0 <= f <= 1.0
    # empty history (pathological) still returns a number, not a crash
    from repro.core.engine import RunResult
    empty = RunResult(values=res.values, aux={}, history=[], supersteps=0,
                      converged=False)
    assert empty.mean_superstep_seconds() == 0.0
    assert empty.disk_stall_fraction() == 0.0
