"""Fused gather→combine→apply kernel (kernels/gab_fused.py, DESIGN.md §14).

Three layers of checks:

  * kernel-level parity: ``gab_fused`` vs the unfused composition (one-hot
    ``segment_reduce`` at the same blocks + the apply/mask tail) over the
    adversarial shapes the engine produces — E=0, an edge block that is
    pure padding, E/V that are not block multiples, Q>1 with sublane
    padding;
  * engine-level bit-identity: all six shipped apps run with
    ``kernel_autotune`` on and must reproduce the unfused one-hot path at
    the autotuner's blocks byte for byte, serial and pipelined;
  * autotuner units: determinism, VMEM feasibility filtering, the static
    (512, 256) never model-beating the pick, stack-size clamping.

A note on float exactness (see DESIGN.md §14): XLA:CPU deletes
``optimization_barrier`` and contextually contracts ``a·x + b·y`` into an
FMA when the apply fuses with the accumulator's producer, so an XLA-traced
affine apply and the in-kernel apply can legitimately differ in the last
ulp for arbitrary coefficients.  Bit-identity is *guaranteed* whenever the
products are exactly representable — min/max applies (no arithmetic) and
power-of-two affine coefficients — so the strict equality tests pin
``damping=0.5``; default-damping runs are asserted at float tolerance.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gab
from repro.kernels.gab_fused import FusedSpec, gab_fused
from repro.roofline import kernel_tune


# ---------------------------------------------------------------------------
# kernel-level parity vs the unfused composition
# ---------------------------------------------------------------------------

def _unfused(spec, src_vals, a, b, dst_local, old, base, num_rows, row_cap,
             blocks):
    """The unfused composition at the same blocks, evaluated eagerly:
    gather expression -> one-hot segment_reduce -> apply -> valid/updated
    mask.  Mirrors core/gab.tile_gather_apply term for term."""
    sv = jnp.asarray(src_vals, jnp.float32)
    contrib = sv
    if a is not None:
        av = jnp.asarray(a, jnp.float32)
        contrib = contrib * (av[:, None] if sv.ndim == 2 else av)
    if b is not None:
        bv = jnp.asarray(b, jnp.float32)
        contrib = contrib + (bv[:, None] if sv.ndim == 2 else bv)
    if spec.add_const is not None:
        contrib = contrib + jnp.float32(spec.add_const)
    accum = gab.segment_reduce(
        contrib, jnp.asarray(dst_local, jnp.int32), row_cap + 1,
        spec.combine, impl="pallas_onehot", blocks=blocks)[:row_cap]
    ov = jnp.asarray(old, jnp.float32)
    if spec.apply == "affine":
        bb = jnp.float32(spec.alpha) * jnp.asarray(base, jnp.float32) \
            if base is not None else jnp.float32(spec.alpha)
        new = bb + jnp.float32(spec.beta) * accum
    elif spec.apply == "min":
        new = jnp.minimum(ov, accum)
    else:
        new = jnp.maximum(ov, accum)
    rows = jnp.arange(row_cap)
    valid = rows < num_rows
    valid = valid[:, None] if new.ndim == 2 else valid
    new = jnp.where(valid, new, ov)
    if spec.update_tol > 0.0:
        upd = jnp.abs(new - ov) > spec.update_tol
    else:
        upd = new != ov
    return np.asarray(new), np.asarray(jnp.logical_and(valid, upd))


def _random_tile(rng, E, row_cap, Q, spec, pad_frac=0.2):
    """Random tile-shaped inputs honoring the sink-row padding convention."""
    shape = (E,) if Q == 1 else (E, Q)
    sv = rng.normal(size=shape).astype(np.float32)
    if spec.combine in ("min", "max"):
        sv = np.abs(sv)         # distances: keep comparable magnitudes
    dst = np.sort(rng.integers(0, row_cap, E)).astype(np.int32)
    npad = int(E * pad_frac)
    if npad:
        dst[E - npad:] = row_cap            # trailing inert padding edges
        sv[E - npad:] = 0.0
    a = (rng.random(E).astype(np.float32) + 0.1) if spec.scale_aux else None
    b = rng.random(E).astype(np.float32) if spec.add_edge else None
    oshape = (row_cap,) if Q == 1 else (row_cap, Q)
    old = np.abs(rng.normal(size=oshape)).astype(np.float32) + 1.0
    base = rng.random(oshape).astype(np.float32) if spec.base_aux else None
    num_rows = max(1, row_cap - 3)
    return sv, a, b, dst, old, base, num_rows


# Power-of-two affine coefficients: products exact in f32, so FMA
# contraction cannot change the rounding — strict equality is well-defined.
SPECS = {
    "sum_affine": FusedSpec(combine="sum", scale_aux="inv", apply="affine",
                            alpha=0.5, beta=0.5, update_tol=1e-8),
    "sum_affine_base": FusedSpec(combine="sum", scale_aux="inv",
                                 apply="affine", alpha=0.25, beta=0.5,
                                 base_aux="seed", update_tol=1e-9),
    "min_edge": FusedSpec(combine="min", add_edge=True, apply="min"),
    "min_const": FusedSpec(combine="min", add_const=1.0, apply="min"),
    "max_plain": FusedSpec(combine="max", apply="max"),
}


@pytest.mark.parametrize("E,row_cap,Q", [
    (777, 130, 3),      # nothing a block multiple
    (513, 257, 5),      # one past a block boundary both axes
    (64, 16, 1),        # far below one block (1-D squeeze path)
    (2000, 300, 8),     # a full sublane of queries
])
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_fused_matches_unfused_composition(E, row_cap, Q, spec_name):
    spec = SPECS[spec_name]
    rng = np.random.default_rng(E * 31 + row_cap + Q)
    sv, a, b, dst, old, base, num_rows = _random_tile(rng, E, row_cap, Q,
                                                      spec)
    blocks = (256, 128)
    new_f, upd_f = gab_fused(
        spec, jnp.asarray(sv), None if a is None else jnp.asarray(a),
        None if b is None else jnp.asarray(b), jnp.asarray(dst),
        jnp.asarray(old), None if base is None else jnp.asarray(base),
        jnp.int32(num_rows), row_cap, block_e=blocks[0], block_r=blocks[1])
    new_u, upd_u = _unfused(spec, sv, a, b, dst, old, base, num_rows,
                            row_cap, blocks)
    np.testing.assert_array_equal(np.asarray(new_f), new_u, err_msg=spec_name)
    np.testing.assert_array_equal(np.asarray(upd_f), upd_u, err_msg=spec_name)


def test_fused_default_damping_within_float_noise():
    """Non-power-of-two affine coefficients: XLA may contract the unfused
    apply into an FMA, so agreement is asserted at float tolerance (the
    accumulation itself is still order-identical)."""
    spec = FusedSpec(combine="sum", scale_aux="inv", apply="affine",
                     alpha=1.0 - 0.85, beta=0.85, update_tol=1e-8)
    rng = np.random.default_rng(3)
    sv, a, b, dst, old, base, num_rows = _random_tile(rng, 900, 200, 4, spec)
    new_f, _ = gab_fused(spec, jnp.asarray(sv), jnp.asarray(a), None,
                         jnp.asarray(dst), jnp.asarray(old), None,
                         jnp.int32(num_rows), 200)
    from repro.kernels.gab_gather import DEFAULT_BLOCK_E, DEFAULT_BLOCK_R
    new_u, _ = _unfused(spec, sv, a, b, dst, old, base, num_rows, 200,
                        (DEFAULT_BLOCK_E, DEFAULT_BLOCK_R))
    np.testing.assert_allclose(np.asarray(new_f), new_u, rtol=1e-6,
                               atol=3e-8)


@pytest.mark.parametrize("spec_name", ["sum_affine", "min_edge", "max_plain"])
def test_fused_empty_edge_list(spec_name):
    """E=0 pads to one all-padding block; every row reduces the identity,
    so affine rows become alpha·base and min/max rows keep old."""
    spec = SPECS[spec_name]
    row_cap, Q = 40, 3
    old = np.abs(np.random.default_rng(0).normal(size=(row_cap, Q))
                 ).astype(np.float32) + 1.0
    a = np.zeros((0,), np.float32) if spec.scale_aux else None
    b = np.zeros((0,), np.float32) if spec.add_edge else None
    new_f, upd_f = gab_fused(
        spec, jnp.zeros((0, Q), jnp.float32),
        None if a is None else jnp.asarray(a),
        None if b is None else jnp.asarray(b),
        jnp.zeros((0,), jnp.int32), jnp.asarray(old), None,
        jnp.int32(row_cap), row_cap)
    if spec.apply in ("min", "max"):
        np.testing.assert_array_equal(np.asarray(new_f), old)
        assert not np.asarray(upd_f).any()
    else:
        want = np.float32(spec.alpha) + np.float32(spec.beta) * np.float32(0)
        np.testing.assert_array_equal(np.asarray(new_f),
                                      np.full_like(old, want))


@pytest.mark.parametrize("spec_name", ["sum_affine_base", "min_const"])
def test_fused_all_padding_edges(spec_name):
    """Every edge routed to the sink row: the accumulator must stay at the
    identity for all real rows (one whole edge block is pure padding)."""
    spec = SPECS[spec_name]
    E, row_cap, Q = 300, 70, 2
    rng = np.random.default_rng(1)
    sv = np.zeros((E, Q), np.float32)
    dst = np.full((E,), row_cap, np.int32)
    old = np.abs(rng.normal(size=(row_cap, Q))).astype(np.float32) + 1.0
    base = rng.random((row_cap, Q)).astype(np.float32)
    a = (rng.random(E).astype(np.float32) if spec.scale_aux else None)
    b = rng.random(E).astype(np.float32) if spec.add_edge else None
    new_f, upd_f = gab_fused(
        spec, jnp.asarray(sv), None if a is None else jnp.asarray(a),
        None if b is None else jnp.asarray(b), jnp.asarray(dst),
        jnp.asarray(old), None if spec.base_aux is None
        else jnp.asarray(base), jnp.int32(row_cap), row_cap)
    new_u, upd_u = _unfused(spec, sv, a, b, dst, old,
                            base if spec.base_aux else None,
                            row_cap, row_cap, (256, 128))
    np.testing.assert_array_equal(np.asarray(new_f), new_u)
    np.testing.assert_array_equal(np.asarray(upd_f), upd_u)


# ---------------------------------------------------------------------------
# engine-level bit-identity with kernel_autotune on
# ---------------------------------------------------------------------------

def _apps():
    from repro.core import apps

    # damping=0.5: affine products exact -> strict equality well-defined
    # (see module docstring); Q spans 1, 3, and a full sublane of 8.
    return [
        ("pagerank", lambda: apps.PageRank(damping=0.5, update_tol=1e-8)),
        ("wcc", lambda: apps.WCC()),
        ("sssp", lambda: apps.SSSP(source=0)),
        ("ppr", lambda: apps.PersonalizedPageRank(
            seeds=(1, 7, 50), damping=0.5)),
        ("msbfs", lambda: apps.MultiSourceBFS(sources=(2, 11, 60))),
        ("landmarks", lambda: apps.LandmarkDistances(
            landmarks=(0, 9, 33, 60, 101, 160, 201, 250))),
    ]


def _run(store, prog, supersteps=10, **cfg_kw):
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    eng = OutOfCoreEngine(store, EngineConfig(num_servers=2, **cfg_kw))
    res = eng.run(prog, max_supersteps=supersteps)
    return np.asarray(res.values), eng


@pytest.mark.parametrize("app_name,mk", _apps())
def test_engine_autotuned_fused_bit_identical(small_store, app_name, mk):
    """kernel_autotune promotes to the fused kernel; the result must be
    byte-for-byte the unfused one-hot path at the autotuner's blocks."""
    store, _, _ = small_store
    v_fused, eng = _run(store, mk(), kernel_autotune=True)
    choice = eng.kernel_choice
    assert choice is not None and choice.block_e >= 128
    v_unfused, _ = _run(store, mk(), seg_impl="pallas_onehot",
                        kernel_blocks=choice.blocks)
    np.testing.assert_array_equal(v_fused, v_unfused, err_msg=app_name)


@pytest.mark.parametrize("app_name,mk", [_apps()[0], _apps()[5]])
def test_engine_autotuned_pipelined_bit_identical(small_store, app_name, mk):
    """Serial and pipelined fused execution agree byte for byte (Q=1 and a
    full Q=8 sublane)."""
    store, _, _ = small_store
    v_serial, _ = _run(store, mk(), kernel_autotune=True)
    v_pipe, _ = _run(store, mk(), kernel_autotune=True, pipeline=True)
    np.testing.assert_array_equal(v_serial, v_pipe, err_msg=app_name)


def test_engine_autotuned_default_damping_close(small_store):
    """Default (non-power-of-two) damping: fused vs unfused agree to float
    tolerance — the last-ulp slack is XLA's FMA contraction of the traced
    apply, not an accumulation difference."""
    from repro.core.apps import PersonalizedPageRank

    store, _, _ = small_store
    v_fused, eng = _run(store, PersonalizedPageRank(seeds=(1, 7, 50)),
                        supersteps=20, kernel_autotune=True)
    v_unfused, _ = _run(store, PersonalizedPageRank(seeds=(1, 7, 50)),
                        supersteps=20, seg_impl="pallas_onehot",
                        kernel_blocks=eng.kernel_choice.blocks)
    np.testing.assert_allclose(v_fused, v_unfused, rtol=1e-5, atol=1e-12)


def test_engine_autotune_fallback_without_fused_spec(small_store):
    """A program with no fused form (InDegree) falls back to the one-hot
    kernel under kernel_autotune and still matches the jnp reference."""
    from repro.core.apps import InDegree

    store, _, _ = small_store
    assert InDegree().fused_spec() is None
    v_auto, _ = _run(store, InDegree(), supersteps=3, kernel_autotune=True)
    v_ref, _ = _run(store, InDegree(), supersteps=3)
    np.testing.assert_array_equal(v_auto, v_ref)


def test_engine_explicit_kernel_blocks_override(small_store):
    """cfg.kernel_blocks bypasses the cost model verbatim."""
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store, _, _ = small_store
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=1, kernel_autotune=True, kernel_blocks=(128, 128)))
    impl, blocks, _ = eng.kernel_plan(PageRank())
    assert impl == "pallas_fused" and blocks == (128, 128)
    assert eng.kernel_choice is None          # model never consulted


# ---------------------------------------------------------------------------
# roofline autotuner units
# ---------------------------------------------------------------------------

def test_pick_blocks_deterministic_and_feasible():
    a = kernel_tune.pick_blocks("sum", 1, 4096, 512, bandwidth=100e9)
    b = kernel_tune.pick_blocks("sum", 1, 4096, 512, bandwidth=100e9)
    assert a == b
    assert a.block_e % 128 == 0 and a.block_r % 128 == 0
    assert 1 <= a.stack_size <= 16
    assert a.predicted_s > 0 and a.edges_per_s > 0
    assert a.bound in ("memory", "compute")
    assert kernel_tune.vmem_plan_bytes("sum", 1, a.block_e, a.block_r) \
        <= kernel_tune._VMEM_FRACTION * kernel_tune.hw.VMEM_BYTES


def test_pick_blocks_never_model_worse_than_static():
    """The static (512, 256) default is always a candidate when feasible,
    so the pick can never predict worse than it."""
    for combine in ("sum", "min"):
        for q in (1, 8, 32):
            for ec, rc in [(4096, 512), (65536, 2048), (512, 128)]:
                pick = kernel_tune.pick_blocks(combine, q, ec, rc,
                                               bandwidth=50e9)
                static = kernel_tune.tile_cost(
                    combine, q, ec, rc, *kernel_tune.STATIC_BLOCKS,
                    bandwidth=50e9)
                feasible = kernel_tune.vmem_plan_bytes(
                    combine, q, *kernel_tune.STATIC_BLOCKS) \
                    <= kernel_tune._VMEM_FRACTION * kernel_tune.hw.VMEM_BYTES
                if feasible:
                    assert pick.predicted_s <= static.predicted_s, \
                        (combine, q, ec, rc)


def test_pick_blocks_vmem_constrains_minmax_wide_q():
    """min/max plan a [Q, BE, BR] select: wide Q must be pushed to smaller
    edge blocks than the sum monoid at the same shape."""
    s = kernel_tune.pick_blocks("sum", 32, 8192, 1024, bandwidth=100e9)
    m = kernel_tune.pick_blocks("min", 32, 8192, 1024, bandwidth=100e9)
    assert kernel_tune.vmem_plan_bytes("min", 32, m.block_e, m.block_r) \
        <= kernel_tune._VMEM_FRACTION * kernel_tune.hw.VMEM_BYTES
    assert m.block_e * m.block_r <= s.block_e * s.block_r


def test_pick_blocks_caps_at_tile_shape():
    """Blocks larger than the padded tile only pad — candidates are capped,
    so a tiny tile picks the minimum (128, 128)."""
    c = kernel_tune.pick_blocks("sum", 1, 100, 60, bandwidth=100e9)
    assert c.blocks == (128, 128)


def test_stack_size_scales_inverse_with_tile_time():
    assert kernel_tune._stack_size(1e-6) == 16     # tiny tiles: batch hard
    assert kernel_tune._stack_size(1.0) == 1       # huge tiles: no batching


def test_degenerate_vmem_budget_falls_back():
    c = kernel_tune.pick_blocks("min", 64, 4096, 2048, bandwidth=100e9,
                                vmem_bytes=1024)
    assert c.blocks == (128, 128)


# ---------------------------------------------------------------------------
# weighted-edge association regression
# ---------------------------------------------------------------------------

def test_engine_weighted_edges_bit_identical(tmp_path):
    """Regression: on *weighted* graphs the fused path pre-folds the scale
    stream as ``a = inv · ev``, so the unfused gather must group
    ``src · (inv · ev)`` the same way — the historical ``(src · inv) · ev``
    rounds differently whenever ev != 1.0 and broke bit-identity only on
    weighted stores (unweighted ev == 1.0 hides it)."""
    from repro.core import apps
    from repro.graphio import spe, synth
    from repro.graphio.formats import TileStore

    store = TileStore(str(tmp_path / "wstore"))
    spe.preprocess(
        lambda: synth.rmat_edges(600, 4000, seed=3, weighted=True),
        600, store, tile_size=128, weighted=True)
    for mk in (lambda: apps.PageRank(damping=0.5, update_tol=1e-8),
               lambda: apps.SSSP(source=0),
               lambda: apps.PersonalizedPageRank(seeds=(1, 7), damping=0.5)):
        v_fused, eng = _run(store, mk(), kernel_autotune=True)
        v_unfused, _ = _run(store, mk(), seg_impl="pallas_onehot",
                            kernel_blocks=eng.kernel_choice.blocks)
        np.testing.assert_array_equal(v_fused, v_unfused,
                                      err_msg=type(mk()).__name__)
