"""Regression tests for the true positives repro-lint found (PR 9 triage).

One behavioral test per fixed finding cluster:

* ``VertexStateStore._spill`` published spill files without fsync — a
  crash could persist the rename with no data behind it (GH302).
* ``TileStore.initialize`` wrote ``degrees.npz`` bare (GH301) and
  ``meta.json``/``write_tile`` published without fsync (GH302).
* ``EdgeCache.maintain`` re-read ``stats`` outside the lock to learn
  whether a demotion committed (GH101) — ``_demote``/``_try_promote``
  now return the outcome instead.
* ``SocketTransport.close`` iterated and cleared ``_out`` without the
  per-destination locks (GH101) — concurrent close/close or close/send
  could double-close a socket.
* ``simulate_superstep`` iterated its ``idle`` set in hash order
  (GH201) — dispatch order (and therefore tie-breaks) now follows
  ``sorted(idle)``.

The remaining fixes (EngineSession.next_qid read-modify-write,
GraphService stats) are lock-discipline only; the analyzer self-run in
``test_analyzers.py`` is their regression test.
"""
import os
import tempfile
import threading

import numpy as np

from repro.core import transport as T
from repro.core.cache import EdgeCache
from repro.core.partition import assign_tiles
from repro.core.vstate import VertexStateStore
from repro.runtime.scheduler import WorkStealingScheduler, simulate_superstep


def _watch_publishes(monkeypatch):
    """Monkeypatch os.fsync/os.replace to record the publish protocol;
    ``_fsync_precedes_every_replace`` then asserts every publish saw an
    fsync since the previous one."""
    events = []
    real_fsync, real_replace = os.fsync, os.replace

    def fsync(fd):
        events.append("fsync")
        return real_fsync(fd)

    def replace(srcp, dstp):
        events.append("replace")
        return real_replace(srcp, dstp)

    monkeypatch.setattr(os, "fsync", fsync)
    monkeypatch.setattr(os, "replace", replace)
    return events


def _fsync_precedes_every_replace(events):
    seen_fsync = False
    for ev in events:
        if ev == "fsync":
            seen_fsync = True
        elif ev == "replace":
            if not seen_fsync:
                return False
            seen_fsync = False
    return True


def test_vstate_spill_fsyncs_and_leaves_no_tmp(tmp_path, monkeypatch):
    events = _watch_publishes(monkeypatch)
    store = VertexStateStore(np.array([0, 64, 128]), budget_bytes=8,
                             spill_dir=str(tmp_path))
    store.add_array("value", np.arange(128, dtype=np.float32))
    assert store.stats.spills > 0
    assert "replace" in events
    assert _fsync_precedes_every_replace(events)
    assert not list(tmp_path.glob("**/*.tmp"))


def test_tilestore_preprocess_is_fully_staged(tmp_path, monkeypatch):
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    events = _watch_publishes(monkeypatch)
    rng = np.random.default_rng(5)
    nv = 60
    src = rng.integers(0, nv, 300)
    dst = rng.integers(0, nv, 300)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    store = TileStore(str(tmp_path / "store"))
    spe.preprocess_arrays(src[i], dst[i], None, nv, store, tile_size=32)

    # meta.json + degrees.npz + every tile published atomically, each
    # fsync-ed first, with no staging debris left behind
    assert events.count("replace") >= 3
    assert _fsync_precedes_every_replace(events)
    assert not list((tmp_path / "store").glob("**/*.tmp"))
    ind, outd = store.load_degrees()
    assert ind.shape == (nv,) and outd.shape == (nv,)


def test_cache_maintain_counts_match_committed_retiers(small_store):
    store, _, _ = small_store
    cache = EdgeCache(store, 1 << 30, policy="tiered")
    for t in range(4):
        cache.get(t)
    # demote properly, then hand the entries pending hit credit so the
    # next maintain() promotes them back
    staged = []
    for t in range(4):
        e = cache._entries[t]
        if cache._demote(t, e.blob, e.mode):
            cache._entries[t].hits_since_retier = 5
            staged.append(t)
    assert staged          # at least one tile recompresses smaller
    before_p = cache.stats.promotions
    before_d = cache.stats.demotions
    out = cache.maintain(max_ops=8)
    # the returned counts ARE the committed re-tiers — maintain no longer
    # re-reads stats unlocked to learn the outcome
    assert out["promoted"] == cache.stats.promotions - before_p
    assert out["demoted"] == cache.stats.demotions - before_d
    assert out["promoted"] == len(staged)


def test_cache_demote_aborts_on_stale_blob(small_store):
    from repro.graphio import formats

    store, _, _ = small_store
    cache = EdgeCache(store, 1 << 30, policy="tiered")
    cache.get(0)
    e = cache._entries[0]
    # byte-identical recompression but a *different object* — models a
    # concurrent replace racing the demotion
    stale = formats.compress_blob(
        formats.decompress_blob(e.blob, e.mode), e.mode)
    before = cache.stats.demotions
    assert cache._demote(0, stale, e.mode) is False
    assert cache.stats.demotions == before
    assert cache._entries[0].mode == e.mode   # entry untouched


def test_socket_transport_close_is_concurrent_safe():
    tmp = tempfile.mkdtemp(prefix="transport_close_")
    a = T.make_transport("tcp", 0, 2, tmp)
    b = T.make_transport("tcp", 1, 2, tmp)
    try:
        a.send(1, b"ping")
        item = b.recv(timeout=10.0)
        assert item == (0, b"ping")
    finally:
        threads = [threading.Thread(target=a.close) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10.0)
        a.close()   # idempotent after the concurrent close storm
        b.close()


def test_superstep_dispatch_is_run_deterministic():
    def run_once():
        rng = np.random.default_rng(3)
        edges = rng.pareto(1.3, 48) * 1000 + 100
        sched = WorkStealingScheduler(assign_tiles(48, 4), edges)
        stats = simulate_superstep(sched, np.array([1.0, 0.7, 1.3, 0.2]),
                                   lambda t: edges[t])
        winners = tuple(sched.tasks[t].completed_by
                        for t in sorted(sched.tasks))
        return stats["makespan"], tuple(stats["busy"]), winners

    assert run_once() == run_once()
