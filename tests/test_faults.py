"""Fault injection + crash-consistent superstep checkpointing (DESIGN.md §12).

The acceptance property of the whole subsystem: **crash anywhere, resume,
and get byte-for-byte the same answers as the uninterrupted run** — for
every app, in-memory and ooc vertex state, single- and multi-rank, with
hard kills and clean preemptions.  Cluster-process drills live in
tests/test_cluster.py; everything here is in-process (fast, debuggable).
"""
import glob
import os
import signal
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.apps import (LandmarkDistances, MultiSourceBFS, PageRank,
                             PersonalizedPageRank, SSSP, WCC)
from repro.core.checkpoint import GraphCheckpointer
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.core.vstate import VertexStateStore
from repro.graphio import spe
from repro.graphio.formats import TileStore
from repro.runtime import faults
from repro.runtime.elastic import handoff_plan, remap_assignment
from repro.runtime.faults import FaultPlan, FaultSpec, InjectedFault
from repro.runtime.ft import FaultTolerantLoop, Preempted

SS = 12


def _make_store(weighted, seed=7, nv=220, ne=1400, tile_size=96):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    src, dst = src[i], dst[i]
    val = (rng.uniform(0.1, 10.0, len(src)).astype(np.float32)
           if weighted else None)
    root = tempfile.mkdtemp(prefix=f"faults_store_{int(weighted)}_")
    spe.preprocess_arrays(src, dst, val, nv, TileStore(root), tile_size)
    return root


@pytest.fixture(scope="module")
def stores():
    """(unweighted root, weighted root) shared by every test here."""
    return _make_store(False), _make_store(True)


def _run(root, prog, *, n=2, **cfg_kw):
    eng = OutOfCoreEngine(TileStore(root), EngineConfig(
        num_servers=n, max_supersteps=SS, **cfg_kw))
    return eng.run(prog)


# ---------------------------------------------------------------------------
# FaultSpec / FaultInjector units
# ---------------------------------------------------------------------------

def test_parse_spec_roundtrip():
    s = faults.parse_spec("rank=1, superstep=2, site=superstep, kind=sigkill")
    assert s == FaultSpec(site="superstep", superstep=2, rank=1,
                          kind="sigkill")
    s = faults.parse_spec("site=ckpt.leaf,kind=torn_write,keep_bytes=3,"
                          "then=kill,once=false")
    assert s.keep_bytes == 3 and s.then == "kill" and not s.once
    with pytest.raises(ValueError, match="needs site"):
        faults.parse_spec("kind=raise")
    with pytest.raises(ValueError, match="unknown --inject key"):
        faults.parse_spec("site=x,bogus=1")
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.parse_spec("site=x,kind=meteor")
    assert faults.parse_plan([]) is None
    plan = faults.parse_plan(["site=a", "site=b,superstep=4"])
    assert len(plan.specs) == 2


def test_injector_matching_and_once():
    plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=3, rank=1),))
    inj = plan.injector(rank=0)
    inj.check("superstep", 3)           # wrong rank: no fire
    inj = plan.injector(rank=1)
    inj.check("superstep", 2)           # wrong step: no fire
    inj.check("barrier", 3)             # wrong site: no fire
    with pytest.raises(InjectedFault):
        inj.check("superstep", 3)
    inj.check("superstep", 3)           # once=True: second pass is a no-op
    assert inj.fired == [plan.specs[0].spec_id()]
    # rank=None (classic engine) matches any rank spec
    with pytest.raises(InjectedFault):
        plan.injector().check("superstep", 3)


def test_injector_once_marker_survives_restart(tmp_path):
    """The marker claim must outlive the process: a respawned rank sharing
    the marker_dir does not re-fire the same once-spec."""
    plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=2),),
                     marker_dir=str(tmp_path))
    with pytest.raises(InjectedFault):
        plan.injector(rank=0).check("superstep", 2)
    # "restart": a fresh injector (fresh process in real life)
    plan.injector(rank=0).check("superstep", 2)
    assert glob.glob(str(tmp_path) + "/*.fired")


def test_injector_torn_write_and_drop(tmp_path):
    plan = FaultPlan(specs=(
        FaultSpec(site="ckpt.leaf", kind="torn_write", keep_bytes=3),
        FaultSpec(site="transport.send", superstep=5, kind="drop_frame"),
    ))
    inj = plan.injector()
    # torn_write only fires through write(); check() must ignore it
    inj.check("ckpt.leaf", 1)
    p = str(tmp_path / "leaf.npy")
    with pytest.raises(InjectedFault, match="torn write"):
        inj.write(p, b"ABCDEFGH", "ckpt.leaf", 1)
    with open(p, "rb") as f:
        assert f.read() == b"ABC"       # the torn prefix really hit disk
    # a clean write after the once-spec burned
    inj.write(p, b"ABCDEFGH", "ckpt.leaf", 2)
    with open(p, "rb") as f:
        assert f.read() == b"ABCDEFGH"
    assert inj.drop("transport.send", 4) is False
    assert inj.drop("transport.send", 5) is True
    assert inj.drop("transport.send", 5) is False   # once


def test_injector_delay_and_preempt_kinds():
    plan = FaultPlan(specs=(
        FaultSpec(site="superstep", superstep=1, kind="delay",
                  delay_seconds=0.01),
    ))
    plan.injector().check("superstep", 1)   # returns after the sleep
    from repro.runtime.ft import PreemptionGuard

    with PreemptionGuard() as g:
        FaultPlan(specs=(FaultSpec(site="barrier", kind="preempt"),)) \
            .injector().check("barrier", 0)
        assert g.triggered


def test_fault_injecting_transport_drop_and_kill():
    from repro.core.transport import FaultInjectingTransport, _U32

    sent = []

    class Fake:
        rank, n = 0, 2

        def send(self, dst, payload, timeout=None):
            sent.append((dst, payload))

        def recv(self, timeout=0.1):
            return (1, b"pong")

        def close(self):
            pass

    plan = FaultPlan(specs=(
        FaultSpec(site="transport.send", superstep=2, kind="drop_frame"),))
    tr = FaultInjectingTransport(Fake(), plan.injector(rank=0))
    tr.send(1, _U32.pack(1) + b"payload")       # seq 1 passes
    tr.send(1, _U32.pack(2) + b"payload")       # seq 2 dropped on the wire
    tr.send(1, _U32.pack(2) + b"payload")       # once => passes again
    assert [p[:4] for _, p in sent] == [_U32.pack(1), _U32.pack(2)]
    assert tr.recv() == (1, b"pong")
    tr.close()


# ---------------------------------------------------------------------------
# Crash + resume bit-identity (the tentpole acceptance bar)
# ---------------------------------------------------------------------------

def _factories(weighted):
    if weighted:
        return [lambda: SSSP(source=0),
                lambda: LandmarkDistances(landmarks=(0, 9, 33))]
    return [PageRank, WCC,
            lambda: PersonalizedPageRank(seeds=(1, 7, 50)),
            lambda: MultiSourceBFS(sources=(2, 11, 60))]


@pytest.mark.parametrize("weighted", [False, True])
def test_crash_resume_bit_identical_all_apps(stores, weighted, tmp_path):
    """Inject a crash mid-run, resume from the boundary checkpoint, and
    require byte-for-byte the answers of the uninterrupted run — every
    app, emulated N=2."""
    root = stores[int(weighted)]
    for i, mk in enumerate(_factories(weighted)):
        ref = _run(root, mk())
        ck = str(tmp_path / f"ck_{int(weighted)}_{i}")
        plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=3),))
        with pytest.raises(InjectedFault):
            _run(root, mk(), checkpoint_dir=ck, checkpoint_every=2,
                 fault_plan=plan)
        out = _run(root, mk(), checkpoint_dir=ck, resume=True)
        assert np.array_equal(out.values, ref.values), mk()
        assert out.supersteps == ref.supersteps
        assert out.converged == ref.converged
        if ref.per_query_supersteps is not None:
            assert np.array_equal(out.per_query_supersteps,
                                  ref.per_query_supersteps)
        # the resumed run really continued mid-stream, not from scratch
        assert len(out.history) < out.supersteps


def test_crash_resume_ooc_vstate_and_final_skip(stores, tmp_path):
    """Ooc vertex state round-trips through interval-block checkpoints
    (budget-portable: resume uses a different budget), and resuming a
    *finished* run short-circuits to the stored result."""
    root = stores[0]
    prog = lambda: PersonalizedPageRank(seeds=(1, 7, 50))  # noqa: E731
    ref = _run(root, prog(), vertex_memory_budget=2000)
    ck = str(tmp_path / "ooc")
    plan = FaultPlan(specs=(FaultSpec(site="barrier", superstep=5),))
    with pytest.raises(InjectedFault):
        _run(root, prog(), vertex_memory_budget=2000, checkpoint_dir=ck,
             checkpoint_every=2, fault_plan=plan)
    # blocks/ payloads exist in the boundary checkpoint
    steps = sorted(glob.glob(ck + "/step_*"))
    assert steps and os.path.isdir(os.path.join(steps[0], "blocks"))
    out = _run(root, prog(), vertex_memory_budget=4000, checkpoint_dir=ck,
               resume=True)
    assert np.array_equal(out.values, ref.values)
    assert np.array_equal(out.per_query_supersteps, ref.per_query_supersteps)
    # final checkpoint: a second resume returns the stored result directly
    again = _run(root, prog(), vertex_memory_budget=2000, checkpoint_dir=ck,
                 resume=True)
    assert np.array_equal(again.values, ref.values)
    assert again.supersteps == ref.supersteps
    assert again.history == []


def test_preemption_saves_and_resumes(stores, tmp_path):
    """SIGTERM (via the preempt fault kind) => checkpoint at the next
    barrier + Preempted; the handlers are restored and the resumed run is
    bit-identical."""
    root = stores[0]
    ref = _run(root, PageRank(), n=1)
    ck = str(tmp_path / "preempt")
    plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=4,
                                      kind="preempt"),))
    before = signal.getsignal(signal.SIGTERM)
    with pytest.raises(Preempted) as ei:
        _run(root, PageRank(), n=1, checkpoint_dir=ck, preemptible=True,
             fault_plan=plan)
    assert ei.value.superstep == 5
    assert signal.getsignal(signal.SIGTERM) is before
    out = _run(root, PageRank(), n=1, checkpoint_dir=ck, resume=True)
    assert np.array_equal(out.values, ref.values)
    assert out.supersteps == ref.supersteps


def test_resume_with_different_server_count(stores, tmp_path):
    """Elastic N->M at the superstep boundary: checkpoint under emulated
    N=4, resume under N=3 and N=5 — both bit-identical (replication means
    no data handoff, only an assignment remap)."""
    root = stores[1]
    ref = _run(root, SSSP(source=0), n=4)
    ck = str(tmp_path / "resize")
    plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=4),))
    with pytest.raises(InjectedFault):
        _run(root, SSSP(source=0), n=4, checkpoint_dir=ck,
             checkpoint_every=2, fault_plan=plan)
    import shutil

    for m in (3, 5):
        # fresh copy per resize: the resumed run writes its own final
        # checkpoint, which would short-circuit the next resume
        ck_m = str(tmp_path / f"resize_{m}")
        shutil.copytree(ck, ck_m)
        res = OutOfCoreEngine(TileStore(root), EngineConfig(
            num_servers=m, max_supersteps=SS, checkpoint_dir=ck_m,
            resume=True, checkpoint_every=0))
        # the resize really adopted a remapped M-way assignment
        assert len(res.assignment) == m
        assert sorted(t for a in res.assignment for t in a) == \
            list(range(res.plan.num_tiles))
        got = res.run(SSSP(source=0))
        assert np.array_equal(got.values, ref.values), m
        assert got.supersteps == ref.supersteps


# ---------------------------------------------------------------------------
# GraphCheckpointer: hardlink-incremental blocks, collision-safe publish
# ---------------------------------------------------------------------------

def _small_vstore():
    vs = VertexStateStore(np.array([0, 4, 8, 12]))
    vs.add_array("value", np.arange(12, dtype=np.float32))
    vs.add_array("deg", np.ones((12, 2), dtype=np.int32))
    return vs


def test_graph_checkpointer_hardlinks_unchanged_blocks(tmp_path):
    ck = GraphCheckpointer(str(tmp_path))
    vs = _small_vstore()
    d1 = ck.save_graph(1, {"updated_ids": np.arange(3)},
                       {"superstep": 1, "assignment": [[0]]}, vstore=vs)
    # dirty exactly one block; the rest must hardlink to the step-1 copies
    vs.write_block("value", 1, np.full(4, 7.0, np.float32))
    d2 = ck.save_graph(2, {"updated_ids": np.arange(3)},
                       {"superstep": 2, "assignment": [[0]]}, vstore=vs)
    changed = os.path.join(d2, "blocks", "value.1.blk")
    unchanged = os.path.join(d2, "blocks", "value.0.blk")
    assert os.stat(unchanged).st_ino == \
        os.stat(os.path.join(d1, "blocks", "value.0.blk")).st_ino
    assert os.stat(changed).st_ino != \
        os.stat(os.path.join(d1, "blocks", "value.1.blk")).st_ino
    # loader reassembles the mutated state exactly
    got = ck.load_graph(2)
    np.testing.assert_array_equal(
        got.vstate["value"],
        np.concatenate([np.arange(4), np.full(4, 7.0),
                        np.arange(8, 12)]).astype(np.float32))
    np.testing.assert_array_equal(got.vstate["deg"],
                                  np.ones((12, 2), np.int32))
    assert got.manifest["superstep"] == 2


def test_graph_checkpointer_first_publish_wins(tmp_path):
    """Two ranks saving the same superstep (preemption race): replicated
    state makes the copies identical, so the loser silently discards."""
    a = GraphCheckpointer(str(tmp_path))
    b = GraphCheckpointer(str(tmp_path))
    st = {"values": np.arange(5.0)}
    man = {"superstep": 3, "assignment": [[0], [1]]}
    a.save_graph(3, st, man)
    b.save_graph(3, st, man)            # loses the publish, must not raise
    assert a.all_steps() == [3]
    assert not glob.glob(str(tmp_path) + "/*.tmp.*")
    got = b.load_graph()
    np.testing.assert_array_equal(got.state["values"], np.arange(5.0))
    assert got.manifest["kind"] == "graphh-superstep"


def test_peek_manifest_empty_and_populated(tmp_path):
    ck = GraphCheckpointer(str(tmp_path))
    assert ck.peek_manifest() is None
    assert ck.load_graph() is None
    ck.save_graph(4, {"values": np.zeros(2)},
                  {"superstep": 4, "assignment": [[0, 1]]})
    step, man = ck.peek_manifest()
    assert step == 4 and man["assignment"] == [[0, 1]]


# ---------------------------------------------------------------------------
# Crash-atomicity: a reader never observes a torn graph checkpoint
# ---------------------------------------------------------------------------

GRAPH_SITES = ["ckpt.mid_write", "ckpt.leaf", "ckpt.block",
               "ckpt.pre_rename", "ckpt.latest", "ckpt.pre_latest"]


@settings(max_examples=24)
@given(st.sampled_from(GRAPH_SITES), st.integers(0, 64),
       st.sampled_from(["raise", "torn_write"]))
def test_graph_checkpoint_crash_atomicity(site, keep_bytes, kind):
    """Kill the writer at any staged-write/rename/pointer site — with the
    write torn at an arbitrary byte — and the reader still sees the
    previous complete checkpoint, bit-exact."""
    if kind == "torn_write" and site in ("ckpt.mid_write", "ckpt.pre_rename",
                                         "ckpt.pre_latest"):
        return       # pure check() sites: nothing is mid-write there
    with tempfile.TemporaryDirectory() as d:
        base = GraphCheckpointer(d)
        vs = _small_vstore()
        state = {"updated_ids": np.arange(5), "x": np.eye(3)}
        man = {"superstep": 2, "assignment": [[0], [1]]}
        base.save_graph(2, state, man, vstore=vs)

        plan = FaultPlan(specs=(FaultSpec(
            site=site, kind=kind, keep_bytes=keep_bytes, superstep=4),))
        wr = GraphCheckpointer(d, fault=plan.injector())
        vs.write_block("value", 0, np.full(4, 9.0, np.float32))
        try:
            wr.save_graph(4, state, {"superstep": 4, "assignment": [[0, 1]]},
                          vstore=vs)
            crashed = False
        except InjectedFault:
            crashed = True
        rd = GraphCheckpointer(d)
        got = rd.load_graph()
        assert got is not None
        if crashed and site not in ("ckpt.latest", "ckpt.pre_latest"):
            # the new step never published: reader sees the old one whole
            assert got.step == 2
            assert got.manifest["superstep"] == 2
            np.testing.assert_array_equal(got.vstate["value"],
                                          np.arange(12, dtype=np.float32))
        else:
            # published (crash only lost/tore the LATEST pointer update,
            # which os.replace keeps atomic) — either step loads cleanly
            assert got.step in (2, 4)
            assert got.manifest["superstep"] == got.step
        np.testing.assert_array_equal(got.state["x"], np.eye(3))


def test_latest_pointer_crash_leaves_prior_resumable(tmp_path):
    """Specifically: die between publishing step K and updating LATEST —
    recovery resumes from the pointer's (older, fully committed) step."""
    base = GraphCheckpointer(str(tmp_path))
    base.save_graph(2, {"v": np.arange(3.0)}, {"superstep": 2,
                                               "assignment": [[0]]})
    plan = FaultPlan(specs=(FaultSpec(site="ckpt.pre_latest",
                                      superstep=4),))
    wr = GraphCheckpointer(str(tmp_path), fault=plan.injector())
    with pytest.raises(InjectedFault):
        wr.save_graph(4, {"v": np.arange(3.0) * 2}, {"superstep": 4,
                                                     "assignment": [[0]]})
    with open(str(tmp_path / "LATEST")) as f:
        assert int(f.read()) == 2
    rd = GraphCheckpointer(str(tmp_path))
    assert rd.latest_step() == 2        # pointer wins: last committed
    assert sorted(rd.all_steps()) == [2, 4]


# ---------------------------------------------------------------------------
# Elastic remap + handoff accounting properties (satellite 4)
# ---------------------------------------------------------------------------

@settings(max_examples=30)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 40))
def test_remap_assignment_properties(old_n, new_n, num_tiles):
    """Every tile owned exactly once after any N->M remap; on shrink the
    survivors keep all their tiles (warmth preservation); deterministic."""
    rng = np.random.default_rng(old_n * 1000 + new_n * 40 + num_tiles)
    edges = rng.integers(1, 100, num_tiles)
    owner = rng.integers(0, old_n, num_tiles)
    old = [sorted(np.flatnonzero(owner == s).tolist())
           for s in range(old_n)]
    new = remap_assignment(old, new_n, edges)
    assert len(new) == new_n
    flat = sorted(t for a in new for t in a)
    assert flat == list(range(num_tiles))           # no tile lost or doubled
    for s in range(min(old_n, new_n)):
        assert set(old[s]) <= set(new[s]) or new_n > old_n
    assert remap_assignment(old, new_n, edges) == new


@settings(max_examples=30)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 40))
def test_handoff_plan_accounting(old_n, new_n, num_tiles):
    """Handoff bytes equal the sum over moved tiles, split per destination;
    unmoved tiles contribute nothing."""
    rng = np.random.default_rng(old_n + 7 * new_n + 13 * num_tiles)
    tile_bytes = rng.integers(1, 1000, num_tiles)
    edges = rng.integers(1, 100, num_tiles)
    owner = rng.integers(0, old_n, num_tiles)
    old = [sorted(np.flatnonzero(owner == s).tolist()) for s in range(old_n)]
    new = remap_assignment(old, new_n, edges)
    plan = handoff_plan(old, new, tile_bytes)
    moved = {t for t, _s, _d in plan["moves"]}
    stayed = set(range(num_tiles)) - moved
    src = {t: s for s, ts in enumerate(old) for t in ts}
    dst = {t: s for s, ts in enumerate(new) for t in ts}
    for t in stayed:
        assert src[t] == dst[t]
    for t, s, d in plan["moves"]:
        assert src.get(t, -1) == s and dst[t] == d and s != d
    assert plan["bytes"] == sum(int(tile_bytes[t]) for t in moved)
    assert plan["bytes"] == sum(plan["per_dst_bytes"].values())


def test_remap_4_to_3_and_2_to_5_non_divisible():
    """The two drills named in DESIGN.md §12: non-divisible shrink and
    growth keep the partition exact and survivors warm."""
    edges = np.arange(1, 14)[::-1]      # 13 tiles, uneven weights
    old4 = [[0, 4, 8, 12], [1, 5, 9], [2, 6, 10], [3, 7, 11]]
    new3 = remap_assignment(old4, 3, edges)
    assert sorted(t for a in new3 for t in a) == list(range(13))
    for s in range(3):
        assert set(old4[s]) <= set(new3[s])
    old2 = [[0, 2, 4, 6, 8, 10, 12], [1, 3, 5, 7, 9, 11]]
    new5 = remap_assignment(old2, 5, edges)
    assert sorted(t for a in new5 for t in a) == list(range(13))
    assert all(len(a) > 0 for a in new5)        # growth absorbed work
    plan = handoff_plan(old2, new5, np.full(13, 10))
    assert plan["bytes"] == 10 * len({t for t, _, _ in plan["moves"]})


# ---------------------------------------------------------------------------
# runtime.ft: handler restoration regression (satellite 1)
# ---------------------------------------------------------------------------

def test_ftloop_context_manager_restores_handlers_on_raise(tmp_path):
    """The regression: FaultTolerantLoop used to leak its SIGTERM/SIGINT
    handlers when the training body raised, redirecting a later job's
    signals into a dead object."""
    from repro.train.checkpoint import CheckpointManager

    def marker(signum, frame):  # pragma: no cover - never delivered
        pass

    prev_term = signal.signal(signal.SIGTERM, marker)
    prev_int = signal.getsignal(signal.SIGINT)
    try:
        with pytest.raises(RuntimeError, match="boom"):
            with FaultTolerantLoop(CheckpointManager(str(tmp_path))) as ft:
                assert not ft.preempted
                raise RuntimeError("boom")
        assert signal.getsignal(signal.SIGTERM) is marker
        assert signal.getsignal(signal.SIGINT) is prev_int
    finally:
        signal.signal(signal.SIGTERM, prev_term)


def test_ftloop_bare_construction_still_works(tmp_path):
    from repro.train.checkpoint import CheckpointManager

    prev = signal.getsignal(signal.SIGTERM)
    ft = FaultTolerantLoop(CheckpointManager(str(tmp_path)), save_every=1)
    assert signal.getsignal(signal.SIGTERM) is not prev
    ft.restore_handlers()
    assert signal.getsignal(signal.SIGTERM) is prev
    ft.restore_handlers()               # idempotent
