"""Multi-query GAB (DESIGN.md §9): differential battery + retirement.

The contract under test: a Q-query batched run is *bit-identical*, column
for column, to Q independent single-query runs — across engine modes
(serial/pipelined, looped/stacked), all three cache policies, and both
segment-reduce implementations — while streaming each tile once per
superstep regardless of Q (the ~Qx I/O amortization that motivates the
whole layer), and retiring converged query columns so late stragglers
stop paying for finished queries.
"""
import numpy as np
import pytest

from repro.core.apps import (LandmarkDistances, MultiSourceBFS, PageRank,
                             PersonalizedPageRank)
from repro.core.engine import EngineConfig, OutOfCoreEngine

SEEDS = (0, 5, 17, 111)


def run(store, prog, servers=3, **kw):
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=servers,
                                              max_supersteps=200, **kw))
    return eng.run(prog)


@pytest.fixture(scope="module")
def weighted_store(small_graph, tmp_path_factory):
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path_factory.mktemp("wstore")))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=100)
    return store


@pytest.fixture(scope="module")
def solo_ppr(small_store):
    store, _, _ = small_store
    return {s: run(store, PersonalizedPageRank(seeds=(s,))) for s in SEEDS}


@pytest.fixture(scope="module")
def solo_msbfs(small_store):
    store, _, _ = small_store
    return {s: run(store, MultiSourceBFS(sources=(s,))) for s in SEEDS}


# ---------------------------------------------------------------------------
# differential battery: batched == Q independent runs, bit for bit
# ---------------------------------------------------------------------------

def test_ppr_batched_bit_identical_to_solo(small_store, solo_ppr):
    store, _, _ = small_store
    rb = run(store, PersonalizedPageRank(seeds=SEEDS))
    assert rb.converged
    assert rb.values.shape == (store.load_plan().num_vertices, len(SEEDS))
    for q, s in enumerate(SEEDS):
        np.testing.assert_array_equal(rb.values[:, q], solo_ppr[s].values[:, 0])
        # a column retires exactly when its solo run would converge
        assert rb.per_query_supersteps[q] == solo_ppr[s].supersteps


def test_msbfs_batched_bit_identical_to_solo(small_store, solo_msbfs):
    store, _, _ = small_store
    rb = run(store, MultiSourceBFS(sources=SEEDS))
    assert rb.converged
    for q, s in enumerate(SEEDS):
        np.testing.assert_array_equal(rb.values[:, q],
                                      solo_msbfs[s].values[:, 0])
        assert rb.per_query_supersteps[q] == solo_msbfs[s].supersteps


def test_landmark_sssp_batched_bit_identical_to_solo(weighted_store):
    rb = run(weighted_store, LandmarkDistances(landmarks=SEEDS))
    assert rb.converged
    for q, s in enumerate(SEEDS):
        rs = run(weighted_store, LandmarkDistances(landmarks=(s,)))
        np.testing.assert_array_equal(rb.values[:, q], rs.values[:, 0])


@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("engine_mode", ["tiled", "stacked"])
@pytest.mark.parametrize("cache_policy", ["lru", "tiered", "cost-aware"])
def test_mode_matrix_bit_identical(small_store, solo_msbfs, pipeline,
                                   engine_mode, cache_policy):
    """Serial/pipelined x looped/stacked x all cache policies must all
    produce the exact solo results per column."""
    store, _, _ = small_store
    rb = run(store, MultiSourceBFS(sources=SEEDS), pipeline=pipeline,
             engine_mode=engine_mode, cache_policy=cache_policy)
    for q, s in enumerate(SEEDS):
        np.testing.assert_array_equal(rb.values[:, q],
                                      solo_msbfs[s].values[:, 0])


@pytest.mark.parametrize("skip_filter", ["bitmap", "bloom"])
def test_tile_skipping_with_batched_queries(weighted_store, skip_filter):
    """Tile skipping keys on the *union* of active vertices across live
    query columns — results must match a no-skip run exactly, and tiles
    must actually be skipped once the joint frontier thins."""
    prog = LandmarkDistances(landmarks=SEEDS)
    r_skip = run(weighted_store, prog, tile_skipping=True,
                 skip_density_threshold=0.9, block_shift=2,
                 skip_filter=skip_filter)
    r_ref = run(weighted_store, LandmarkDistances(landmarks=SEEDS),
                tile_skipping=False)
    np.testing.assert_array_equal(r_skip.values, r_ref.values)
    if skip_filter == "bloom":
        # 2^16 bits over 300 vertices is near-exact per-vertex membership,
        # so the thinning multi-query frontier must skip something; the
        # 4-vertex-block bitmap is coarser and may legitimately skip nothing
        # against a 4-query union frontier
        assert sum(h.tiles_skipped for h in r_skip.history) > 0


def test_pallas_seg_impl_matches_jnp(small_store, weighted_store):
    """Both monoids through the Pallas kernels at Q>1: sum (MXU one-hot
    GEMM, PPR) and min (masked VPU reduction, landmark distances)."""
    store, _, _ = small_store
    a = run(store, PersonalizedPageRank(seeds=SEEDS), seg_impl="pallas_onehot")
    b = run(store, PersonalizedPageRank(seeds=SEEDS), seg_impl="jnp")
    np.testing.assert_array_equal(a.values, b.values)
    c = run(weighted_store, LandmarkDistances(landmarks=SEEDS),
            seg_impl="pallas_onehot")
    d = run(weighted_store, LandmarkDistances(landmarks=SEEDS), seg_impl="jnp")
    np.testing.assert_array_equal(c.values, d.values)


# ---------------------------------------------------------------------------
# I/O amortization: one edge pass serves all Q queries
# ---------------------------------------------------------------------------

def test_q32_ppr_streams_tiles_once(small_store):
    """Acceptance: a Q=32 PPR batch must stream each tile once per
    superstep — io_bytes within 5% of a single-query run (i.e. ~32x
    amortization vs 32 independent runs) — with per-query results
    bit-identical to the corresponding single-query runs."""
    store, plan, _ = small_store
    rng = np.random.default_rng(0)
    seeds = tuple(int(v) for v in rng.choice(plan.num_vertices, 32,
                                             replace=False))
    # 1-byte cache: every tile visit is a real disk read, so disk_bytes_read
    # counts tile streaming exactly
    kw = dict(cache_capacity_bytes=1, tile_skipping=False)
    rb = run(store, PersonalizedPageRank(seeds=seeds), **kw)
    assert rb.converged

    # the batch runs as long as its slowest query; compare tile I/O against
    # that query's solo run
    slowest = int(np.argmax(rb.per_query_supersteps))
    rs = run(store, PersonalizedPageRank(seeds=(seeds[slowest],)), **kw)
    io_b = sum(h.disk_bytes_read for h in rb.history)
    io_s = sum(h.disk_bytes_read for h in rs.history)
    assert abs(io_b - io_s) <= 0.05 * io_s, (io_b, io_s)

    np.testing.assert_array_equal(rb.values[:, slowest], rs.values[:, 0])
    for q in (0, 7, 19, 31):   # spot-check more columns
        r1 = run(store, PersonalizedPageRank(seeds=(seeds[q],)), **kw)
        np.testing.assert_array_equal(rb.values[:, q], r1.values[:, 0])
        assert rb.per_query_supersteps[q] == r1.supersteps


# ---------------------------------------------------------------------------
# query retirement: converged columns leave compute, broadcast, accounting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chain_store(tmp_path_factory):
    """A 50-vertex path 0->1->...->40 plus isolated vertices 41..49: BFS
    from 0 needs 40 supersteps, BFS from the isolated 45 converges
    immediately."""
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv = 50
    src = np.arange(0, 40)
    dst = np.arange(1, 41)
    store = TileStore(str(tmp_path_factory.mktemp("chain")))
    spe.preprocess_arrays(src, dst, None, nv, store, tile_size=16)
    return store, nv


def test_query_retirement_excludes_converged_columns(chain_store):
    store, nv = chain_store
    rb = run(store, MultiSourceBFS(sources=(0, 45)), servers=2)
    assert rb.converged
    # the isolated-source query produces zero updates in superstep 0 and
    # retires there; the chain query runs on alone
    assert rb.history[0].active_queries == 2
    assert rb.history[0].retired_queries == (1,)
    assert rb.history[0].updated_per_query[1] == 0
    assert rb.per_query_supersteps[1] == 1
    for h in rb.history[1:]:
        assert h.active_queries == 1
        assert set(h.updated_per_query) == {0}
        assert h.retired_queries in ((), (0,))
        assert h.updated_pairs == h.updated_vertices  # one live column

    # after retirement the broadcast payload must be byte-identical to a
    # run that never had the retired query at all
    rs = run(store, MultiSourceBFS(sources=(0,)), servers=2)
    assert rs.supersteps == rb.supersteps
    for hb, hs in zip(rb.history[1:], rs.history[1:]):
        assert hb.raw_bytes == hs.raw_bytes
        assert hb.wire_bytes == hs.wire_bytes

    np.testing.assert_array_equal(rb.values[:, 0], rs.values[:, 0])
    assert rb.values[45, 1] == 0.0 and np.isinf(rb.values[0, 1])

    # dense comm ships whole columns: while both queries are live the
    # payload is strictly larger, and drops to the solo size the superstep
    # after retirement
    rbd = run(store, MultiSourceBFS(sources=(0, 45)), servers=2,
              comm_mode="dense")
    rsd = run(store, MultiSourceBFS(sources=(0,)), servers=2,
              comm_mode="dense")
    assert rbd.history[0].raw_bytes > rsd.history[0].raw_bytes
    for hb, hs in zip(rbd.history[1:], rsd.history[1:]):
        assert hb.raw_bytes == hs.raw_bytes


def test_single_query_stats_unchanged(small_store):
    """Classic 1-D programs keep their stats semantics."""
    store, _, _ = small_store
    r = run(store, PageRank(update_tol=1e-10))
    for h in r.history:
        assert h.active_queries == 1
        assert h.updated_pairs == h.updated_vertices
        assert h.updated_per_query == {}
        assert h.retired_queries == ()
    assert r.per_query_supersteps is None


# ---------------------------------------------------------------------------
# 2-D broadcast payloads (host accounting + device collectives)
# ---------------------------------------------------------------------------

def test_multi_query_payload_accounting():
    from repro.core import comm

    nv, nq = 256, 3
    values = np.arange(nv * nq, dtype=np.float32).reshape(nv, nq)
    updated = np.zeros((nv, nq), dtype=bool)
    updated[:, 0] = True           # dense column (density 1.0)
    updated[:10, 1] = True         # sparse column (10 updates)
    # column 2: converged — no updates at all
    rec = comm.plan_broadcast(values, updated, compressor="none")
    assert rec.mode == "mixed"
    assert rec.query_modes == ("dense", "sparse", "sparse")
    # dense col: ceil(V/8) bitvector + V f32; sparse cols: 10 pairs of
    # (uint32 vertex, uint32 query) + 10 f32 values, zero for column 2
    want = ((nv + 7) // 8 + 4 * nv) + 10 * (8 + 4)
    assert rec.raw_bytes == want
    assert rec.wire_bytes == want  # compressor "none"

    dense = comm.plan_broadcast(values, updated, compressor="none",
                                mode="dense")
    assert dense.query_modes == ("dense",) * 3
    assert dense.raw_bytes == 3 * ((nv + 7) // 8 + 4 * nv)
    sparse = comm.plan_broadcast(values, updated, compressor="none",
                                 mode="sparse")
    assert sparse.query_modes == ("sparse",) * 3
    assert sparse.raw_bytes == (nv + 10) * (8 + 4)


def test_sampled_accounting_multi_query(small_store, solo_msbfs):
    """comm_accounting="sampled" must stay bit-identical and estimate
    2-D sparse payloads at 12 bytes/cell ((u32, u32) pair + f32), not the
    1-D 8 bytes/update."""
    from repro.core import comm

    store, _, _ = small_store
    rb = run(store, MultiSourceBFS(sources=SEEDS), comm_accounting="sampled")
    for q, s in enumerate(SEEDS):
        np.testing.assert_array_equal(rb.values[:, q],
                                      solo_msbfs[s].values[:, 0])
    # unit check of the pair-overhead estimate
    assert comm.wire_bytes_estimate(1000, 0.01, index_bytes=8) == 10 * 12
    assert comm.wire_bytes_estimate(1000, 0.01) == 10 * 8


def test_hybrid_broadcast_2d_single_host():
    """Device-side 2-D broadcast on a 1-shard mesh: flatten to (vertex,
    query) cells, results must round-trip exactly for every mode."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map_unchecked
    from repro.core import comm

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    rng = np.random.default_rng(0)
    nv, nq = 64, 4
    old = rng.normal(size=(nv, nq)).astype(np.float32)
    upd = rng.random((nv, nq)) < 0.1
    new = np.where(upd, rng.normal(size=(nv, nq)).astype(np.float32), 0.0)
    want = np.where(upd, new, old)

    rep = P()
    for mode in ("dense", "sparse", "hybrid"):
        fn = shard_map_unchecked(
            lambda o, m, u: comm.hybrid_broadcast(o, m, u, "x", mode=mode)[0],
            mesh=mesh, in_specs=(rep, rep, rep), out_specs=rep)
        got = np.asarray(fn(old, new, upd))
        np.testing.assert_array_equal(got, want, err_msg=mode)
