"""Adaptive multi-tier edge cache (DESIGN.md §8): tier transitions,
byte-accounting invariants (property-style), warm() admission control, and
engine equivalence with the tiered policies enabled."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.cache import TIER_LADDER, EdgeCache
from repro.graphio import formats

# The property tests can't take pytest fixtures (the hypothesis fallback
# shim's wrapper hides the signature), so they share one module-level store.
_PROP_STORE = None


def _prop_store():
    global _PROP_STORE
    if _PROP_STORE is None:
        import tempfile

        from repro.graphio import spe
        from repro.graphio.formats import TileStore

        rng = np.random.default_rng(11)
        nv, ne = 200, 1200
        src = rng.integers(0, nv, ne)
        dst = rng.integers(0, nv, ne)
        key = src * nv + dst
        _, idx = np.unique(key, return_index=True)
        store = TileStore(tempfile.mkdtemp(prefix="cache_prop_"))
        plan = spe.preprocess_arrays(src[idx], dst[idx], None, nv, store,
                                     tile_size=64)
        _PROP_STORE = (store, plan)
    return _PROP_STORE


def _warm_blob_size(store, tile_id=0):
    """Size of a tile's blob at the tiered admission mode (warm, zstd-1)."""
    raw = formats.decompress_blob(store.read_tile_blob(tile_id),
                                  store.disk_mode)
    return len(formats.compress_blob(raw, TIER_LADDER[1]))


# --------------------------- tier transitions ------------------------------

def test_unknown_policy_rejected(small_store):
    store, _, _ = small_store
    with pytest.raises(ValueError, match="policy"):
        EdgeCache(store, 1 << 20, policy="mru")


def test_admission_lands_in_warm_tier(small_store):
    store, _, _ = small_store
    cache = EdgeCache(store, 1 << 30, policy="tiered")
    cache.get(0)
    snap = cache.tier_snapshot()
    assert snap["warm"]["tiles"] == 1
    assert "hot" not in snap or snap["hot"]["tiles"] == 0


def test_repeated_hits_promote_to_hot(small_store):
    store, _, _ = small_store
    cache = EdgeCache(store, 1 << 30, policy="tiered", promote_hits=2)
    cache.get(0)            # miss -> warm
    cache.get(0)            # hit 1: below promote threshold
    assert cache.tier_snapshot()["warm"]["tiles"] == 1
    cache.get(0)            # hit 2: promoted warm -> hot
    snap = cache.tier_snapshot()
    assert snap["hot"]["tiles"] == 1
    assert cache.stats.promotions == 1
    # hot entries decode without a codec pass; content identical
    t = cache.get(0)
    np.testing.assert_array_equal(t.src, store.read_tile(0).src)


def test_pressure_demotes_reused_tiles_instead_of_evicting(small_store):
    """Tiles with demonstrated reuse are demoted (kept, compressed colder)
    under pressure, never evicted while zero-reuse churn is around; the
    streaming tiles are the ones that get evicted."""
    store, plan, _ = small_store
    reused = (0, 1, 2)
    cap = sum(_warm_blob_size(store, t) for t in reused) + 64
    tiered = EdgeCache(store, cap, policy="tiered", promote_hits=100)
    for t in reused:
        tiered.get(t)
    for t in reused:
        tiered.get(t)           # reuse: these earn demote-not-evict
    for t in range(3, min(12, plan.num_tiles)):
        tiered.get(t)           # streaming churn under full cache
    assert tiered.stats.demotions > 0      # reused tiles were recompressed,
    assert tiered.stats.evictions > 0      # the zero-reuse stream evicted
    assert tiered.resident_bytes() <= cap
    # reused tiles outlive the streaming churn (demoted colder, evicted only
    # once already cold and no zero-reuse victim remains)
    assert any(tiered.contains(t) for t in reused)
    assert tiered.tier_snapshot().get("cold", {}).get("tiles", 0) > 0


def test_streaming_scan_evicts_without_recompress(small_store):
    """A pure streaming scan (no tile ever re-hit) must not pay demotion
    codec work — zero-reuse entries are evicted directly."""
    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    cache = EdgeCache(store, sum(sizes[:3]) // 2, policy="tiered")
    for t in range(plan.num_tiles):
        cache.get(t)
    assert cache.stats.demotions == 0
    assert cache.stats.evictions > 0
    assert cache.resident_bytes() <= cache.capacity_bytes


def test_promotion_suppressed_under_pressure_resumes_on_resize(small_store):
    """Hit credit accumulates while capacity is tight; growing the budget
    (memory pressure change) lets maintain()/resize() promote."""
    store, _, _ = small_store
    w = _warm_blob_size(store)
    cache = EdgeCache(store, int(w * 1.2), policy="tiered", promote_hits=2)
    for _ in range(5):
        cache.get(0)        # pressure ~0.83 > watermark: no inline promotion
    assert cache.stats.promotions == 0
    assert cache.tier_snapshot()["warm"]["tiles"] == 1
    out = cache.resize(1 << 30)
    assert out["promoted"] == 1
    assert cache.tier_snapshot()["hot"]["tiles"] == 1


def test_resize_shrink_walks_demote_ladder(small_store):
    store, plan, _ = small_store
    cache = EdgeCache(store, 1 << 30, policy="tiered", promote_hits=100)
    for t in range(plan.num_tiles):
        cache.get(t)
    for t in range(plan.num_tiles):
        cache.get(t)            # reuse: shrink must demote, not just evict
    before = sum(d["tiles"] for d in cache.tier_snapshot().values())
    w = _warm_blob_size(store)
    cache.resize(3 * w)
    assert cache.resident_bytes() <= 3 * w
    assert cache.stats.demotions > 0
    assert sum(d["tiles"] for d in cache.tier_snapshot().values()) <= before


def test_maintain_predemotes_at_high_pressure(small_store):
    store, _, _ = small_store
    need = sum(_warm_blob_size(store, t) for t in (0, 1, 2))
    cache = EdgeCache(store, need + 8, policy="tiered", promote_hits=100)
    assert cache.warm([0, 1, 2]) == 3       # pressure ~0.99
    for t in (0, 1, 2):
        cache.get(t)        # reused: eligible for pre-demotion
    out = cache.maintain()
    assert out["demoted"] >= 1
    assert cache.tier_snapshot().get("cold", {}).get("tiles", 0) >= 1


def test_cost_aware_keeps_high_value_tile(small_store):
    """The cost-aware victim is the least decompress-seconds-saved per
    byte; a heavily reused tile must survive a streaming scan."""
    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    # promote_hits high: tile 0 stays warm (small blob), so its
    # decompress-seconds-saved per byte dwarfs the single-use tiles'
    cache = EdgeCache(store, sum(sizes[:3]), policy="cost-aware",
                      promote_hits=100)
    for _ in range(10):
        cache.get(0)                        # tile 0: high reuse
    for t in range(1, plan.num_tiles):      # streaming churn
        cache.get(t)
    assert cache.contains(0)


def test_background_retier_thread_starts_and_stops(small_store):
    store, _, _ = small_store
    cache = EdgeCache(store, 1 << 30, policy="tiered")
    cache.get(0)
    cache.start_background(interval_s=0.01)
    try:
        import time
        time.sleep(0.05)
    finally:
        cache.stop_background()
    assert cache._bg_thread is None


# --------------------------- warm() admission control ----------------------

def test_warm_stops_at_capacity_no_thrash(small_store):
    """Warming a working set larger than capacity must stop instead of
    LRU-thrashing: no evictions, and the first tiles stay resident."""
    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    cache = EdgeCache(store, sum(sizes[:2]) + 32, mode=1)
    admitted = cache.warm(range(plan.num_tiles))
    assert admitted == 2
    assert cache.stats.evictions == 0
    assert cache.contains(0) and cache.contains(1)
    assert cache.resident_bytes() <= cache.capacity_bytes
    # the admitted prefix now hits
    h0 = cache.stats.hits
    cache.get(0)
    assert cache.stats.hits == h0 + 1


def test_warm_counts_resident_tiles(small_store):
    store, plan, _ = small_store
    cache = EdgeCache(store, 1 << 30, mode=2)
    assert cache.warm(range(plan.num_tiles)) == plan.num_tiles
    # warming again is all hits, nothing re-read
    b0 = store.bytes_read
    assert cache.warm(range(plan.num_tiles)) == plan.num_tiles
    assert store.bytes_read == b0


# --------------------------- accounting invariants -------------------------

@given(st.sampled_from(["lru", "tiered", "cost-aware"]),
       st.integers(2, 6),
       st.lists(st.integers(0, 3 * 8 - 1), min_size=1, max_size=40))
@settings(max_examples=12, deadline=None)
def test_cache_accounting_invariants(policy, cap_tiles, ops):
    """After ANY get/warm/maintain sequence: resident_bytes() <=
    capacity_bytes, resident bytes match the tier snapshot exactly, and
    hits + misses == number of lookups performed."""
    store, plan = _prop_store()
    P = plan.num_tiles
    sizes = [store.tile_disk_bytes(t) for t in range(P)]
    cache = EdgeCache(store, cap_tiles * (sum(sizes) // P), policy=policy)
    lookups = 0
    for op in ops:
        kind, tid = divmod(op, 8)
        tid = tid % P
        if kind == 0:
            cache.get(tid)
            lookups += 1
        elif kind == 1:
            cache.warm([tid])      # single tile: exactly one lookup
            lookups += 1
        else:
            cache.maintain()
        assert cache.resident_bytes() <= cache.capacity_bytes
        snap_bytes = sum(d.get("bytes", 0)
                         for d in cache.tier_snapshot().values())
        assert snap_bytes == cache.resident_bytes()
        assert cache.stats.hits + cache.stats.misses == lookups


@given(st.sampled_from(["tiered", "cost-aware"]),
       st.lists(st.integers(0, 7), min_size=4, max_size=24))
@settings(max_examples=8, deadline=None)
def test_retier_preserves_content_and_budget(policy, ops):
    """Promotion/demotion churn never corrupts a tile or the byte budget."""
    store, plan = _prop_store()
    P = plan.num_tiles
    sizes = [store.tile_disk_bytes(t) for t in range(P)]
    cache = EdgeCache(store, sum(sizes[:3]), policy=policy, promote_hits=1)
    for tid in ops:
        t = cache.get(tid % P)
        ref = store.read_tile(tid % P)
        np.testing.assert_array_equal(t.src, ref.src)
        np.testing.assert_array_equal(t.dst_local, ref.dst_local)
        assert cache.resident_bytes() <= cache.capacity_bytes
    cache.maintain()
    assert cache.resident_bytes() <= cache.capacity_bytes


# --------------------------- engine equivalence ----------------------------

def _engine_run(store, prog, **kw):
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    kw.setdefault("max_supersteps", 200)
    cfg = EngineConfig(num_servers=3, **kw)
    return OutOfCoreEngine(store, cfg).run(prog)


@pytest.mark.parametrize("policy", ["tiered", "cost-aware"])
def test_tiered_engine_bit_identical_pagerank_wcc(small_store, policy):
    from repro.core.apps import WCC, PageRank

    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    cap = sum(sizes) // 3     # eviction/demotion pressure every superstep
    for prog_factory in (lambda: PageRank(update_tol=1e-10), WCC):
        ref = _engine_run(store, prog_factory())
        res = _engine_run(store, prog_factory(), cache_policy=policy,
                          cache_capacity_bytes=cap)
        assert ref.supersteps == res.supersteps
        assert np.array_equal(ref.values, res.values)


def test_tiered_engine_bit_identical_sssp_pipelined(tmp_path, small_graph):
    from repro.core.apps import SSSP
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=100)
    ref = _engine_run(store, SSSP(source=0))
    res = _engine_run(store, SSSP(source=0), cache_policy="tiered",
                      pipeline=True, prefetch_depth=3, prefetch_workers=2,
                      stack_size=2)
    assert np.array_equal(ref.values, res.values)


def test_cache_aware_order_resident_first(small_store):
    """Cache-hit-first scheduling: resident tiles lead the visit order and
    the result/stat stream is unaffected."""
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store, plan, _ = small_store
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=1))
    tids = list(range(plan.num_tiles))
    eng.caches[0].warm(tids[::2])         # every other tile resident
    ordered = eng._order_cache_first(0, tids)
    assert sorted(ordered) == tids
    assert ordered[: len(tids[::2])] == tids[::2]
    assert ordered[len(tids[::2]):] == tids[1::2]

    ref = _engine_run(store, PageRank(update_tol=1e-10),
                      cache_aware_order=False)
    res = _engine_run(store, PageRank(update_tol=1e-10),
                      cache_aware_order=True)
    assert np.array_equal(ref.values, res.values)


def test_superstep_report_carries_tier_stats(small_store):
    from repro.core.apps import PageRank

    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    res = _engine_run(store, PageRank(), cache_policy="tiered",
                      cache_capacity_bytes=sum(sizes) // 8, max_supersteps=4)
    h = res.history[-1]
    assert h.cache_tiers                      # per-tier residency present
    assert sum(d["tiles"] for d in h.cache_tiers.values()) > 0
    # the working set exceeds the warm-tier budget, so re-tiering must
    # have moved tiles (demotions under pressure, or promotions after)
    assert (sum(x.cache_demotions for x in res.history)
            + sum(x.cache_promotions for x in res.history)) > 0


def test_second_run_stats_rebaselined(small_store):
    """Regression: the cumulative-counter baselines (_io_busy_cum /
    _promo_cum / _demo_cum / _disk_cum) were only set in __init__, so cache
    activity between runs (warm()/maintain()/direct get()s) leaked into the
    next run's first-superstep deltas.  run() must re-baseline: every
    per-superstep delta of run 2 sums exactly to what run 2 itself moved."""
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=2, cache_capacity_bytes=sum(sizes) // 3, cache_mode=2,
        tile_skipping=False, max_supersteps=3))
    eng.run(PageRank())
    # external cache traffic between the runs: clear + touch tiles directly
    for c in eng.caches.values():
        c.clear()
        c.get(eng.assignment[0][0])
    external = sum(c.stats.disk_bytes_read for c in eng.caches.values())
    res2 = eng.run(PageRank())
    total_after = sum(c.stats.disk_bytes_read for c in eng.caches.values())
    per_ss = [h.disk_bytes_read for h in res2.history]
    assert all(b >= 0 for b in per_ss)
    # run 2's deltas cover exactly run 2's disk traffic — the external
    # reads between runs are excluded (pre-fix they landed in superstep 0)
    assert sum(per_ss) == total_after - external
    assert all(h.io_busy_seconds >= 0 for h in res2.history)
    assert all(h.cache_promotions >= 0 and h.cache_demotions >= 0
               for h in res2.history)
