"""Scheduler (work stealing + speculation), serving engine, baselines."""
import numpy as np
import pytest

from repro.core.partition import assign_tiles
from repro.runtime.scheduler import WorkStealingScheduler, simulate_superstep


def _sched(n_tiles=32, n_servers=4, seed=0, **kw):
    rng = np.random.default_rng(seed)
    edges = rng.pareto(1.3, n_tiles) * 1000 + 100
    return WorkStealingScheduler(assign_tiles(n_tiles, n_servers), edges, **kw), edges


def test_all_tiles_complete_exactly_once():
    sched, edges = _sched()
    stats = simulate_superstep(sched, np.ones(4), lambda t: edges[t])
    assert sched.all_done()
    winners = [t.completed_by for t in sched.tasks.values()]
    assert all(w is not None for w in winners)


def test_work_stealing_beats_static_with_skew():
    """Heterogeneous server speeds: stealing shortens the makespan vs
    static round-robin (no stealing)."""
    speeds = np.array([1.0, 1.0, 1.0, 0.25])     # one slow straggler node
    rng = np.random.default_rng(1)
    edges = rng.uniform(100, 1000, 64)           # no single dominating tile
    sched1 = WorkStealingScheduler(assign_tiles(64, 4), edges,
                                   enable_speculation=False)
    dynamic = simulate_superstep(sched1, speeds, lambda t: edges[t])

    # static: each server must run exactly its own tiles
    assign = assign_tiles(64, 4)
    static_makespan = max(
        sum(edges[t] for t in assign[s]) / speeds[s] for s in range(4))
    assert dynamic["makespan"] < static_makespan * 0.75
    assert dynamic["steals"] > 0


def test_speculation_rescues_giant_tile_on_slow_server():
    """A dominating tile landing on a slow node: speculative re-execution
    on a fast node bounds the makespan near the fast-node tile time."""
    edges = np.array([100.0] * 16)
    edges[3] = 10_000.0
    # tile 3 is server 3's FIRST tile: it starts immediately on the slow
    # node, so stealing can't rescue it (in flight) — only speculation can.
    speeds = np.array([1.0, 1.0, 1.0, 0.1])
    sched = WorkStealingScheduler(assign_tiles(16, 4), edges,
                                  enable_speculation=True,
                                  straggler_factor=2.0)
    dyn = simulate_superstep(sched, speeds, lambda t: edges[t])
    nospec = WorkStealingScheduler(assign_tiles(16, 4), edges,
                                   enable_speculation=False)
    base = simulate_superstep(nospec, speeds, lambda t: edges[t])
    assert base["makespan"] >= 10_000 / 0.1 * 0.99    # stuck on the slow node
    assert dyn["makespan"] < base["makespan"] * 0.25  # speculation rescued it
    assert dyn["speculative"] >= 1


def test_speculative_execution_counts():
    sched, edges = _sched(16, 4, enable_speculation=True)
    sim = simulate_superstep(sched, np.array([1, 1, 1, 0.05]),
                             lambda t: edges[t])
    assert sched.all_done()


def test_completion_idempotent():
    sched, edges = _sched(4, 2)
    t = sched.next_tile(0)
    assert sched.complete(0, t) is True
    assert sched.complete(1, t) is False          # duplicate finish ignored


def test_serve_engine_continuous_batching_consistency():
    import jax
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.models.model_zoo import build_model
    from repro.serve.engine import Request, ServeEngine

    run = RunConfig(remat="none", q_chunk=16, kv_chunk=16,
                    compute_dtype="float32")
    cfg = registry.get_config("qwen3-1.7b", reduced=True)
    params = build_model(cfg, run).init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 10))).astype(np.int32),
                    max_new_tokens=6) for i in range(5)]
    eng = ServeEngine(cfg, run, params, slots=2, max_len=48)
    outs = {o.rid: o.tokens for o in eng.run_requests(reqs)}
    assert len(outs) == 5
    # continuous-batched result equals isolated single-slot decoding
    for rid in (0, 3):
        single = ServeEngine(cfg, run, params, slots=1, max_len=48)
        ref = single.run_requests(
            [Request(rid=rid, prompt=reqs[rid].prompt, max_new_tokens=6)])
        assert outs[rid] == ref[0].tokens, rid


@pytest.mark.parametrize("name", ["pregel+", "powergraph", "graphd", "chaos"])
def test_baselines_match_networkx(name, small_graph, nx_pagerank):
    from repro.core.apps import PageRank
    from repro.core.baselines import ENGINES

    nv, src, dst = small_graph
    eng = ENGINES[name](src, dst, None, nv, num_servers=3)
    res = eng.run(PageRank(update_tol=1e-10), max_supersteps=150)
    ours = res.values / res.values.sum()
    assert np.abs(ours - nx_pagerank).max() < 1e-7, name


def test_baseline_cost_shapes(small_graph):
    """Table III qualitative shape: Chaos moves the most bytes; out-of-core
    engines do real disk I/O, in-memory ones none."""
    from repro.core.apps import PageRank
    from repro.core.baselines import ENGINES

    nv, src, dst = small_graph
    stats = {}
    for name, cls in ENGINES.items():
        eng = cls(src, dst, None, nv, num_servers=3)
        res = eng.run(PageRank(update_tol=1e-10), max_supersteps=3)
        h = res.history[1]
        stats[name] = h
    assert stats["pregel+"].disk_read_bytes == 0
    assert stats["powergraph"].disk_read_bytes == 0
    assert stats["graphd"].disk_read_bytes > 0
    assert stats["chaos"].disk_read_bytes > 0
    assert stats["chaos"].network_bytes > stats["pregel+"].network_bytes
