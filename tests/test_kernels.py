"""Pallas kernels vs pure-jnp oracles (interpret mode — CPU container)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("E,R", [(64, 16), (1000, 300), (4096, 512),
                                 (777, 1), (128, 1024)])
@pytest.mark.parametrize("combine", ["sum", "min", "max"])
def test_segment_reduce_shapes(E, R, combine):
    rng = np.random.default_rng(E + R)
    c = jnp.asarray(rng.normal(size=E).astype(np.float32))
    d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
    kfn = getattr(ops, f"segment_{combine}")
    rfn = getattr(ref, f"segment_{combine}")
    got, want = kfn(c, d, R), rfn(c, d, R)
    fin = jnp.isfinite(want)
    assert bool(jnp.all(jnp.isfinite(got) == fin))
    np.testing.assert_allclose(np.asarray(got)[np.asarray(fin)],
                               np.asarray(want)[np.asarray(fin)],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("be,br", [(128, 128), (256, 512), (512, 256)])
def test_segment_sum_block_shapes(be, br):
    rng = np.random.default_rng(be)
    E, R = 2000, 700
    c = jnp.asarray(rng.normal(size=E).astype(np.float32))
    d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
    got = ops.segment_sum(c, d, R, block_e=be, block_r=br)
    want = ref.segment_sum(c, d, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 2000), st.integers(1, 400), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_segment_sum_property(E, R, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=E).astype(np.float32))
    d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
    got = ops.segment_sum(c, d, R)
    want = ref.segment_sum(c, d, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # conservation: total mass preserved
    assert abs(float(jnp.sum(got)) - float(jnp.sum(c))) < 1e-2


def test_segment_sum_unsorted_ids():
    """The one-hot kernel must not require sorted dst ids."""
    rng = np.random.default_rng(0)
    E, R = 1500, 200
    c = jnp.asarray(rng.normal(size=E).astype(np.float32))
    d = jnp.asarray(rng.integers(0, R, E).astype(np.int32))  # unsorted
    np.testing.assert_allclose(np.asarray(ops.segment_sum(c, d, R)),
                               np.asarray(ref.segment_sum(c, d, R)),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3000), st.floats(0.0, 0.39), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_compact_property(n, p, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < p)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    K = max(int(np.ceil(0.4 * n)), 1)
    gi, gv = ops.compact(mask, vals, K)
    ri, rv = ref.compact(mask, vals, K)
    assert bool(jnp.all(gi == ri))
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv), rtol=1e-6)


def test_compact_block_sizes():
    rng = np.random.default_rng(1)
    n = 2048
    mask = jnp.asarray(rng.random(n) < 0.3)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    K = 1024
    ri, rv = ref.compact(mask, vals, K)
    for block in (128, 256, 1024):
        gi, gv = ops.compact(mask, vals, K, block=block)
        assert bool(jnp.all(gi == ri)), block


# ---------------------------------------------------------------------------
# multi-query (contrib [E, Q]) parity — the one-hot matvec becomes a GEMM
# ---------------------------------------------------------------------------

def _per_column_ref(combine, c2, d, R):
    rfn = getattr(ref, f"segment_{combine}")
    return np.stack([np.asarray(rfn(c2[:, q], d, R))
                     for q in range(c2.shape[1])], axis=1)


@pytest.mark.parametrize("E,R,Q", [
    (777, 130, 3),      # nothing a multiple of (BE, BR)
    (1000, 300, 5),
    (64, 16, 2),        # far below one block in both axes
    (513, 257, 4),      # one past the block boundary on both axes
    (3, 1, 7),          # degenerate row count
])
@pytest.mark.parametrize("combine", ["sum", "min", "max"])
def test_segment_reduce_multi_query_parity(E, R, Q, combine):
    """Q>1 parity vs the per-column jnp oracle for every monoid, with
    shapes that are not multiples of the (BE, BR) kernel blocks."""
    rng = np.random.default_rng(E * 7 + R + Q)
    c2 = jnp.asarray(rng.normal(size=(E, Q)).astype(np.float32))
    d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
    got = np.asarray(getattr(ops, f"segment_{combine}")(c2, d, R))
    want = _per_column_ref(combine, c2, d, R)
    assert got.shape == (R, Q)
    fin = np.isfinite(want)
    assert np.array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("combine", ["sum", "min", "max"])
@pytest.mark.parametrize("Q", [1, 4])
def test_segment_reduce_all_padding_edge_block(combine, Q):
    """The engine's inert-padding convention: every edge routed to the
    sink (out-of-range) row — one-hot hits no lane, so each output row
    must be the monoid identity.  Exercises an edge block made entirely
    of padding (plus kernel-side padding of the partial block)."""
    from repro.kernels.gab_gather import _IDENTITY

    E, R = 200, 70
    rng = np.random.default_rng(0)
    shape = (E,) if Q == 1 else (E, Q)
    c = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    d = jnp.full((E,), R, dtype=jnp.int32)       # all edges -> sink row R
    got = np.asarray(getattr(ops, f"segment_{combine}")(c, d, R + 1))
    # rows [0, R) saw no edge at all; row R collected everything
    body = got[:R]
    assert np.all(body == np.float32(_IDENTITY[combine])), combine


@pytest.mark.parametrize("combine", ["sum", "min", "max"])
def test_segment_reduce_empty_edge_list(combine):
    """E=0: the kernel pads up to one full block of pure padding; output
    must be all-identity (sum collapses to 0 everywhere)."""
    from repro.kernels.gab_gather import _IDENTITY, segment_reduce_pallas

    c = jnp.zeros((0, 3), dtype=jnp.float32)
    d = jnp.zeros((0,), dtype=jnp.int32)
    got = np.asarray(segment_reduce_pallas(c, d, 40, combine=combine,
                                           interpret=True))
    assert got.shape == (40, 3)
    assert np.all(got == np.float32(_IDENTITY[combine]))


def test_segment_sum_q1_column_matches_1d():
    """A [E, 1] batch must reproduce the 1-D kernel result bit-for-bit —
    the invariant the engine's batched-vs-solo differential relies on."""
    rng = np.random.default_rng(5)
    E, R = 900, 250
    c = jnp.asarray(rng.normal(size=E).astype(np.float32))
    d = jnp.asarray(np.sort(rng.integers(0, R, E)).astype(np.int32))
    one = np.asarray(ops.segment_sum(c, d, R))
    col = np.asarray(ops.segment_sum(c[:, None], d, R))[:, 0]
    np.testing.assert_array_equal(one, col)


def test_gab_engine_with_pallas_segsum(small_store, nx_pagerank):
    """End-to-end: PageRank through the engine using the Pallas kernel path."""
    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    store, plan, _ = small_store
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=2, seg_impl="pallas_onehot", max_supersteps=60))
    res = eng.run(PageRank(update_tol=1e-8))
    ours = res.values / res.values.sum()
    assert np.abs(ours - nx_pagerank).max() < 1e-5


@pytest.mark.parametrize("combine", ["sum", "min", "max"])
def test_segment_reduce_integer_exact_above_2p24(combine):
    """Regression: wide-integer contributions must keep integer exactness.

    The Pallas path casts to f32, which cannot represent odd integers
    above 2**24 — the gather wrapper now routes >=32-bit integer inputs to
    the exact jnp reference (mirroring the compact kernel's magnitude
    guard in ops.py) instead of silently rounding."""
    big = 1 << 24
    c = jnp.asarray([big - 1, big, big + 1, big + 3, 1, 2], dtype=jnp.int32)
    d = jnp.asarray([0, 0, 1, 1, 2, 2], dtype=jnp.int32)
    got = np.asarray(getattr(ops, f"segment_{combine}")(c, d, 3))
    want = np.asarray(getattr(ref, f"segment_{combine}")(c, d, 3))
    assert got.dtype == want.dtype and np.issubdtype(got.dtype, np.integer)
    np.testing.assert_array_equal(got, want)
    if combine == "sum":
        # the f32 path would have produced 2**25 + 3 -> rounded
        assert got[1] == 2 * big + 4


def test_segment_sum_int32_many_terms_exact():
    """A sum that only crosses 2**24 through accumulation (every term is
    small) must still be exact — the guard keys on dtype, not magnitude,
    because the kernel cannot know the reduction total in advance."""
    E = 4096
    c = jnp.full((E,), 8193, dtype=jnp.int32)       # total = 8193*4096 > 2^25
    d = jnp.zeros((E,), dtype=jnp.int32)
    got = np.asarray(ops.segment_sum(c, d, 1))
    assert int(got[0]) == 8193 * E


@pytest.mark.parametrize("Q", [1, 3, 5, 8])
@pytest.mark.parametrize("combine", ["sum", "min", "max"])
def test_segment_reduce_sublane_q_padding(Q, combine):
    """Regression: Q is padded to a full sublane multiple inside the
    wrapper (raw q as the BlockSpec sublane dim miscompiles on real TPUs)
    and sliced back on return — results must match the per-column oracle
    for every Q in and at the sublane boundary."""
    rng = np.random.default_rng(Q * 11 + len(combine))
    E, R = 513, 130
    shape = (E,) if Q == 1 else (E, Q)
    c = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    d = jnp.asarray(rng.integers(0, R, E).astype(np.int32))
    got = np.asarray(getattr(ops, f"segment_{combine}")(c, d, R))
    want_2d = _per_column_ref(combine, c if c.ndim == 2 else c[:, None],
                              d, R)
    want = want_2d[:, 0] if Q == 1 else want_2d
    assert got.shape == ((R,) if Q == 1 else (R, Q))
    fin = np.isfinite(want)
    assert np.array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5, atol=1e-5)
