"""End-to-end behaviour tests for the whole system.

1. GraphH pipeline: synthetic graph -> SPE -> tile store -> out-of-core
   engine (cache + hybrid comm + skipping) -> PageRank == networkx; engine
   accounting is self-consistent.
2. LM pipeline: train a tiny model for a few steps (driver code path),
   checkpoint, then serve completions from the trained weights.
"""
import numpy as np
import pytest


def test_graphh_end_to_end(tmp_path):
    import networkx as nx

    from repro.core.apps import PageRank
    from repro.core.engine import EngineConfig, OutOfCoreEngine
    from repro.graphio import spe, synth
    from repro.graphio.formats import TileStore

    nv, ne = 2000, 16000
    store = TileStore(str(tmp_path / "g"), disk_mode=2)    # compressed at rest
    spe.preprocess(lambda: synth.rmat_edges(nv, ne, seed=5),
                   nv, store, tile_size=1024)
    plan = store.load_plan()
    assert plan.num_tiles > 4

    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=4, cache_capacity_bytes=1 << 22, cache_mode="auto",
        comm_mode="hybrid", max_supersteps=100))
    res = eng.run(PageRank(update_tol=1e-9))
    assert res.converged

    # oracle
    tiles_edges = []
    for t in range(plan.num_tiles):
        tile = store.read_tile(t)
        n = tile.meta.num_edges
        tiles_edges.append((tile.src[:n], tile.dst_local[:n] + tile.meta.row_start))
    src = np.concatenate([e[0] for e in tiles_edges])
    dst = np.concatenate([e[1] for e in tiles_edges])
    # RMAT emits parallel edges; GraphH keeps multiplicity (paper semantics),
    # so the oracle uses multiplicity as edge weight.
    key = src.astype(np.int64) * nv + dst
    uniq, counts = np.unique(key, return_counts=True)
    G = nx.DiGraph()
    G.add_nodes_from(range(nv))
    G.add_weighted_edges_from(
        zip((uniq // nv).tolist(), (uniq % nv).tolist(), counts.tolist()))
    pr = nx.pagerank(G, alpha=0.85, tol=1e-12, max_iter=500, weight="weight")
    ref = np.array([pr[i] for i in range(nv)])
    ours = res.values / res.values.sum()
    assert np.abs(ours - ref).max() < 1e-6

    # accounting self-consistency
    h0 = res.history[0]
    assert h0.tiles_processed == plan.num_tiles
    assert h0.raw_bytes > 0 and h0.wire_bytes > 0
    assert 0 <= h0.cache_hit_ratio <= 1
    # warm cache by superstep 2 (capacity is generous)
    assert res.history[2].disk_bytes_read <= res.history[0].disk_bytes_read


def test_lm_train_then_serve(tmp_path):
    from repro.launch import serve as serve_cli
    from repro.launch import train as train_cli

    losses = train_cli.main([
        "--arch", "granite-moe-1b-a400m", "--reduced",
        "--steps", "12", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "6",
        "--log-every", "6",
    ])
    assert losses[-1] < losses[0]
    outs = serve_cli.main([
        "--arch", "granite-moe-1b-a400m", "--reduced",
        "--requests", "4", "--slots", "2", "--max-new", "4",
        "--max-len", "48", "--prompt-len", "6",
        "--ckpt-dir", str(tmp_path / "ck"),
    ])
    assert len(outs) == 4
    assert all(len(o.tokens) == 4 for o in outs)


def test_graph_cli(tmp_path):
    from repro.launch import graph as graph_cli

    res = graph_cli.main([
        "--app", "pagerank", "--vertices", "500", "--edges", "3000",
        "--tile-size", "256", "--servers", "2", "--supersteps", "30",
        "--store", str(tmp_path / "s"),
    ])
    assert res.supersteps > 1
