"""Pipelined superstep engine (DESIGN.md §7): prefetch iterator contract,
serial/pipelined equivalence, and stacked-batch padding correctness."""
import threading
import time

import numpy as np
import pytest

from repro.core.apps import SSSP, WCC, PageRank
from repro.core.cache import EdgeCache
from repro.core.engine import EngineConfig, OutOfCoreEngine


# --------------------------- prefetch iterator -----------------------------

def test_prefetch_iter_order_and_content(small_store):
    store, plan, _ = small_store
    ids = list(range(plan.num_tiles))[::-1]  # arbitrary (reverse) order
    got = list(store.prefetch_iter(ids, depth=2))
    assert [tid for tid, _ in got] == ids
    for tid, tile in got:
        ref = store.read_tile(tid)
        np.testing.assert_array_equal(tile.src, ref.src)
        np.testing.assert_array_equal(tile.dst_local, ref.dst_local)
        np.testing.assert_array_equal(tile.row_ptr, ref.row_ptr)


def test_prefetch_iter_empty_and_single(small_store):
    store, plan, _ = small_store
    assert list(store.prefetch_iter([], depth=3)) == []
    [(tid, tile)] = list(store.prefetch_iter([0], depth=3))
    assert tid == 0 and tile.meta.tile_id == 0


def test_prefetch_iter_bounded_depth(small_store):
    """Readahead must never exceed ``depth`` undelivered tiles, no matter
    how slow the consumer is."""
    store, plan, _ = small_store
    depth = 2
    reads = []
    lock = threading.Lock()
    orig = store.read_tile

    def counting_read(tid):
        with lock:
            reads.append(tid)
        return orig(tid)

    store.read_tile = counting_read
    try:
        consumed = 0
        max_ahead = 0
        for _tid, _tile in store.prefetch_iter(range(plan.num_tiles),
                                               depth=depth, workers=2):
            consumed += 1
            time.sleep(0.02)  # slow consumer: give workers time to run ahead
            with lock:
                max_ahead = max(max_ahead, len(reads) - consumed)
        assert consumed == plan.num_tiles
        # at most `depth` tiles may be claimed/decoded but not yet consumed
        assert max_ahead <= depth
    finally:
        store.read_tile = orig


def test_prefetch_iter_early_close_stops_workers(small_store):
    store, plan, _ = small_store
    it = store.prefetch_iter(range(plan.num_tiles), depth=2)
    next(it)
    it.close()  # must not hang or leak a blocked worker
    alive = [t for t in threading.enumerate()
             if t.name.startswith("graphh-prefetch")]
    assert not alive


def test_prefetch_iter_through_cache_hits(small_store):
    store, plan, _ = small_store
    cache = EdgeCache(store, capacity_bytes=1 << 30, mode=2)
    cache.warm(range(plan.num_tiles))
    misses0 = cache.stats.misses
    bytes0 = store.bytes_read
    out = list(store.prefetch_iter(range(plan.num_tiles), depth=3,
                                   cache=cache))
    assert len(out) == plan.num_tiles
    assert cache.stats.misses == misses0          # all hits
    assert cache.stats.hits >= plan.num_tiles
    assert store.bytes_read == bytes0             # disk never touched


def test_prefetch_iter_inflight_dedup_single_read_per_tile(small_store):
    """Regression: two prefetch workers claiming the same tile id both
    missed the cache (get_if_resident consulted, but nothing marked the
    read in flight) and read the tile from disk twice.  With in-flight
    deduplication the follower waits for the leader's read and serves the
    duplicate from the cache — exactly one disk read per distinct tile."""
    store, plan, _ = small_store
    cache = EdgeCache(store, capacity_bytes=1 << 30, mode=2)
    reads = []
    lock = threading.Lock()
    orig = store.read_tile_blob

    def slow_counting_read(tid):
        with lock:
            reads.append(tid)
        time.sleep(0.05)   # hold the read open so workers overlap on it
        return orig(tid)

    store.read_tile_blob = slow_counting_read
    try:
        # duplicate ids back to back: both workers pick up the same tile
        ids = [t for t in range(min(4, plan.num_tiles)) for _ in range(2)]
        got = list(store.prefetch_iter(ids, depth=4, workers=2, cache=cache))
        assert [tid for tid, _ in got] == ids
        for tid, tile in got:
            assert tile.meta.tile_id == tid
        with lock:
            assert sorted(reads) == sorted(set(ids))   # one read per tile
    finally:
        store.read_tile_blob = orig


def test_prefetch_iter_propagates_errors(small_store):
    store, plan, _ = small_store
    with pytest.raises(FileNotFoundError):
        list(store.prefetch_iter([0, 99999], depth=2))


# --------------------------- stacked-batch padding -------------------------

def test_run_tile_stack_padding_is_inert(small_store):
    from repro.core.distributed import pad_stack_to
    from repro.core.gab import run_tile_stack
    from repro.core.tiles import stack_tiles

    store, plan, _ = small_store
    import jax.numpy as jnp

    tiles = [store.read_tile(t) for t in range(min(3, plan.num_tiles))]
    nv = plan.num_vertices
    prog = PageRank()
    state = prog.init(nv, np.ones(nv), np.ones(nv))
    values = jnp.asarray(state.pop("value"))
    aux = {k: jnp.asarray(v) for k, v in state.items()}

    plain = stack_tiles(tiles, plan.row_cap)
    padded = pad_stack_to(stack_tiles(tiles, plan.row_cap), len(tiles) + 3)
    assert len(padded["row_start"]) == len(tiles) + 3

    m1, u1 = run_tile_stack(prog, values, aux, plain, plan.row_cap)
    m2, u2 = run_tile_stack(prog, values, aux, padded, plan.row_cap)
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_run_tile_stack_matches_run_tile(small_store):
    """One batched dispatch == per-tile dispatches, bit for bit."""
    from repro.core.gab import run_tile, run_tile_stack
    from repro.core.tiles import stack_tiles, tile_edge_values

    store, plan, _ = small_store
    import jax.numpy as jnp

    tiles = [store.read_tile(t) for t in range(plan.num_tiles)]
    nv = plan.num_vertices
    prog = PageRank()
    state = prog.init(nv, np.ones(nv), np.ones(nv))
    values = jnp.asarray(state.pop("value"))
    aux = {k: jnp.asarray(v) for k, v in state.items()}

    masked, upd = run_tile_stack(prog, values, aux,
                                 stack_tiles(tiles, plan.row_cap),
                                 plan.row_cap)
    masked, upd = np.asarray(masked), np.asarray(upd)

    ref_masked = np.zeros(nv, np.float32)
    ref_upd = np.zeros(nv, bool)
    for t in tiles:
        rows, new, u = run_tile(
            prog, values, aux, (t.src, t.dst_local, tile_edge_values(t)),
            t.meta.row_start, t.meta.num_rows, plan.row_cap)
        rows, new, u = np.asarray(rows), np.asarray(new), np.asarray(u)
        ref_masked[rows[u]] = new[u]
        ref_upd[rows[u]] = True

    np.testing.assert_array_equal(upd, ref_upd)
    np.testing.assert_array_equal(masked[ref_upd], ref_masked[ref_upd])


# --------------------------- engine equivalence ----------------------------

def _run(store, prog, pipeline, **kw):
    cfg = EngineConfig(num_servers=3, max_supersteps=200, pipeline=pipeline,
                       prefetch_depth=3, prefetch_workers=2, stack_size=2,
                       **kw)
    return OutOfCoreEngine(store, cfg).run(prog)


@pytest.mark.parametrize("prog_factory", [
    lambda: PageRank(update_tol=1e-10),
    lambda: WCC(),
], ids=["pagerank", "wcc"])
def test_pipelined_bit_identical_unweighted(small_store, prog_factory):
    store, plan, _ = small_store
    ser = _run(store, prog_factory(), pipeline=False)
    pip = _run(store, prog_factory(), pipeline=True)
    assert ser.supersteps == pip.supersteps
    assert np.array_equal(ser.values, pip.values)  # bit-identical


def test_pipelined_bit_identical_sssp(tmp_path, small_graph):
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=100)
    ser = _run(store, SSSP(source=0), pipeline=False)
    pip = _run(store, SSSP(source=0), pipeline=True)
    assert ser.supersteps == pip.supersteps
    assert np.array_equal(ser.values, pip.values)


def test_pipelined_with_tile_skipping(tmp_path, small_graph):
    """Skip filters and the pipelined path must compose: the survivor list
    is prefetched, skipped tiles are never read."""
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w2"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=64)
    kw = dict(tile_skipping=True, skip_density_threshold=0.9, block_shift=2)
    ser = _run(store, SSSP(source=0), pipeline=False, **kw)
    pip = _run(store, SSSP(source=0), pipeline=True, **kw)
    assert np.array_equal(ser.values, pip.values)
    assert sum(h.tiles_skipped for h in pip.history) > 0
    assert (sum(h.tiles_skipped for h in ser.history)
            == sum(h.tiles_skipped for h in pip.history))


def test_pipelined_small_cache_and_stall_accounting(small_store):
    """Under eviction pressure results stay exact and the stall/io-busy
    accounting stays sane (stall <= superstep wall time)."""
    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    cap = sum(sizes) // 3
    ser = _run(store, PageRank(update_tol=1e-10), pipeline=False,
               cache_capacity_bytes=cap, cache_mode=2)
    pip = _run(store, PageRank(update_tol=1e-10), pipeline=True,
               cache_capacity_bytes=cap, cache_mode=2)
    assert np.array_equal(ser.values, pip.values)
    for h in pip.history:
        assert 0.0 <= h.stall_seconds <= h.seconds + 1e-6
        assert h.io_busy_seconds >= 0.0
    # the serial engine never hides I/O behind compute
    assert all(h.io_hidden_seconds == 0.0 for h in ser.history)


def test_pipelined_stack_size_one(small_store):
    """stack_size=1 degenerates to per-tile dispatch but stays correct."""
    store, plan, _ = small_store
    ser = _run(store, PageRank(update_tol=1e-10), pipeline=False)
    cfg = EngineConfig(num_servers=2, max_supersteps=200, pipeline=True,
                       prefetch_depth=1, prefetch_workers=1, stack_size=1)
    pip = OutOfCoreEngine(store, cfg).run(PageRank(update_tol=1e-10))
    assert np.array_equal(ser.values, pip.values)
