import os

# Tests must see the single real CPU device (the 512-device flag is ONLY for
# the dry-run entry point).  Distributed tests spawn subprocesses that set
# their own XLA_FLAGS.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_graph():
    """Deduped random directed graph (300 vertices, ~1.8k edges)."""
    rng = np.random.default_rng(7)
    nv, ne = 300, 2000
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, idx = np.unique(key, return_index=True)
    return nv, src[idx], dst[idx]


@pytest.fixture(scope="session")
def small_store(small_graph, tmp_path_factory):
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    store = TileStore(str(tmp_path_factory.mktemp("store")))
    plan = spe.preprocess_arrays(src, dst, None, nv, store, tile_size=128)
    return store, plan, (nv, src, dst)


@pytest.fixture(scope="session")
def nx_pagerank(small_graph):
    import networkx as nx

    nv, src, dst = small_graph
    G = nx.DiGraph()
    G.add_nodes_from(range(nv))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    pr = nx.pagerank(G, alpha=0.85, tol=1e-12, max_iter=500)
    return np.array([pr[i] for i in range(nv)])
