"""Two-stage partitioning invariants (paper §III-B), incl. property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import partition as pt
from repro.core.tiles import build_tile, stack_tiles


@given(st.lists(st.integers(0, 50), min_size=1, max_size=300),
       st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_splitter_invariants(degs, tile_size):
    in_deg = np.asarray(degs, dtype=np.int64)
    sp = pt.make_splitter(in_deg, tile_size)
    # covers all vertices exactly once, monotone
    assert sp[0] == 0 and sp[-1] == len(degs)
    assert np.all(np.diff(sp) >= 1)
    # edge conservation
    csum = np.concatenate([[0], np.cumsum(in_deg)])
    per_tile = csum[sp[1:]] - csum[sp[:-1]]
    assert per_tile.sum() == in_deg.sum()
    # paper's rule: every tile except the last stops at the first vertex
    # that pushes it past S => tile minus its last vertex is < S
    for t in range(len(sp) - 2):
        lo, hi = sp[t], sp[t + 1]
        if hi - lo > 1:
            assert (csum[hi - 1] - csum[lo]) < tile_size


@given(st.integers(1, 500), st.integers(1, 2000), st.integers(8, 256))
@settings(max_examples=30, deadline=None)
def test_plan_partition_caps(nv, ne, tile_size):
    rng = np.random.default_rng(nv * 31 + ne)
    dst = rng.integers(0, nv, ne)
    in_deg = np.bincount(dst, minlength=nv)
    plan = pt.plan_partition(in_deg, tile_size)
    assert plan.num_edges == ne
    assert plan.edge_cap >= plan.edges_per_tile.max()
    assert plan.row_cap >= np.diff(plan.splitter).max()
    # tile_of_vertex consistent with splitter
    for v in rng.integers(0, nv, 10):
        t = plan.tile_of_vertex(int(v))
        assert plan.splitter[t] <= v < plan.splitter[t + 1]


@given(st.integers(2, 400), st.integers(8, 256), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_tile_of_vertex_boundary_roundtrip(nv, tile_size, seed):
    """Property: ``tile_of_vertex`` round-trips exactly at tile boundaries —
    the first and last vertex of every tile map back to that tile, and the
    vertex one past the end maps to the next tile."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, nv, nv * 3)
    in_deg = np.bincount(dst, minlength=nv)
    plan = pt.plan_partition(in_deg, tile_size)
    sp = plan.splitter
    # tiles exactly partition [0, V): contiguous, disjoint, complete
    assert sp[0] == 0 and sp[-1] == nv
    assert np.all(np.diff(sp) >= 1)
    for t in range(plan.num_tiles):
        lo, hi = plan.tile_range(t)
        assert plan.tile_of_vertex(lo) == t
        assert plan.tile_of_vertex(hi - 1) == t
        if hi < nv:
            assert plan.tile_of_vertex(hi) == t + 1


@pytest.mark.parametrize("name,degs", [
    ("all_zero", np.zeros(64, dtype=np.int64)),
    ("single_hub", np.concatenate([[10_000], np.zeros(63, dtype=np.int64)])),
    ("hub_at_end", np.concatenate([np.zeros(63, dtype=np.int64), [10_000]])),
    ("two_hubs", np.array([0, 5000, 0, 0, 5000, 0] * 10, dtype=np.int64)),
    ("powerlaw", (np.random.default_rng(0).zipf(1.5, 200)
                  .clip(0, 50_000).astype(np.int64))),
    ("alternating", np.array([0, 300] * 50, dtype=np.int64)),
    ("one_vertex", np.array([7], dtype=np.int64)),
])
def test_plan_partition_adversarial_degrees(name, degs):
    """PartitionPlan invariants under adversarial degree distributions:
    hub vertices whose degree dwarfs tile_size, zero-degree runs, and
    heavy-tailed skew.  Caps must always cover the realized per-tile
    maxima and the splitter must stay an exact partition of [0, V)."""
    for tile_size in (8, 64, 1024):
        plan = pt.plan_partition(degs, tile_size)
        sp = plan.splitter
        assert sp[0] == 0 and sp[-1] == len(degs), name
        assert np.all(np.diff(sp) >= 1), name
        # edge conservation
        assert plan.num_edges == int(degs.sum()), name
        assert plan.edges_per_tile.sum() == degs.sum(), name
        # caps respected (a hub > tile_size forces a single-vertex tile,
        # and edge_cap must stretch to hold it)
        assert plan.edge_cap >= int(plan.edges_per_tile.max(initial=1)), name
        assert plan.row_cap >= int(np.diff(sp).max(initial=1)), name
        # per-tile edge counts consistent with the degree prefix sums
        csum = np.concatenate([[0], np.cumsum(degs)])
        np.testing.assert_array_equal(
            plan.edges_per_tile, csum[sp[1:]] - csum[sp[:-1]], err_msg=name)
        # boundary round-trips survive the skew
        for t in range(plan.num_tiles):
            lo, hi = plan.tile_range(t)
            assert plan.tile_of_vertex(lo) == t, name
            assert plan.tile_of_vertex(hi - 1) == t, name


def test_round_robin_assignment():
    a = pt.assign_tiles(10, 3)
    assert a == [[0, 3, 6, 9], [1, 4, 7], [2, 5, 8]]


def test_balanced_assignment_better_than_round_robin():
    rng = np.random.default_rng(0)
    edges = rng.pareto(1.2, 64) * 1000 + 10
    rr = pt.assign_tiles(64, 8)
    lpt = pt.assign_tiles_balanced(edges, 8)
    s_rr = pt.balance_stats(edges, rr)
    s_lpt = pt.balance_stats(edges, lpt)
    assert s_lpt["max_over_mean"] <= s_rr["max_over_mean"] + 1e-9
    # both cover every tile exactly once
    assert sorted(t for g in lpt for t in g) == list(range(64))


def test_build_tile_and_stack(small_graph):
    nv, src, dst = small_graph
    m = (dst >= 10) & (dst < 60)
    t = build_tile(0, 10, 60, src[m], dst[m], None, edge_cap=1024, row_cap=64)
    t.validate()
    assert t.meta.num_edges == m.sum()
    stk = stack_tiles([t], row_cap=64)
    assert stk["src"].shape == (1, 1024)
    # padding points at the global sink row
    assert np.all(stk["dst_local"][0, t.meta.num_edges:] == 64)
    # real edge values are 1.0 (unweighted), padding 0
    assert np.all(stk["val"][0, :t.meta.num_edges] == 1.0)
    assert np.all(stk["val"][0, t.meta.num_edges:] == 0.0)


def test_spe_preserves_edges(small_store):
    store, plan, (nv, src, dst) = small_store
    got = []
    for t in range(plan.num_tiles):
        tile = store.read_tile(t)
        n = tile.meta.num_edges
        got.append((tile.src[:n], tile.dst_local[:n] + tile.meta.row_start))
    gs = np.concatenate([g[0] for g in got])
    gd = np.concatenate([g[1] for g in got])
    want = np.lexsort((src, dst))
    have = np.lexsort((gs, gd))
    assert np.array_equal(gs[have], src[want])
    assert np.array_equal(gd[have], dst[want])
