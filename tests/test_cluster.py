"""Multi-process cluster runtime (DESIGN.md §11).

The acceptance property: an N-server cluster run is bit-identical to the
single-process engine for every app at N in {1, 2, 4}.  Covered two ways:

  * in-process "clusters" — each rank is a thread with its own engine +
    ClusterExchange over a real transport (fast; also what gives coverage
    visibility into the cluster code paths), and
  * real spawned clusters through launch.cluster.run_cluster (slower; one
    launch per (N, store) amortizes process startup over all apps).
"""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import transport as T
from repro.core.apps import (LandmarkDistances, MultiSourceBFS, PageRank,
                             PersonalizedPageRank, SSSP, WCC)
from repro.core.distributed import ClusterExchange
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe
from repro.graphio.formats import TileStore
from repro.launch.cluster import ClusterConfig, ClusterFailure, run_cluster
from repro.runtime.faults import FaultPlan, FaultSpec

SS = 12   # superstep cap: keep runs cheap; parity must hold at any cap


def _make_store(weighted, seed=7, nv=220, ne=1400, tile_size=96):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    src, dst = src[i], dst[i]
    val = (rng.uniform(0.1, 10.0, len(src)).astype(np.float32)
           if weighted else None)
    root = tempfile.mkdtemp(prefix=f"cluster_store_{int(weighted)}_")
    spe.preprocess_arrays(src, dst, val, nv, TileStore(root), tile_size)
    return root


@pytest.fixture(scope="module")
def stores():
    """(unweighted root, weighted root) shared by every test here."""
    return _make_store(False), _make_store(True)


def _apps_for(weighted):
    if weighted:
        return [SSSP(source=0), LandmarkDistances(landmarks=(0, 9, 33))]
    return [PageRank(), WCC(), PersonalizedPageRank(seeds=(1, 7, 50)),
            MultiSourceBFS(sources=(2, 11, 60))]


def _reference(root, prog, n, **cfg_kw):
    eng = OutOfCoreEngine(TileStore(root), EngineConfig(
        num_servers=n, max_supersteps=SS, **cfg_kw))
    return eng.run(prog)


def _thread_cluster(root, prog_factory, n, **cfg_kw):
    """Run one app on an in-process n-rank cluster (threads + shm rings)."""
    run_dir = tempfile.mkdtemp(prefix="cluster_rings_")
    T.create_ring_files(run_dir, n)
    outs = [None] * n
    errs = [None] * n

    def worker(r):
        try:
            store = TileStore(root)
            store.load_meta()
            eng = OutOfCoreEngine(store, EngineConfig(
                num_servers=n, server_rank=r, max_supersteps=SS, **cfg_kw))
            tr = T.RingTransport(r, n, run_dir)
            ex = ClusterExchange(tr, assignment=eng.assignment,
                                 edges_per_tile=eng.plan.edges_per_tile,
                                 timeout=60.0)
            eng.exchange = ex
            try:
                outs[r] = eng.run(prog_factory())
            finally:
                ex.close()
                tr.close()
        except BaseException as exc:   # pragma: no cover - surfaced below
            errs[r] = exc

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300.0)
    for r, e in enumerate(errs):
        assert e is None, f"rank {r}: {e!r}"
    return outs


@pytest.mark.parametrize("n", [1, 2, 4])
def test_inprocess_cluster_bit_identical(stores, n):
    unweighted, _ = stores
    ref = _reference(unweighted, PageRank(), n)
    outs = _thread_cluster(unweighted, PageRank, n)
    for r in range(n):
        assert np.array_equal(outs[r].values, ref.values)
        assert outs[r].supersteps == ref.supersteps
        assert outs[r].converged == ref.converged
    # every rank derived the same merged wire accounting
    for h_ref, *h_ranks in zip(*(o.history for o in outs)):
        assert all(h.wire_bytes == h_ref.wire_bytes for h in h_ranks)
        assert all(h.updated_vertices == h_ref.updated_vertices
                   for h in h_ranks)


def test_inprocess_cluster_multi_query_retirement(stores):
    unweighted, _ = stores
    prog = lambda: PersonalizedPageRank(seeds=(1, 7, 50))  # noqa: E731
    ref = _reference(unweighted, prog(), 2)
    outs = _thread_cluster(unweighted, prog, 2)
    for r in range(2):
        assert np.array_equal(outs[r].values, ref.values)
        assert np.array_equal(outs[r].per_query_supersteps,
                              ref.per_query_supersteps)
        # column retirement is cluster-wide: same columns, same supersteps
        assert [h.retired_queries for h in outs[r].history] == \
               [h.retired_queries for h in ref.history]


def test_inprocess_cluster_ooc_vstate(stores):
    unweighted, _ = stores
    ref = _reference(unweighted, PageRank(), 2, vertex_memory_budget=2000)
    outs = _thread_cluster(unweighted, PageRank, 2,
                           vertex_memory_budget=2000)
    assert np.array_equal(outs[0].values, ref.values)
    assert np.array_equal(outs[1].values, ref.values)


def test_inprocess_cluster_pipelined(stores):
    unweighted, _ = stores
    ref = _reference(unweighted, PageRank(), 2)
    outs = _thread_cluster(unweighted, PageRank, 2, pipeline=True)
    assert np.array_equal(outs[0].values, ref.values)


def test_exchange_steal_rebalances_deterministically(stores):
    """Both ranks must derive the same post-steal assignment from the
    same replicated timings, and results stay identical (tiles are
    idempotent — ownership never changes values)."""
    unweighted, _ = stores
    store = TileStore(unweighted)
    store.load_meta()
    eng = OutOfCoreEngine(store, EngineConfig(num_servers=2))
    run_dir = tempfile.mkdtemp(prefix="steal_rings_")
    T.create_ring_files(run_dir, 2)
    nv = eng.plan.num_vertices
    rng = np.random.default_rng(0)
    idx = np.sort(rng.choice(nv, size=40, replace=False)).astype(np.int64)
    vals = rng.normal(size=40).astype(np.float32)
    results = [None, None]

    def worker(r):
        tr = T.RingTransport(r, 2, run_dir)
        ex = ClusterExchange(tr, assignment=eng.assignment,
                             edges_per_tile=eng.plan.edges_per_tile,
                             steal=True, straggler_factor=1.5, timeout=60.0)
        try:
            half = idx[r::2]
            out = ex.exchange(idx=half, vals=vals[r::2], mask=None, nv=nv,
                              compute_seconds=10.0 if r == 0 else 1.0)
            results[r] = (out, [list(a) for a in ex.assignment])
        finally:
            ex.close()
            tr.close()

    ts = [threading.Thread(target=worker, args=(r,)) for r in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120.0)
    (out0, asg0), (out1, asg1) = results
    # identical merged updates on both ranks (rank order)
    assert np.array_equal(out0.idx, out1.idx)
    assert np.array_equal(out0.vals, out1.vals)
    # rank 0 straggled 10x -> it must shed tiles; both agree on the result
    assert out0.assignment is not None
    assert asg0 == asg1
    before = len(eng.assignment[0])
    assert len(asg0[0]) < before
    assert sorted(t for a in asg0 for t in a) == \
           sorted(t for a in eng.assignment for t in a)


# ---------------------------------------------------------------------------
# Real spawned clusters (launch.cluster)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("n", [1, 2, 4])
def test_spawned_cluster_all_apps_bit_identical(stores, n):
    """The acceptance sweep: all six apps, real server processes."""
    for root, weighted in zip(stores, (False, True)):
        progs = _apps_for(weighted)
        refs = [_reference(root, p, n) for p in progs]
        out = run_cluster(root, progs, ClusterConfig(
            num_servers=n, engine=EngineConfig(max_supersteps=SS)))
        assert out.verified   # driver-side cross-rank equality
        for a, p in enumerate(progs):
            assert np.array_equal(out.results[a].values, refs[a].values), p
            assert out.results[a].supersteps == refs[a].supersteps


@pytest.mark.slow
def test_spawned_cluster_tcp_and_steal(stores):
    unweighted, _ = stores
    ref = _reference(unweighted, PageRank(), 2)
    out = run_cluster(unweighted, [PageRank()], ClusterConfig(
        num_servers=2, transport="tcp", steal=True,
        engine=EngineConfig(max_supersteps=SS)))
    assert out.verified
    assert np.array_equal(out.results[0].values, ref.values)


# ---------------------------------------------------------------------------
# Fault drills on real spawned clusters (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _assert_no_live_children(pids, grace=10.0):
    """Every pid must be gone (teardown neither hangs nor leaks)."""
    deadline = time.monotonic() + grace
    for pid in pids:
        while True:
            try:
                os.kill(pid, 0)
            except (ProcessLookupError, PermissionError):
                break       # dead (or reaped); PermissionError = not ours
            assert time.monotonic() < deadline, f"child {pid} leaked"
            time.sleep(0.1)


@pytest.mark.slow
@pytest.mark.parametrize("n", [2, 4])
def test_spawned_cluster_sigkill_fail_fast(stores, n):
    """SIGKILL a rank mid-superstep: the parent must notice within the
    poll loop (not the transport timeout), raise ClusterFailure, and
    reap every child in bounded time."""
    unweighted, _ = stores
    plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=2,
                                      rank=1, kind="sigkill"),))
    cfg = ClusterConfig(
        num_servers=n, on_failure="fail",
        engine=EngineConfig(max_supersteps=SS, fault_plan=plan),
        timeout_seconds=60, launch_timeout_seconds=240)
    t0 = time.monotonic()
    with pytest.raises(ClusterFailure) as ei:
        run_cluster(unweighted, [PageRank()], cfg)
    assert time.monotonic() - t0 < 120          # bounded, not a hang
    assert ei.value.dead_ranks == [1]
    assert not ei.value.preempted
    assert len(ei.value.pids) == n
    _assert_no_live_children(ei.value.pids)


@pytest.mark.slow
def test_spawned_cluster_kill_restart_resume_bit_identical(stores, tmp_path):
    """The tentpole acceptance drill: hard-kill rank 1 at superstep 4,
    supervised restart resumes from the boundary checkpoint, and all six
    apps still answer byte-for-byte like the uninterrupted run."""
    for root, weighted in zip(stores, (False, True)):
        progs = _apps_for(weighted)
        refs = [_reference(root, p, 2) for p in progs]
        ck = str(tmp_path / f"ck_{int(weighted)}")
        # killing at superstep 4 guarantees the step-2 boundary published:
        # rank 1 only reaches 4 after rank 0's superstep-3 frames, which
        # are sent strictly after rank 0's boundary-2 save
        plan = FaultPlan(
            specs=(FaultSpec(site="superstep", superstep=4, rank=1,
                             kind="kill"),),
            marker_dir=str(tmp_path / f"mk_{int(weighted)}"))
        cfg = ClusterConfig(
            num_servers=2, on_failure="restart", max_restarts=2,
            engine=EngineConfig(max_supersteps=SS, checkpoint_dir=ck,
                                checkpoint_every=2, fault_plan=plan),
            timeout_seconds=60, launch_timeout_seconds=600)
        out = run_cluster(root, progs, cfg)
        assert out.restarts == 1
        assert out.final_servers == 2
        assert out.verified
        # prog 0 resumed mid-stream (its post-restart history is shorter
        # than the global superstep count)
        assert len(out.results[0].history) < out.results[0].supersteps
        for a, p in enumerate(progs):
            assert np.array_equal(out.results[a].values, refs[a].values), p
            assert out.results[a].supersteps == refs[a].supersteps
            assert out.results[a].converged == refs[a].converged


@pytest.mark.slow
def test_spawned_cluster_shrink_resize(stores, tmp_path):
    """Elastic mid-run resize: kill a rank at N=4, supervision resumes
    with the 3 survivors (remapped assignment), same answers."""
    unweighted, _ = stores
    ref = _reference(unweighted, PageRank(), 4)
    plan = FaultPlan(
        specs=(FaultSpec(site="superstep", superstep=4, rank=2,
                         kind="kill"),),
        marker_dir=str(tmp_path / "mk"))
    cfg = ClusterConfig(
        num_servers=4, on_failure="shrink", max_restarts=2,
        engine=EngineConfig(max_supersteps=SS,
                            checkpoint_dir=str(tmp_path / "ck"),
                            checkpoint_every=2, fault_plan=plan),
        timeout_seconds=60, launch_timeout_seconds=600)
    out = run_cluster(unweighted, [PageRank()], cfg)
    assert out.restarts == 1
    assert out.final_servers == 3
    assert np.array_equal(out.results[0].values, ref.values)
    assert out.results[0].supersteps == ref.supersteps


@pytest.mark.slow
def test_spawned_cluster_preemption_saves_and_resumes(stores, tmp_path):
    """Spot-reclaim drill: a SIGTERM'd (preemptible) rank checkpoints at
    the barrier and exits cleanly; the restart resumes bit-identically —
    no periodic checkpoints needed, the preemption save is the resume
    point."""
    unweighted, _ = stores
    ref = _reference(unweighted, PageRank(), 2)
    plan = FaultPlan(
        specs=(FaultSpec(site="superstep", superstep=4, rank=0,
                         kind="preempt"),),
        marker_dir=str(tmp_path / "mk"))
    cfg = ClusterConfig(
        num_servers=2, on_failure="restart", max_restarts=2,
        engine=EngineConfig(max_supersteps=SS,
                            checkpoint_dir=str(tmp_path / "ck"),
                            checkpoint_every=0, preemptible=True,
                            fault_plan=plan),
        timeout_seconds=60, launch_timeout_seconds=600)
    out = run_cluster(unweighted, [PageRank()], cfg)
    assert out.restarts == 1
    # resumed exactly at the preemption boundary (superstep 5)
    assert len(out.results[0].history) == out.results[0].supersteps - 5
    assert np.array_equal(out.results[0].values, ref.values)
    assert out.results[0].supersteps == ref.supersteps


@pytest.mark.slow
def test_spawned_cluster_fail_fast_exceeding_restart_budget(stores):
    """A not-once fault that kills every attempt must exhaust
    max_restarts and surface the ClusterFailure (never loop forever)."""
    unweighted, _ = stores
    plan = FaultPlan(specs=(FaultSpec(site="superstep", superstep=1,
                                      rank=0, kind="kill", once=False),))
    cfg = ClusterConfig(
        num_servers=2, on_failure="restart", max_restarts=1,
        engine=EngineConfig(max_supersteps=SS, fault_plan=plan),
        timeout_seconds=60, launch_timeout_seconds=240)
    with pytest.raises(ClusterFailure):
        run_cluster(unweighted, [PageRank()], cfg)


# ---------------------------------------------------------------------------
# Scheduler / elastic units backing the cluster runtime
# ---------------------------------------------------------------------------

def test_rebalance_assignment_noop_when_balanced():
    from repro.runtime.scheduler import rebalance_assignment

    asg = [[0, 2], [1, 3]]
    edges = np.array([10, 10, 10, 10])
    assert rebalance_assignment(asg, edges, [1.0, 1.1]) is None
    assert rebalance_assignment([[0], [1]], edges[:2], [0.0, 0.0]) is None


def test_rebalance_assignment_moves_off_straggler():
    from repro.runtime.scheduler import rebalance_assignment

    asg = [[0, 1, 2, 3], [4, 5, 6, 7]]
    edges = np.array([100, 90, 80, 70, 10, 10, 10, 10])
    out = rebalance_assignment(asg, edges, [10.0, 1.0])
    assert out is not None
    new, moved = out
    assert moved > 0
    assert len(new[0]) < 4
    # partition stays complete and disjoint
    flat = sorted(t for a in new for t in a)
    assert flat == list(range(8))
    # deterministic: same inputs, same output
    again, _ = rebalance_assignment(asg, edges, [10.0, 1.0])
    assert again == new


def test_make_cluster_mesh_requires_devices():
    from repro.launch.mesh import make_cluster_mesh

    # single-CPU test env: a 1-server mesh works, a wide one explains how
    mesh = make_cluster_mesh(1)
    assert mesh.axis_names == ("server",)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_cluster_mesh(99)


def test_remap_assignment_shrink_and_grow():
    from repro.runtime.elastic import remap_assignment

    edges = np.array([50, 40, 30, 20, 10, 5])
    old = [[0, 3], [1, 4], [2, 5]]
    shrunk = remap_assignment(old, 2, edges)
    assert sorted(t for a in shrunk for t in a) == list(range(6))
    # survivors keep their original tiles (cache warmth): the orphans from
    # removed rank 2 land on the least-loaded survivors without displacing
    # the survivors' own tiles in this balanced case
    assert set(old[0]) <= set(shrunk[0])
    assert set(old[1]) <= set(shrunk[1])
    grown = remap_assignment(shrunk, 3, edges)
    assert sorted(t for a in grown for t in a) == list(range(6))
    assert all(len(a) > 0 for a in grown)
    # deterministic
    assert remap_assignment(old, 2, edges) == shrunk
