"""Mid-run query admission (DESIGN.md §13).

The acceptance invariant: a query admitted into a retired ``[V, Q]``
slot at superstep k is **bit-identical** to a fresh single-query run —
per-column math is independent of batch context, the admitted column
runs one forced all-dirty superstep, and its per-query superstep count
is measured from its own admission.  Covered across serial / pipelined
/ ooc-vstate engines and an in-process N=2 cluster, plus the session
API properties: slot reuse never leaks prior column state, drains
freeze partial values, and a session with zero live columns keeps
stepping until scheduled admissions arrive.
"""
import dataclasses
import tempfile
import threading

import numpy as np
import pytest

from repro.core import transport as T
from repro.core.apps import APPS
from repro.core.distributed import ClusterExchange
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe
from repro.graphio.formats import TileStore

SS = 120   # enough for every app here to converge on the test graphs


def _make_store(weighted, seed=7, nv=220, ne=1400, tile_size=96):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    src, dst = src[i], dst[i]
    val = (rng.uniform(0.1, 10.0, len(src)).astype(np.float32)
           if weighted else None)
    root = tempfile.mkdtemp(prefix=f"admit_store_{int(weighted)}_")
    spe.preprocess_arrays(src, dst, val, nv, TileStore(root), tile_size)
    return root


@pytest.fixture(scope="module")
def stores():
    return _make_store(False), _make_store(True)


# (app, initial seeds, admitted seed, admission superstep)
CASES = [
    ("ppr", (1, 7, 50), 77, 2),
    ("msbfs", (2, 11, 60), 77, 1),
    ("landmarks", (0, 9, 33), 77, 1),
]

MODES = {
    "serial": {},
    "pipelined": dict(pipeline=True),
    "ooc": dict(vertex_memory_budget=48 * 1024, num_intervals=4),
}


def _root(stores, app):
    return stores[1] if app == "landmarks" else stores[0]


def _cfg(**kw):
    return EngineConfig(num_servers=2, max_supersteps=SS, **kw)


def _run(root, prog, **kw):
    eng = OutOfCoreEngine(TileStore(root), _cfg(**kw))
    return eng.run(prog)


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("app,init,seed,at", CASES,
                         ids=[c[0] for c in CASES])
def test_admitted_query_bit_identical(stores, app, init, seed, at, mode):
    root = _root(stores, app)
    kw = MODES[mode]
    fresh = _run(root, APPS[app]().with_queries((seed,)), **kw)
    assert fresh.converged
    batch = _run(root, APPS[app]().with_queries(init),
                 admit_plan=((at, (seed,)),), **kw)
    gq = len(init)           # admitted query renumbers after the batch
    assert np.array_equal(batch.values[:, gq], fresh.values[:, 0])
    # superstep accounting is relative to its own admission: same count
    # as the fresh run even though it started mid-stream
    assert batch.per_query_supersteps[gq] == fresh.per_query_supersteps[0]
    # the original batch is untouched by the splice
    ref = _run(root, APPS[app]().with_queries(init), **kw)
    assert np.array_equal(batch.values[:, :gq], ref.values)


def test_admission_cluster_n2(stores):
    """Rank 0 ships the admission record in its frame header; both ranks
    splice identically and match the fresh single-query run."""
    root = stores[0]
    fresh = _run(root, APPS["msbfs"]().with_queries((77,)))
    n = 2
    run_dir = tempfile.mkdtemp(prefix="admit_rings_")
    T.create_ring_files(run_dir, n)
    outs = [None] * n
    errs = [None] * n

    def worker(r):
        try:
            store = TileStore(root)
            store.load_meta()
            eng = OutOfCoreEngine(store, _cfg(
                server_rank=r, admit_plan=((1, (77,)),)))
            tr = T.RingTransport(r, n, run_dir)
            ex = ClusterExchange(tr, assignment=eng.assignment,
                                 edges_per_tile=eng.plan.edges_per_tile,
                                 timeout=60.0)
            eng.exchange = ex
            try:
                outs[r] = eng.run(APPS["msbfs"]().with_queries((2, 11)))
            finally:
                ex.close()
                tr.close()
        except BaseException as exc:    # pragma: no cover - surfaced below
            errs[r] = exc

    threads = [threading.Thread(target=worker, args=(r,))
               for r in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300.0)
    for r, e in enumerate(errs):
        assert e is None, f"rank {r}: {e!r}"
    for r in range(n):
        assert np.array_equal(outs[r].values[:, 2], fresh.values[:, 0])
        assert (outs[r].per_query_supersteps[2]
                == fresh.per_query_supersteps[0])
        # the admission barrier is cluster-wide and deterministic
        assert [h.admitted_queries for h in outs[r].history] == \
               [h.admitted_queries for h in outs[0].history]
    assert np.array_equal(outs[0].values, outs[1].values)


# ---------------------------------------------------------------------------
# session API properties


def _session(root, prog, *, q_slots=None, **kw):
    eng = OutOfCoreEngine(TileStore(root), _cfg(**kw))
    return eng.open_session(prog, q_slots=q_slots)


@pytest.mark.parametrize("ooc", [False, True], ids=["mem", "ooc"])
def test_slot_reuse_never_leaks(stores, ooc):
    """admit -> retire -> admit reusing the same physical slot: the new
    column must match a fresh run exactly (no residue from the prior
    occupant's values, aux, or convergence state)."""
    kw = (dict(vertex_memory_budget=48 * 1024, num_intervals=4)
          if ooc else {})
    root = stores[0]
    seeds = [3, 41, 77, 105, 9]
    fresh = {s: _run(root, APPS["msbfs"]().with_queries((s,)), **kw)
             for s in seeds}
    sess = _session(root, APPS["msbfs"]().with_queries((seeds[0],)),
                    q_slots=1, **kw)
    for s in seeds[1:]:
        sess.admit([s])
    while not sess.finished:
        stats = sess.step()
        # one live column max: each admission reuses the freed slot
        assert stats.active_queries <= 1
    res = sess.result()
    assert res.converged
    for gq, s in enumerate(seeds):
        assert np.array_equal(res.values[:, gq], fresh[s].values[:, 0]), s
        assert (res.per_query_supersteps[gq]
                == fresh[s].per_query_supersteps[0]), s


def test_drain_freezes_partial_column(stores):
    root = stores[0]
    prog = APPS["ppr"]().with_queries((1, 7))
    sess = _session(root, prog)
    sess.step()
    sess.step()
    sess.drain([1])
    stats = sess.step()
    assert stats.drained_queries == (1,)
    assert sess.active_queries == (0,)
    # a drained query never reports a convergence superstep count
    assert sess.query_supersteps(1) == -1
    partial = sess.query_result(1)
    while not sess.finished:
        sess.step()
    res = sess.result()
    # the frozen partial column is what the result carries for qid 1
    assert np.array_equal(res.values[:, 1], partial)
    # ...and qid 0 still converged to the batch-run answer
    ref = _run(root, APPS["ppr"]().with_queries((1, 7)))
    assert np.array_equal(res.values[:, 0], ref.values[:, 0])


def test_zero_live_columns_waits_for_scheduled_admission(stores):
    """A session whose columns all retired keeps stepping (no compute,
    barrier only) until a scheduled admission refills it — and the late
    query still matches a fresh run bit-for-bit."""
    root = stores[0]
    fresh = _run(root, APPS["msbfs"]().with_queries((77,)))
    gap_at = 20      # well after the 3-ish supersteps msbfs needs
    res = _run(root, APPS["msbfs"]().with_queries((2,)),
               admit_plan=((gap_at, (77,)),))
    assert res.converged
    gap = [h for h in res.history if h.active_queries == 0]
    assert gap, "expected idle supersteps between retirement and admission"
    assert all(h.tiles_processed == 0 and h.updated_pairs == 0
               for h in gap)
    assert np.array_equal(res.values[:, 1], fresh.values[:, 0])
    assert res.per_query_supersteps[1] == fresh.per_query_supersteps[0]


def test_admit_respects_slot_cap(stores):
    """Live admissions beyond q_slots queue until retirement frees a
    slot; scheduled plan entries ride along; nothing is lost."""
    root = stores[0]
    sess = _session(root, APPS["msbfs"]().with_queries((2, 11)),
                    q_slots=2)
    gqs = sess.admit([77, 105, 9])
    assert gqs == [2, 3, 4]
    assert sess.free_slots == 0
    seen = set()
    while not sess.finished:
        stats = sess.step()
        assert stats.active_queries <= 2
        seen.update(stats.admitted_queries)
    assert seen == {2, 3, 4}
    res = sess.result()
    assert res.converged
    fresh = _run(root, APPS["msbfs"]().with_queries((77,)))
    assert np.array_equal(res.values[:, 2], fresh.values[:, 0])


def test_checkpoint_resume_preserves_admission_lineage(stores, tmp_path):
    """A session checkpointed mid-serve resumes with query lineage,
    renumbering, and per-query accounting intact (manifest ``queries`` /
    ``admitted_at`` / ``next_qid``)."""
    root = stores[0]
    ck = str(tmp_path / "ck")
    cfg = _cfg(checkpoint_dir=ck, admit_plan=((1, (77,)),))
    eng = OutOfCoreEngine(TileStore(root), cfg)
    sess = eng.open_session(APPS["ppr"]().with_queries((1, 7)))
    for _ in range(4):
        sess.step()
    sess.checkpoint()
    sess.close()
    loaded = eng.ckpt.load_graph()
    assert loaded.live_queries().keys() == {0, 1, 2}
    assert loaded.live_queries()[2] == 77
    # resume and run to completion: identical to the uninterrupted run
    cfg2 = dataclasses.replace(cfg, resume=True)
    eng2 = OutOfCoreEngine(TileStore(root), cfg2)
    sess2 = eng2.open_session(APPS["ppr"]().with_queries((1, 7)))
    assert sess2.superstep == 4
    assert sess2.query_seeds[2] == 77
    while not sess2.finished:
        sess2.step()
    res = sess2.result()
    clean = _run(root, APPS["ppr"]().with_queries((1, 7)),
                 admit_plan=((1, (77,)),))
    assert np.array_equal(res.values, clean.values)
    assert np.array_equal(res.per_query_supersteps,
                          clean.per_query_supersteps)
