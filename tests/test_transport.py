"""Transport round-trip property tests (DESIGN.md §11).

Every BroadcastRecord mode — dense bitvec, sparse pairs, per-interval
sections, multi-query column modes — must cross both transports
byte-identically (value bytes round-trip exactly; that is what keeps
cluster results bit-identical), including the zlib-fallback codec when
zstandard is absent.
"""
import os
import tempfile
import threading

import numpy as np
import pytest

from repro import compat
from repro.core import comm
from repro.core import transport as T


def _rand_updates(rng, nv, density, qa=None):
    """Random sparse update triple at the given per-cell density."""
    if qa is None:
        upd = rng.random(nv) < density
        idx = np.nonzero(upd)[0].astype(np.int64)
        vals = rng.normal(size=len(idx)).astype(np.float32)
        return idx, vals, None
    mask = rng.random((nv, qa)) < density
    vmask = mask.any(axis=1)
    idx = np.nonzero(vmask)[0].astype(np.int64)
    m = mask[idx]
    vals = np.where(m, rng.normal(size=m.shape), 0.0).astype(np.float32)
    return idx, vals, m


def _assert_roundtrip(idx, vals, mask, dec):
    order = np.argsort(dec.idx)
    assert np.array_equal(dec.idx[order], idx)
    if mask is None:
        assert dec.mask is None
        assert np.array_equal(dec.vals[order], vals)
    else:
        assert np.array_equal(dec.mask[order], mask)
        got = np.where(dec.mask[order], dec.vals[order], 0.0)
        assert np.array_equal(got, np.where(mask, vals, 0.0))


@pytest.mark.parametrize("mode", ["dense", "sparse", "hybrid"])
@pytest.mark.parametrize("density", [0.0, 0.02, 0.4, 0.9, 1.0])
def test_flat_1d_roundtrip(mode, density):
    rng = np.random.default_rng(int(density * 100) + len(mode))
    idx, vals, _ = _rand_updates(rng, 700, density)
    frame, header = T.encode_frame(idx, vals, None, 700, mode=mode)
    dec = T.decode_frame(frame)
    _assert_roundtrip(idx, vals, None, dec)
    assert dec.header["mode"] in ("dense", "sparse")
    assert header["wire_bytes"] == len(frame)
    if mode != "hybrid" and density > 0:
        assert dec.header["mode"] == mode


@pytest.mark.parametrize("compressor", ["none", "zstd-1", "zstd-3"])
def test_codec_label_reflects_fallback(compressor):
    # zlib-fallback-when-zstd-absent: the recorded codec must be what ran
    rng = np.random.default_rng(3)
    idx, vals, _ = _rand_updates(rng, 300, 0.3)
    frame, header = T.encode_frame(idx, vals, None, 300,
                                   compressor=compressor)
    dec = T.decode_frame(frame)
    _assert_roundtrip(idx, vals, None, dec)
    if compressor == "none":
        assert header["codec"] == "none"
    else:
        want = "zstd" if compat.HAVE_ZSTD else "zlib"
        assert header["codec"].startswith(want)


@pytest.mark.parametrize("mode", ["dense", "sparse", "hybrid"])
def test_multi_query_column_modes_roundtrip(mode):
    rng = np.random.default_rng(11)
    nv, qa = 400, 5
    # per-column densities spanning the threshold -> mixed column modes
    mask = rng.random((nv, qa)) < np.array([0.9, 0.01, 0.5, 0.0, 0.2])
    vmask = mask.any(axis=1)
    idx = np.nonzero(vmask)[0].astype(np.int64)
    m = mask[idx]
    vals = np.where(m, rng.normal(size=m.shape), 0.0).astype(np.float32)
    frame, header = T.encode_frame(idx, vals, m, nv, mode=mode)
    dec = T.decode_frame(frame)
    _assert_roundtrip(idx, vals, m, dec)
    if mode == "dense":
        assert all(q == "dense" for q in dec.header["qmodes"])
    if mode == "sparse":
        assert all(q == "sparse" for q in dec.header["qmodes"])


@pytest.mark.parametrize("qa", [None, 3])
def test_interval_sections_roundtrip(qa):
    rng = np.random.default_rng(5)
    nv = 600
    splitter = np.array([0, 100, 250, 280, 500, 600], np.int64)
    # cluster the updates so some intervals stay clean
    idx, vals, mask = _rand_updates(rng, nv, 0.15, qa)
    keep = (idx < 250) | (idx >= 500)
    idx = idx[keep]
    vals = vals[keep]
    mask = mask[keep] if mask is not None else None
    frame, header = T.encode_frame(idx, vals if qa is None else vals,
                                   mask, nv, splitter=splitter)
    dec = T.decode_frame(frame)
    _assert_roundtrip(idx, vals, mask, dec)
    assert dec.header["kind"] == "intervals"
    touched = set(np.searchsorted(splitter, idx, side="right") - 1)
    assert {s["iv"] for s in dec.header["sections"]} == touched
    # clean intervals ship zero sections
    assert len(dec.header["sections"]) == len(touched)


def test_empty_updates_roundtrip():
    for splitter in (None, np.array([0, 50, 100], np.int64)):
        frame, _ = T.encode_frame(np.zeros(0, np.int64),
                                  np.zeros(0, np.float32), None, 100,
                                  splitter=splitter)
        dec = T.decode_frame(frame)
        assert len(dec.idx) == 0 and len(dec.vals) == 0


@pytest.mark.parametrize("qa,splitter", [
    (None, None), (4, None),
    (None, "iv"), (4, "iv"),
])
def test_hybrid_never_larger_than_pure_modes(qa, splitter):
    """The measured-size hybrid ships the smallest complete frame."""
    rng = np.random.default_rng(17)
    nv = 512
    sp = np.linspace(0, nv, 5).astype(np.int64) if splitter else None
    for density in (0.01, 0.2, 0.39, 0.41, 0.8):
        idx, vals, mask = _rand_updates(rng, nv, density, qa)
        sizes = {}
        for mode in ("dense", "sparse", "hybrid"):
            frame, _ = T.encode_frame(idx, vals, mask, nv,
                                      splitter=sp, mode=mode)
            sizes[mode] = len(frame)
        assert sizes["hybrid"] <= min(sizes["dense"], sizes["sparse"])


def test_frame_bytes_deterministic():
    """Frames are a pure function of the update set (control stats live in
    the exchange envelope) — same updates, same bytes."""
    rng = np.random.default_rng(23)
    idx, vals, _ = _rand_updates(rng, 300, 0.3)
    f1, _ = T.encode_frame(idx, vals, None, 300)
    f2, _ = T.encode_frame(idx.copy(), vals.copy(), None, 300)
    assert f1 == f2


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------

def _transport_pair(kind, tmp):
    if kind == "shm":
        T.create_ring_files(tmp, 2, capacity=1 << 12)
    a = T.make_transport(kind, 0, 2, tmp)
    b = T.make_transport(kind, 1, 2, tmp)
    return a, b


@pytest.mark.parametrize("kind", ["shm", "tcp"])
def test_transport_ordered_delivery_and_large_messages(kind):
    tmp = tempfile.mkdtemp(prefix=f"transport_{kind}_")
    a, b = _transport_pair(kind, tmp)
    try:
        # includes messages larger than the shm ring capacity (chunked)
        msgs = [os.urandom(n) for n in (1, 3, 5000, 20000, 7)]
        done = threading.Event()

        def send():
            for m in msgs:
                a.send(1, m)
            done.set()

        t = threading.Thread(target=send)
        t.start()
        got = []
        while len(got) < len(msgs):
            item = b.recv(timeout=10.0)
            assert item is not None, "transport recv timed out"
            src, payload = item
            assert src == 0
            got.append(payload)
        t.join(timeout=10.0)
        assert done.is_set()
        assert got == msgs
        assert b.recv(timeout=0.05) is None
    finally:
        a.close()
        b.close()


@pytest.mark.parametrize("kind", ["shm", "tcp"])
def test_frames_cross_transport_byte_identically(kind):
    tmp = tempfile.mkdtemp(prefix=f"frames_{kind}_")
    a, b = _transport_pair(kind, tmp)
    try:
        rng = np.random.default_rng(29)
        cases = [
            _rand_updates(rng, 300, 0.9),            # dense
            _rand_updates(rng, 300, 0.01),           # sparse
            _rand_updates(rng, 300, 0.3, qa=3),      # multi-query mixed
        ]
        splitter = np.array([0, 100, 200, 300], np.int64)
        frames = []
        for k, (idx, vals, mask) in enumerate(cases):
            sp = splitter if k == 1 else None        # one interval frame
            frame, _ = T.encode_frame(idx, vals, mask, 300, splitter=sp)
            frames.append(frame)
            a.send(1, frame)
        for k, (idx, vals, mask) in enumerate(cases):
            src, payload = b.recv(timeout=10.0)
            assert payload == frames[k]              # byte-identical wire
            dec = T.decode_frame(payload)
            _assert_roundtrip(idx, vals, mask, dec)
    finally:
        a.close()
        b.close()


def test_ring_channel_wraparound():
    tmp = tempfile.mkdtemp(prefix="ring_wrap_")
    path = os.path.join(tmp, "ch.buf")
    T.RingChannel.create(path, capacity=64)
    w = T.RingChannel(path, writer=True)
    r = T.RingChannel(path, writer=False)
    rng = np.random.default_rng(31)
    try:
        for trial in range(50):   # cursors wrap the 64-byte ring many times
            msg = rng.bytes(int(rng.integers(1, 50)))
            w.send_msg(msg, timeout=5.0)
            assert r.recv_msg(timeout=5.0) == msg
        assert r.recv_msg(timeout=0.01) is None
    finally:
        w.close()
        r.close()


def test_make_transport_rejects_unknown():
    with pytest.raises(ValueError, match="unknown transport"):
        T.make_transport("carrier-pigeon", 0, 2, "/tmp")
