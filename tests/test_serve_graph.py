"""Online graph-query serving (serve.graph_service, DESIGN.md §13).

Covers the service surface over the session admission machinery: results
match fresh batch runs bit-for-bit, deadlines drain to timeout tickets,
SIGTERM drains gracefully (in-process flag drill + a real subprocess
drill through ``launch.graph --serve``), checkpoint-drain + resume keeps
in-flight queries alive across a restart, and the serve-engine sampling
regression (``_sample`` reseeded per decode step, not per slot count).
"""
import dataclasses
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np
import pytest

from repro.core.apps import APPS
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe
from repro.graphio.formats import TileStore
from repro.serve.graph_service import GraphService, QueryTicket

SS = 120


def _make_store(nv=220, ne=1400, tile_size=96, seed=7):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    root = tempfile.mkdtemp(prefix="serve_store_")
    spe.preprocess_arrays(src[i], dst[i], None, nv, TileStore(root),
                          tile_size)
    store = TileStore(root)
    store.load_meta()
    return store


@pytest.fixture(scope="module")
def store():
    return _make_store()


def _cfg(**kw):
    return EngineConfig(num_servers=2, max_supersteps=SS, **kw)


def _fresh(store, app, seed):
    eng = OutOfCoreEngine(TileStore(store.root), _cfg())
    return eng.run(APPS[app]().with_queries((seed,)))


def _drain_and_join(svc, timeout=120):
    svc.request_drain()
    svc.join(timeout)
    assert svc._thread is not None and not svc._thread.is_alive()


def test_service_results_match_fresh_runs(store):
    svc = GraphService(store, _cfg(), q_slots=3, min_fill=2,
                       max_wait_s=0.01, max_supersteps=SS)
    svc.start()
    work = [("ppr", 3), ("msbfs", 11), ("ppr", 77), ("msbfs", 42),
            ("ppr", 105)]
    tickets = [svc.submit(app, seed) for app, seed in work]
    for t in tickets:
        assert t.wait(120), t
    _drain_and_join(svc)
    assert svc.stats["done"] == len(work)
    assert svc.stats["timeout"] == svc.stats["failed"] == 0
    for t in tickets:
        assert t.status == "done"
        ref = _fresh(store, t.app, t.seed)
        # online-served query == fresh batch run, bit for bit
        assert np.array_equal(t.result, ref.values[:, 0]), (t.app, t.seed)
        assert t.supersteps == ref.per_query_supersteps[0]
        assert t.total_s >= t.service_s >= 0
        assert t.queue_wait_s >= 0
    s = svc.latency_summary()
    assert s["count"] == len(work)
    assert s["p99_ms"] >= s["p50_ms"] > 0


def test_deadline_drains_to_timeout(store):
    svc = GraphService(store, _cfg(), q_slots=2, max_wait_s=0.01,
                       max_supersteps=SS)
    svc.start()
    slow = svc.submit("ppr", 3, deadline_s=0.0)      # overdue on arrival
    ok = svc.submit("msbfs", 11)
    assert slow.wait(120) and ok.wait(120)
    _drain_and_join(svc)
    assert slow.status == "timeout"
    assert slow.supersteps == -1          # drained, never converged
    assert slow.result is not None        # partial column still delivered
    assert ok.status == "done"
    assert svc.stats["timeout"] == 1 and svc.stats["done"] == 1


def test_sigterm_flag_drains_in_flight_work(store):
    """The in-process half of the SIGTERM drill: latch the guard flag the
    signal handler would set; the loop must stop admitting and finish
    in-flight queries before returning."""
    svc = GraphService(store, _cfg(), q_slots=2, max_wait_s=0.01,
                       max_supersteps=SS)
    svc.start()
    tickets = [svc.submit("ppr", s) for s in (3, 77)]
    while svc.stats["supersteps"] < 1:     # in-flight for real
        time.sleep(0.005)
    svc.guard.triggered = True             # what SIGTERM does
    svc.join(120)
    assert all(t.status == "done" for t in tickets)
    with pytest.raises(RuntimeError):
        svc.submit("ppr", 9)               # drained services reject work


def test_sigterm_subprocess_drill(store):
    """The real drill: SIGTERM a live ``launch.graph --serve`` process —
    it must drain gracefully and exit 0."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.graph", "--serve",
         "--vertices", "300", "--edges", "1500", "--tile-size", "128",
         "--servers", "1", "--serve-requests", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    try:
        for line in p.stdout:
            if "serving" in line:
                break
        time.sleep(0.3)
        p.send_signal(signal.SIGTERM)
        out = p.stdout.read()
        assert p.wait(timeout=120) == 0
        assert "drained" in out
    finally:
        if p.poll() is None:      # pragma: no cover - cleanup on failure
            p.kill()


def test_checkpoint_drain_and_resume(store, tmp_path):
    """drain_mode='checkpoint': SIGTERM-style drain checkpoints live
    sessions with their query lineage; a resumed service re-registers the
    in-flight queries and finishes them to the fresh-run answers."""
    ck = str(tmp_path / "svc_ck")
    cfg = _cfg(checkpoint_dir=ck)
    svc = GraphService(store, cfg, q_slots=2, max_wait_s=0.01,
                       max_supersteps=SS, drain_mode="checkpoint")
    svc.start()
    seeds = (3, 77)
    tickets = [svc.submit("ppr", s) for s in seeds]
    while svc.stats["supersteps"] < 2:      # mid-flight, not converged
        time.sleep(0.005)
    svc.request_drain()
    svc.join(120)
    assert all(t.status == "failed" for t in tickets)   # not resolved here
    assert os.path.isdir(os.path.join(ck, "ppr"))

    svc2 = GraphService(store, cfg, q_slots=2, max_wait_s=0.01,
                        max_supersteps=SS, resume=True)
    # the resumed service re-registered the live columns from the
    # manifest lineage before serving anything new
    resumed = {t.seed: t for app in svc2._live
               for t in svc2._live[app].values()}
    assert set(resumed) == set(seeds)
    svc2.start()
    for t in resumed.values():
        assert t.wait(120), t
    _drain_and_join(svc2)
    for s in seeds:
        t = resumed[s]
        assert t.status == "done"
        ref = _fresh(store, "ppr", s)
        assert np.array_equal(t.result, ref.values[:, 0]), s
        assert t.supersteps == ref.per_query_supersteps[0]


def test_submit_rejects_unbatched_app(store):
    svc = GraphService(store, _cfg())
    with pytest.raises(ValueError):
        svc.submit("pagerank", 0)


def test_ticket_latency_components():
    t = QueryTicket(rid=0, app="ppr", seed=1, submitted_s=1.0,
                    admitted_s=3.0, finished_s=7.5)
    assert t.queue_wait_s == 2.0
    assert t.service_s == 4.5
    assert t.total_s == 6.5


# ---------------------------------------------------------------------------
# serve-engine sampling regression (the [V,Q]-slot analogue lives above;
# this is the token-slot engine's per-step reseed fix)


def test_serve_engine_sample_reseeds_per_step():
    """_sample used to seed from rid + len(self.slot_out) — the FIXED
    slot-list length — so every decode step of a request drew the same
    sample.  It must draw from (rid, step): steps differ, reruns repeat."""
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine.__new__(ServeEngine)    # no model needed for _sample
    eng.slot_out = [[] for _ in range(4)]
    req = Request(rid=5, prompt=np.zeros(1, np.int32), temperature=1.0)
    logits = np.zeros(64, np.float32)         # uniform: sampling is pure RNG
    draws = [eng._sample(logits, req, step=s) for s in range(12)]
    assert len(set(draws)) > 1, "every decode step drew the same token"
    # deterministic per (rid, step): a rerun reproduces the sequence
    assert draws == [eng._sample(logits, req, step=s) for s in range(12)]
    # greedy path ignores the rng entirely
    g = Request(rid=5, prompt=np.zeros(1, np.int32), temperature=0.0)
    peaked = np.zeros(64, np.float32)
    peaked[17] = 9.0
    assert eng._sample(peaked, g, step=3) == 17
