"""HTTP frontend for the graph-query service (serve/http.py, DESIGN.md §16).

The end-to-end harness the serving stack is judged by:

  * a real ``launch.graph --serve-http`` subprocess driven by threaded
    ``urllib`` clients — HTTP-served results must be **byte-identical**
    to direct ``GraphService.submit`` and to a clean offline ``run()``;
  * SIGTERM mid-load: in-flight queries finish, new submits get 503,
    the stats invariant ``submitted == done+timeout+failed+refused``
    holds at drain, exit code 0;
  * property-based request-schema tests (hypothesis, with the in-repo
    shim fallback): arbitrary bodies never crash the handler thread —
    every malformed request is a structured 4xx, valid requests
    round-trip their ticket fields exactly;
  * ``site=http_response`` fault injection: a dropped response leaves
    service state consistent and a retry of the same rid observes the
    completed result; a delayed response arrives late but intact.
"""
import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.apps import APPS
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe
from repro.graphio.formats import TileStore
from repro.runtime.faults import FaultPlan, FaultSpec
from repro.serve.graph_service import GraphService
from repro.serve.http import (HttpFrontend, decode_array, encode_array,
                              parse_query_body, BadRequest)

SS = 200
NV = 220


def _make_store(nv=NV, ne=1400, tile_size=96, seed=7):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne)
    dst = rng.integers(0, nv, ne)
    key = src * nv + dst
    _, i = np.unique(key, return_index=True)
    root = tempfile.mkdtemp(prefix="serve_http_store_")
    spe.preprocess_arrays(src[i], dst[i], None, nv, TileStore(root),
                          tile_size)
    store = TileStore(root)
    store.load_meta()
    return store


#: lazily-built singletons shared between pytest fixtures and the
#: hypothesis properties (the shim's @given cannot inject fixtures)
_LAZY: dict = {}


def _store_singleton():
    if "store" not in _LAZY:
        _LAZY["store"] = _make_store()
    return _LAZY["store"]


@pytest.fixture(scope="module")
def store():
    return _store_singleton()


def _schema_frontend():
    """An HTTP frontend over an un-started service: validation and
    ticket bookkeeping run for real, nothing executes (schema tests
    don't need results)."""
    if "fe" not in _LAZY:
        svc = GraphService(_store_singleton(), _cfg(), q_slots=2,
                           max_wait_s=0.01)
        _LAZY["fe"] = HttpFrontend(svc).start()
    return _LAZY["fe"]


def _cfg(**kw):
    return EngineConfig(num_servers=2, max_supersteps=SS, **kw)


# -- tiny urllib client ------------------------------------------------------

def _post(base, body, timeout=30):
    """POST /v1/query; returns (status, decoded json)."""
    data = body if isinstance(body, bytes) else json.dumps(body).encode()
    req = urllib.request.Request(base + "/v1/query", data=data,
                                 headers={"Content-Type":
                                          "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(base, path, timeout=30):
    try:
        with urllib.request.urlopen(base + path, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _poll(base, rid, timeout=120):
    """Poll GET /v1/query/<rid> until the ticket is terminal."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        code, j = _get(base, f"/v1/query/{rid}")
        assert code == 200, (code, j)
        if j["status"] in ("done", "timeout", "failed"):
            return j
        time.sleep(0.05)
    raise AssertionError(f"rid {rid} never finished")


def _spawn_serve(store, *extra):
    """Start launch.graph --serve-http on the given store; returns
    (process, base_url)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.graph", "--serve-http",
         "--port", "0", "--store", store.root, "--reuse",
         "--servers", "2", "--supersteps", str(SS),
         "--max-wait-ms", "10", *extra],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    port = None
    for line in p.stdout:
        # the listener is bound before this line prints, so it is safe
        # to talk to the server as soon as the port is known
        if line.startswith("serving http on"):
            port = int(line.rsplit(":", 1)[1])
            break
    assert port is not None, "server never printed its port"
    return p, f"http://127.0.0.1:{port}"


# -- end-to-end subprocess harness ------------------------------------------

def test_e2e_http_results_byte_identical(store):
    """HTTP-served results == direct GraphService.submit == clean run(),
    byte for byte, driven by threaded urllib clients against a real
    --serve-http subprocess."""
    work = [("ppr", 3), ("msbfs", 11), ("landmarks", 9), ("ppr", 77),
            ("msbfs", 42), ("landmarks", 130)]
    p, base = _spawn_serve(store, "--result-cache", "32",
                           "--drain-linger-ms", "4000")
    results = {}
    errors = []

    def client(i, app, seed):
        try:
            code, t = _post(base, dict(app=app, seed=seed,
                                       tenant=f"t{i % 2}"))
            assert code == 200, (code, t)
            assert (t["app"], t["seed"]) == (app, seed)
            results[i] = _poll(base, t["rid"])
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append((i, e))

    try:
        threads = [threading.Thread(target=client, args=(i, app, seed))
                   for i, (app, seed) in enumerate(work)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(180)
        assert not errors, errors
        assert len(results) == len(work)

        # terminate cleanly before comparing (frees the store for reuse)
        p.send_signal(signal.SIGTERM)
        out = p.stdout.read()
        assert p.wait(timeout=120) == 0
        assert "drained" in out

        svc = GraphService(store, _cfg(), q_slots=3, max_wait_s=0.01,
                           max_supersteps=SS)
        svc.start()
        direct = [svc.submit(app, seed) for app, seed in work]
        for t in direct:
            assert t.wait(120), t
        svc.request_drain()
        svc.join(120)

        for i, (app, seed) in enumerate(work):
            served = results[i]
            assert served["status"] == "done", served
            via_http = decode_array(served["result"])
            # 1) HTTP == direct submit, byte for byte
            assert np.array_equal(via_http, direct[i].result), (app, seed)
            # 2) HTTP == clean offline run, byte for byte
            eng = OutOfCoreEngine(TileStore(store.root), _cfg())
            ref = eng.run(APPS[app]().with_queries((seed,)))
            assert np.array_equal(via_http, ref.values[:, 0]), (app, seed)
            assert served["supersteps"] == ref.per_query_supersteps[0]
            assert served["total_ms"] >= served["service_ms"] >= 0
    finally:
        if p.poll() is None:  # pragma: no cover - cleanup on failure
            p.kill()


def test_e2e_sigterm_mid_load(store):
    """SIGTERM a loaded server: in-flight queries finish, new submits
    get 503, the drain invariant holds, exit code 0."""
    p, base = _spawn_serve(store, "--drain-linger-ms", "6000")
    try:
        rng = np.random.default_rng(0)
        rids = []
        for i in range(6):
            code, t = _post(base, dict(app="msbfs",
                                       seed=int(rng.integers(NV))))
            assert code == 200
            rids.append(t["rid"])
        # wait until at least one query is actually running
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            code, j = _get(base, f"/v1/query/{rids[0]}")
            if j["status"] != "queued":
                break
            time.sleep(0.02)
        p.send_signal(signal.SIGTERM)
        # new submits must be refused with 503 during the drain window
        saw_503 = False
        for _ in range(200):
            try:
                code, j = _post(base, dict(app="msbfs", seed=1), timeout=5)
            except (urllib.error.URLError, ConnectionError, OSError):
                break              # linger expired — server went away
            if code == 503:
                saw_503 = True
                break
            assert code == 200     # raced the drain latch: accepted
            rids.append(j["rid"])
            time.sleep(0.02)
        assert saw_503, "no submit observed the 503 drain refusal"
        # every accepted query resolves during the linger window
        statuses = [_poll(base, rid, timeout=60)["status"] for rid in rids]
        assert all(s in ("done", "timeout", "failed") for s in statuses)
        assert any(s == "done" for s in statuses)
        # stats invariant at drain: submitted == done+timeout+failed+refused
        code, snap = _get(base, "/v1/stats")
        assert code == 200
        s = snap["stats"]
        assert s["submitted"] == (s["done"] + s["timeout"] + s["failed"]
                                  + s["refused"]), s
        assert s["refused"] >= 1
        out = p.stdout.read()
        assert p.wait(timeout=120) == 0
        assert "drained" in out
    finally:
        if p.poll() is None:  # pragma: no cover - cleanup on failure
            p.kill()


# -- request/response schema properties --------------------------------------

@pytest.fixture(scope="module", autouse=True)
def _close_lazy_frontend():
    yield
    fe = _LAZY.pop("fe", None)
    if fe is not None:
        fe.close()


@given(st.binary(min_size=0, max_size=300))
@settings(max_examples=30, deadline=None)
def test_arbitrary_bodies_never_crash_the_handler(raw):
    """Any byte soup POSTed to /v1/query yields a structured 4xx and the
    server keeps answering."""
    frontend = _schema_frontend()
    code, j = _post(frontend.address, raw)
    assert 400 <= code < 500, (code, j)
    assert "error" in j
    assert _get(frontend.address, "/healthz")[0] == 200


@given(st.integers(-(10 ** 12), 10 ** 12),
       st.sampled_from(["ppr", "msbfs", "landmarks", "pagerank", "",
                        "PPR", 7]),
       st.sampled_from([None, 250.0, -1, 0, float("1e18"), "soon"]))
@settings(max_examples=40, deadline=None)
def test_schema_validation_matches_submit_contract(seed, app, deadline_ms):
    """POST /v1/query accepts exactly the bodies the service contract
    allows: servable app, integer seed inside [0, V), positive bounded
    deadline — everything else is a structured 4xx, never a handler
    crash."""
    body = dict(app=app, seed=seed)
    if deadline_ms is not None:
        body["deadline_ms"] = deadline_ms
    valid = (app in ("ppr", "msbfs", "landmarks")
             and 0 <= seed < NV
             and (deadline_ms is None
                  or (isinstance(deadline_ms, (int, float))
                      and 0 < deadline_ms <= 86_400_000)))
    code, j = _post(_schema_frontend().address, body)
    if valid:
        assert code == 200, (body, j)
        assert (j["app"], j["seed"]) == (app, seed)
    else:
        assert 400 <= code < 500, (body, code, j)
        assert "error" in j


def test_valid_request_roundtrips_ticket_fields():
    """Ticket fields survive POST -> GET exactly (rid, app, seed,
    tenant, status, cache_hit)."""
    frontend = _schema_frontend()
    code, t = _post(frontend.address,
                    dict(app="msbfs", seed=17, tenant="acme",
                         deadline_ms=60_000, ignored_extra="ok"))
    assert code == 200
    code, back = _get(frontend.address, f"/v1/query/{t['rid']}")
    assert code == 200
    for k in ("rid", "app", "seed", "tenant", "status", "cache_hit"):
        assert back[k] == t[k], k
    assert back["tenant"] == "acme"
    assert back["status"] == "queued"
    assert back["cache_hit"] is False


def test_structured_errors_for_each_field():
    base = _schema_frontend().address
    cases = [
        b"not json at all",
        json.dumps([1, 2, 3]).encode(),                  # non-object
        dict(seed=1),                                    # app missing
        dict(app="pagerank", seed=1),                    # not servable
        dict(app="ppr"),                                 # seed missing
        dict(app="ppr", seed="3"),                       # non-int seed
        dict(app="ppr", seed=True),                      # bool is not int
        dict(app="ppr", seed=-1),                        # negative
        dict(app="ppr", seed=NV),                        # one past the end
        dict(app="ppr", seed=10 ** 18),                  # huge
        dict(app="ppr", seed=1, deadline_ms=0),          # absurd deadline
        dict(app="ppr", seed=1, deadline_ms=-5),
        dict(app="ppr", seed=1, deadline_ms=float("1e18")),
        dict(app="ppr", seed=1, deadline_ms="soon"),
        dict(app="ppr", seed=1, tenant=""),              # bad tenant
        dict(app="ppr", seed=1, tenant="x" * 65),
        dict(app="ppr", seed=1, tenant=7),
    ]
    for body in cases:
        code, j = _post(base, body)
        assert 400 <= code < 500, body
        assert "error" in j, body
    code, j = _get(base, "/v1/query/not-a-rid")
    assert code == 400
    code, j = _get(base, "/v1/query/999999")
    assert code == 404
    code, j = _get(base, "/nope")
    assert code == 404


def test_parse_query_body_unit():
    kw = parse_query_body(
        json.dumps(dict(app="ppr", seed=5, deadline_ms=1500,
                        tenant="t")).encode(), 10)
    assert kw == dict(app="ppr", seed=5, deadline_s=1.5, tenant="t")
    with pytest.raises(BadRequest):
        parse_query_body(b"\xff\xfe", 10)
    with pytest.raises(BadRequest) as e:
        parse_query_body(b"x" * (2 ** 20 + 1), 10)
    assert e.value.status == 413


def test_encode_decode_array_bit_exact():
    rng = np.random.default_rng(3)
    for a in (rng.standard_normal(37).astype(np.float32),
              rng.integers(-(2 ** 60), 2 ** 60, 11),
              np.array([np.inf, -np.inf, np.nan, -0.0])):
        b = decode_array(json.loads(json.dumps(encode_array(a))))
        assert b.dtype == a.dtype and b.shape == a.shape
        assert a.tobytes() == b.tobytes()


# -- http_response fault site -------------------------------------------------

def _served_service(store, fault=None, **kw):
    svc = GraphService(store, _cfg(), q_slots=2, max_wait_s=0.01,
                       max_supersteps=SS, **kw)
    svc.start()
    fe = HttpFrontend(svc, fault=fault).start()
    return svc, fe


def test_dropped_response_retry_same_rid_gets_result(store):
    """site=http_response kind=drop: the first response is lost on the
    wire; service state stays consistent and the client's retry of the
    same rid observes the completed result."""
    plan = FaultPlan(specs=(FaultSpec(site="http_response", kind="drop"),))
    svc, fe = _served_service(store, fault=plan.injector())
    try:
        t = svc.submit("msbfs", 11)     # submit directly: the GET is the
        assert t.wait(120)              # response under test
        before = svc.stats_snapshot()["stats"]
        with pytest.raises((urllib.error.URLError, ConnectionError,
                            OSError)):
            urllib.request.urlopen(
                fe.address + f"/v1/query/{t.rid}", timeout=10).read()
        assert fe.counters()["dropped_responses"] == 1
        # retry, same rid: the completed result comes back intact
        code, j = _get(fe.address, f"/v1/query/{t.rid}")
        assert code == 200 and j["status"] == "done"
        assert np.array_equal(decode_array(j["result"]), t.result)
        after = svc.stats_snapshot()["stats"]
        assert before == after          # the drop mutated nothing
    finally:
        svc.request_drain()
        svc.join(120)
        fe.close()


def test_delayed_response_arrives_late_but_intact(store):
    plan = FaultPlan(specs=(FaultSpec(site="http_response", kind="delay",
                                      delay_seconds=0.3),))
    svc, fe = _served_service(store, fault=plan.injector())
    try:
        t = svc.submit("msbfs", 42)
        assert t.wait(120)
        t0 = time.perf_counter()
        code, j = _get(fe.address, f"/v1/query/{t.rid}")
        assert time.perf_counter() - t0 >= 0.3
        assert code == 200 and j["status"] == "done"
        assert np.array_equal(decode_array(j["result"]), t.result)
    finally:
        svc.request_drain()
        svc.join(120)
        fe.close()


def test_stats_and_healthz_lifecycle(store):
    svc, fe = _served_service(store, result_cache=8,
                              tenants={"a": 2.0, "b": 1.0})
    try:
        assert _get(fe.address, "/healthz") == (200, dict(status="ok"))
        code, t = _post(fe.address, dict(app="ppr", seed=3, tenant="a"))
        assert code == 200
        _poll(fe.address, t["rid"])
        code, snap = _get(fe.address, "/v1/stats")
        assert code == 200
        assert snap["stats"]["done"] == 1
        assert snap["tenants"]["a"]["submitted"] == 1
        assert snap["cache"]["misses"] == 1
        assert snap["http"]["requests"] >= 2
        assert snap["latency"]["count"] == 1
    finally:
        svc.request_drain()
        svc.join(120)
    # after drain: healthz flips to 503, POST refuses with Retry-After
    code, j = _get(fe.address, "/healthz")
    assert code == 503
    code, j = _post(fe.address, dict(app="ppr", seed=4))
    assert code == 503
    fe.close()
