"""Tile-skip filter safety (paper §III-C-4).

The property that makes skipping sound: a filter may run *extra* tiles
(false positives waste I/O) but must never skip a tile that contains an
active source (a false negative silently drops updates).  These tests
assert that property directly — at the filter level over adversarial id
sets, and at the engine level via the skip-decision log — plus a
false-positive-rate sanity check at small ``bloom_bits``.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.bloom import BloomFilter, SourceBlockBitmap


@given(st.lists(st.integers(0, 5000), min_size=1, max_size=200),
       st.lists(st.integers(0, 5000), min_size=1, max_size=200),
       st.sampled_from([64, 256, 1 << 16]))
@settings(max_examples=60, deadline=None)
def test_bloom_never_false_negative(tile_sources, active, num_bits):
    """If any active id is among the tile's sources, the bloom filter must
    report a possible hit — at *any* filter size, including degenerate
    64-bit filters where false positives are near-certain."""
    f = BloomFilter(num_bits=num_bits)
    f.add(np.asarray(tile_sources, dtype=np.int64))
    if set(tile_sources) & set(active):
        assert f.might_contain_any(np.asarray(active, dtype=np.int64))


@given(st.lists(st.integers(0, 5000), min_size=1, max_size=200),
       st.lists(st.integers(0, 5000), min_size=1, max_size=200),
       st.sampled_from([2, 4, 8]))
@settings(max_examples=60, deadline=None)
def test_bitmap_never_false_negative(tile_sources, active, block_shift):
    f = SourceBlockBitmap(5001, block_shift)
    f.add(np.asarray(tile_sources, dtype=np.int64))
    words = SourceBlockBitmap.active_words_from_ids(
        np.asarray(active, dtype=np.int64), 5001, block_shift)
    if set(tile_sources) & set(active):
        assert f.intersects(words)


def test_bloom_false_positive_rate_small_filter():
    """FPR sanity at small ``bloom_bits``: with n ids hashed k times into m
    bits the expected FPR is (1 - e^{-kn/m})^k.  Check the measured rate
    on disjoint probe ids is in a generous band around that — high enough
    to prove we are really measuring false positives at m=1024, and far
    from 1.0 so the filter still skips something."""
    rng = np.random.default_rng(42)
    n, m = 120, 1024
    members = rng.choice(100_000, size=n, replace=False)
    f = BloomFilter(num_bits=m, num_hashes=4)
    f.add(members)
    probes = np.setdiff1d(np.arange(100_000, 200_000), members)[:5000]
    hits = sum(bool(f.might_contain_any(np.array([p]))) for p in probes)
    fpr = hits / len(probes)
    expected = (1.0 - np.exp(-4 * n / m)) ** 4
    assert 0.3 * expected < fpr < min(3.0 * expected, 0.9), (fpr, expected)
    # members must all hit (no false negatives, probed one at a time)
    assert all(f.might_contain_any(np.array([v])) for v in members)


def test_bloom_fpr_shrinks_with_bits():
    rng = np.random.default_rng(7)
    members = rng.choice(50_000, size=200, replace=False)
    probes = np.setdiff1d(np.arange(50_000, 60_000), members)[:2000]

    def fpr(bits):
        f = BloomFilter(num_bits=bits)
        f.add(members)
        return sum(bool(f.might_contain_any(np.array([p])))
                   for p in probes) / len(probes)

    assert fpr(1 << 16) < fpr(1 << 10) <= fpr(1 << 6)


# ---------------------------------------------------------------------------
# engine level: the skip decision itself, via the skip-decision log
# ---------------------------------------------------------------------------

def _run_logged(store, prog, **kw):
    from repro.core.engine import EngineConfig, OutOfCoreEngine

    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=3, max_supersteps=200, tile_skipping=True,
        skip_density_threshold=0.9, debug_skip_log=True, **kw))
    res = eng.run(prog)
    return eng, res


@pytest.mark.parametrize("bloom_bits", [64, 1 << 16])
def test_engine_bloom_skip_safety(small_store, bloom_bits):
    """Engine-level safety: under ``skip_filter="bloom"`` a tile whose
    source set intersects the superstep's active ids is *never* skipped —
    only extra (no-active-source) tiles may run.  Checked against the
    ground-truth tile source sets for every logged decision, down to a
    64-bit filter that false-positives on nearly everything."""
    from repro.core.apps import BFS

    store, plan, _ = small_store
    sources = {t: set(store.read_tile(t).source_ids().tolist())
               for t in range(plan.num_tiles)}
    eng, res = _run_logged(store, BFS(source=0), skip_filter="bloom",
                           bloom_bits=bloom_bits)
    assert eng.skip_log, "skip decisions must have been logged"
    extra_runs = 0
    for entry in eng.skip_log:
        active = set(entry["active"].tolist())
        for tid in entry["skipped"]:
            assert not (sources[tid] & active), \
                f"tile {tid} with an active source was skipped (ss " \
                f"{entry['superstep']})"
        extra_runs += sum(1 for tid in entry["run"]
                          if not (sources[tid] & active))
    # correctness of the end state regardless of skipping
    res_ref = _run_logged(store, BFS(source=0), skip_filter="bitmap")[1]
    np.testing.assert_array_equal(res.values, res_ref.values)
    if bloom_bits == 64:
        # a degenerate filter must still be safe; it just runs extra tiles
        assert extra_runs >= 0


def test_engine_bitmap_skip_safety(small_store):
    """Same ground-truth check for the exact block bitmap: never skips an
    active-source tile (at block granularity it may also run extras)."""
    from repro.core.apps import BFS

    store, plan, _ = small_store
    sources = {t: set(store.read_tile(t).source_ids().tolist())
               for t in range(plan.num_tiles)}
    eng, _ = _run_logged(store, BFS(source=0), skip_filter="bitmap",
                         block_shift=2)
    assert eng.skip_log
    for entry in eng.skip_log:
        active = set(entry["active"].tolist())
        for tid in entry["skipped"]:
            assert not (sources[tid] & active)


def test_engine_bloom_skips_something(tmp_path, small_graph):
    """With a well-sized filter the skip machinery must actually skip
    tiles on a sparse-frontier app (otherwise the safety tests above are
    vacuous)."""
    from repro.core.apps import SSSP
    from repro.graphio import spe
    from repro.graphio.formats import TileStore

    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=64)
    eng, res = _run_logged(store, SSSP(source=0), skip_filter="bloom")
    assert sum(h.tiles_skipped for h in res.history) > 0
    assert any(e["skipped"] for e in eng.skip_log)
