"""Training substrate: optimizer, microbatching, compression, checkpointing,
fault-tolerant resume equivalence, and crash-atomicity of the checkpoint
protocol under injected kills (runtime.faults)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.train import data as datalib
from repro.train import optimizer as opt
from repro.train import train_step as ts
from repro.train.checkpoint import CheckpointManager
from repro.runtime.faults import FaultPlan, FaultSpec, InjectedFault

RUN = RunConfig(remat="none", q_chunk=16, kv_chunk=16, loss_chunk=16,
                compute_dtype="float32")
CFG = registry.get_config("qwen3-1.7b", reduced=True)
OPT = opt.OptConfig(lr=1e-3, warmup_steps=2, decay_steps=100)


def _batches(n, batch=4, seq=32, seed=0):
    src = datalib.SyntheticLM(CFG, batch, seq, seed=seed)
    return [{k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
            for i in range(n)]


def test_adamw_quadratic_convergence():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.adamw_init(params)
    cfg = opt.OptConfig(lr=0.3, warmup_steps=1, decay_steps=1000,
                        weight_decay=0.0, grad_clip=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_lr_schedule_shape():
    c = opt.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(opt.lr_schedule(c, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 200]]
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_loss_decreases():
    step, init, _ = ts.build_train_step(CFG, RUN, OPT)
    state = init(jax.random.key(0))
    losses = []
    for b in _batches(20):
        state, stats = step(state, b)
        losses.append(float(stats["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatch_equivalence():
    """microbatch=2 must equal microbatch=1 up to numerics (same global
    batch, grads averaged)."""
    import dataclasses

    s1, init1, _ = ts.build_train_step(CFG, RUN, OPT)
    s2, init2, _ = ts.build_train_step(
        CFG, dataclasses.replace(RUN, microbatch=2), OPT)
    st1, st2 = init1(jax.random.key(0)), init2(jax.random.key(0))
    for b in _batches(3):
        st1, r1 = s1(st1, b)
        st2, r2 = s2(st2, b)
    for a, b_ in zip(jax.tree.leaves(st1["params"]),
                     jax.tree.leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("compression", ["bf16", "topk"])
def test_grad_compression_trains(compression):
    import dataclasses

    run = dataclasses.replace(RUN, grad_compression=compression)
    step, init, _ = ts.build_train_step(CFG, run, OPT)
    state = init(jax.random.key(0))
    losses = []
    for b in _batches(15):
        state, stats = step(state, b)
        losses.append(float(stats["loss"]))
    if compression == "topk":
        assert float(stats["density"]) <= 0.05
    assert losses[-1] < losses[0]


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"a": jnp.arange(6.0).reshape(2, 3),
                        "nested": {"b": jnp.asarray([1, 2], jnp.int32)}},
             "step": jnp.asarray(7, jnp.int32)}
    mgr.save(7, state)
    mgr.save(12, state)
    mgr.save(20, state)
    assert mgr.all_steps() == [12, 20]          # keep=2 gc'd step 7
    step, got = mgr.restore()
    assert step == 20
    np.testing.assert_array_equal(np.asarray(got["params"]["a"]),
                                  np.asarray(state["params"]["a"]))
    np.testing.assert_array_equal(np.asarray(got["params"]["nested"]["b"]),
                                  np.asarray(state["params"]["nested"]["b"]))


def test_checkpoint_compressed(tmp_path):
    mgr = CheckpointManager(str(tmp_path), compress=True)
    state = {"w": jnp.zeros((64, 64))}
    mgr.save(1, state)
    _, got = mgr.restore()
    np.testing.assert_array_equal(np.asarray(got["w"]), np.zeros((64, 64)))


def test_resume_equivalence(tmp_path):
    """5 steps + save + restore + 5 steps == 10 straight steps exactly
    (deterministic data pipeline + pure step function)."""
    step, init, _ = ts.build_train_step(CFG, RUN, OPT)
    batches = _batches(10)

    state = init(jax.random.key(0))
    for b in batches:
        state, _ = step(state, b)
    straight = state

    mgr = CheckpointManager(str(tmp_path))
    state = init(jax.random.key(0))
    for b in batches[:5]:
        state, _ = step(state, b)
    mgr.save(5, state)
    _, state2 = mgr.restore(5)
    state2 = jax.tree.map(jnp.asarray, state2)
    for b in batches[5:]:
        state2, _ = step(state2, b)

    for a, b_ in zip(jax.tree.leaves(straight["params"]),
                     jax.tree.leaves(state2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-6, atol=1e-7)


def test_failure_injection_and_recovery(tmp_path):
    from repro.runtime.ft import FailureInjector, FaultTolerantLoop, SimulatedFailure

    step, init, _ = ts.build_train_step(CFG, RUN, OPT)
    batches = _batches(8)
    mgr = CheckpointManager(str(tmp_path))
    ft = FaultTolerantLoop(mgr, save_every=2, on_preempt_save=False)
    inj = FailureInjector({5})

    def run_job():
        start, state = ft.resume_or_init(lambda: init(jax.random.key(0)))
        for s in range(start, 8):
            inj.check(s)
            state, _ = step(state, batches[s])
            ft.maybe_save(s + 1, state)
        return state

    with pytest.raises(SimulatedFailure):
        run_job()                      # dies at step 5 (after ckpt at 4)
    state = run_job()                  # resumes from 4, finishes

    ref = init(jax.random.key(0))
    for b in batches:
        ref, _ = step(ref, b)
    for a, b_ in zip(jax.tree.leaves(ref["params"]),
                     jax.tree.leaves(state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-6, atol=1e-7)
    assert inj.failures == 1


# -- crash atomicity (DESIGN.md §12) ----------------------------------------
#
# Kill the writer at every named point of the staged-write protocol — a
# reader must always see the previous complete checkpoint (or, when only
# the LATEST pointer update was lost, a fully committed step), never a
# torn mix.

CKPT_SITES = ["ckpt.mid_write", "ckpt.leaf", "ckpt.pre_rename",
              "ckpt.latest", "ckpt.pre_latest"]


@settings(max_examples=20)
@given(st.sampled_from(CKPT_SITES), st.integers(0, 128),
       st.sampled_from(["raise", "torn_write"]))
def test_checkpoint_crash_atomicity(site, keep_bytes, kind):
    if kind == "torn_write" and site in ("ckpt.mid_write", "ckpt.pre_rename",
                                         "ckpt.pre_latest"):
        return       # pure check() sites: no write to tear there
    with tempfile.TemporaryDirectory() as d:
        old = {"params": {"a": np.arange(6.0).reshape(2, 3)},
               "step": np.asarray(4, np.int32)}
        new = {"params": {"a": np.full((2, 3), 7.0)},
               "step": np.asarray(9, np.int32)}
        CheckpointManager(d).save(4, old)
        plan = FaultPlan(specs=(FaultSpec(
            site=site, kind=kind, keep_bytes=keep_bytes, superstep=9),))
        wr = CheckpointManager(d, fault=plan.injector())
        try:
            wr.save(9, new)
            crashed = False
        except InjectedFault:
            crashed = True
        step, got = CheckpointManager(d).restore()
        if crashed and site not in ("ckpt.latest", "ckpt.pre_latest"):
            assert step == 4        # the torn step 9 never published
        else:
            assert step in (4, 9)   # only the pointer update was lost
        want = old if step == 4 else new
        np.testing.assert_array_equal(np.asarray(got["params"]["a"]),
                                      want["params"]["a"])
        np.testing.assert_array_equal(np.asarray(got["step"]),
                                      np.asarray(want["step"]))


def test_checkpoint_unreadable_latest_falls_back(tmp_path):
    """A torn LATEST pointer (crash mid-content) must not brick recovery:
    the reader falls back to the newest published step directory."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"w": np.zeros(4)})
    with open(str(tmp_path / "LATEST"), "w") as f:
        f.write("garb")             # torn/corrupt pointer content
    assert CheckpointManager(str(tmp_path)).latest_step() == 3
    # pointer naming a missing step dir also falls back
    with open(str(tmp_path / "LATEST"), "w") as f:
        f.write("77")
    assert CheckpointManager(str(tmp_path)).latest_step() == 3


def test_prefetcher_deterministic():
    src = datalib.SyntheticLM(CFG, 2, 16, seed=3)
    pf = datalib.Prefetcher(src, start_step=4)
    s, b = pf.next()
    pf.close()
    assert s == 4
    np.testing.assert_array_equal(b["tokens"], src.batch_at(4)["tokens"])
