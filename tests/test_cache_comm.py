"""Edge cache (paper §III-D-2) and hybrid communication (§III-D-3)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import comm
from repro.core.cache import DEFAULT_GAMMAS, EdgeCache, auto_select_mode
from repro.graphio import formats


# --------------------------- cache ---------------------------------------

def test_auto_select_mode_paper_rule():
    # min i s.t. working_set / gamma_i <= C, else mode 3
    assert auto_select_mode(100, 200) == 1          # raw fits
    assert auto_select_mode(300, 200) == 2          # needs 2x
    assert auto_select_mode(700, 200) == 3          # needs 4x
    assert auto_select_mode(900, 200) == 4          # needs 5x
    assert auto_select_mode(10_000, 200) == 3       # nothing fits -> mode 3


def test_cache_hit_miss_eviction(small_store):
    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    cache = EdgeCache(store, capacity_bytes=sum(sizes[:3]) + 64, mode=1)
    cache.get(0), cache.get(1)
    assert cache.stats.misses == 2
    cache.get(0)
    assert cache.stats.hits == 1
    # fill beyond capacity -> eviction of LRU (tile 1 is older than 0)
    for t in range(plan.num_tiles):
        cache.get(t)
    assert cache.stats.evictions > 0
    assert cache.resident_bytes() <= cache.capacity_bytes


def test_cache_modes_equivalent_content(small_store):
    store, plan, _ = small_store
    tiles = {}
    for mode in (1, 2, 3, 4):
        c = EdgeCache(store, 1 << 30, mode)
        t = c.get(1)
        t2 = c.get(1)     # from cache (decompression path)
        assert c.stats.hits == 1
        np.testing.assert_array_equal(t.src, t2.src)
        tiles[mode] = t2
    for mode in (2, 3, 4):
        np.testing.assert_array_equal(tiles[1].src, tiles[mode].src)
        np.testing.assert_array_equal(tiles[1].dst_local, tiles[mode].dst_local)


def test_compressed_modes_smaller(small_store):
    store, plan, _ = small_store
    blob = formats.decompress_blob(store.read_tile_blob(0), store.disk_mode)
    raw = len(formats.compress_blob(blob, 1))
    z1 = len(formats.compress_blob(blob, 2))
    z9 = len(formats.compress_blob(blob, 4))
    assert z1 < raw and z9 <= z1


@given(st.binary(min_size=0, max_size=4096), st.sampled_from([1, 2, 3, 4]))
@settings(max_examples=30, deadline=None)
def test_blob_roundtrip(blob, mode):
    assert formats.decompress_blob(formats.compress_blob(blob, mode), mode) == blob


# --------------------------- hybrid comm ---------------------------------

def test_plan_broadcast_mode_switch():
    nv = 1000
    vals = np.random.default_rng(0).normal(size=nv).astype(np.float32)
    dense_upd = np.ones(nv, bool)
    sparse_upd = np.zeros(nv, bool)
    sparse_upd[:50] = True
    rec_d = comm.plan_broadcast(vals, dense_upd)
    rec_s = comm.plan_broadcast(vals, sparse_upd)
    assert rec_d.mode == "dense" and rec_s.mode == "sparse"
    # sparse payload is much smaller at 5% density
    assert rec_s.raw_bytes < rec_d.raw_bytes / 4
    # threshold boundary
    upd = np.zeros(nv, bool)
    upd[:400] = True
    assert comm.plan_broadcast(vals, upd).mode == "dense"
    upd[:] = False
    upd[:399] = True
    assert comm.plan_broadcast(vals, upd).mode == "sparse"


def test_wire_bytes_model_matches_payloads():
    nv = 4096
    vals = np.zeros(nv, np.float32)
    upd = np.zeros(nv, bool)
    upd[:100] = True
    est = comm.wire_bytes_estimate(nv, 100 / nv)
    assert est == len(comm.sparse_payload(vals, upd))
    upd[:] = True
    est_d = comm.wire_bytes_estimate(nv, 1.0)
    assert est_d == len(comm.dense_payload(vals, upd))


def test_wire_bytes_dense_parity_non_multiple_of_8():
    """The dense bitvector is np.packbits output = ceil(V/8) bytes; the
    estimate must match the real payload for V not divisible by 8
    (regression: V // 8 undercounted by one byte)."""
    for nv in (7, 1001, 4093, 4095, 4097):
        vals = np.zeros(nv, np.float32)
        upd = np.ones(nv, bool)
        est = comm.wire_bytes_estimate(nv, 1.0)
        assert est == len(comm.dense_payload(vals, upd)), nv


def test_plan_broadcast_rejects_unknown_compressor():
    vals = np.zeros(16, np.float32)
    upd = np.ones(16, bool)
    with pytest.raises(ValueError, match="snappy"):
        comm.plan_broadcast(vals, upd, compressor="snappy")


def test_plan_broadcast_records_actual_codec():
    """The recorded compressor must name the codec that actually ran —
    zlib-N when repro.compat has fallen back from zstd (regression: the
    record always claimed zstd)."""
    from repro import compat

    vals = np.zeros(64, np.float32)
    upd = np.ones(64, bool)
    rec = comm.plan_broadcast(vals, upd, compressor="zstd-1")
    expected = "zstd-1" if compat.HAVE_ZSTD else "zlib-1"
    assert rec.compressor == expected
    assert comm.plan_broadcast(vals, upd, compressor="none").compressor == "none"
    _, label9 = comm.resolve_compressor("zstd-9")
    assert label9.endswith("-9")


def test_forced_sparse_overflow_falls_back_to_dense():
    """Forced mode="sparse" with more updates than the fixed compaction
    capacity used to silently truncate (jnp.nonzero(..., size=capacity));
    the global overflow guard must now deliver every update via the dense
    fallback."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map_unchecked
    from repro.launch.mesh import make_mesh

    nv, capacity = 64, 8
    mesh = make_mesh((1,), ("data",))
    old = jnp.zeros(nv, jnp.float32)
    new = jnp.arange(1, nv + 1, dtype=jnp.float32)

    fn = shard_map_unchecked(
        lambda o, n, u: comm.hybrid_broadcast(o, n, u, "data",
                                              capacity=capacity, mode="sparse"),
        mesh, in_specs=(P(), P(), P()), out_specs=(P(), P()))

    # overflow: 64 updates > capacity 8 -> dense fallback, nothing dropped
    out, _ = fn(old, new, jnp.ones(nv, bool))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(new))

    # no overflow: the sparse path itself is untouched
    upd = np.zeros(nv, bool)
    upd[:capacity - 2] = True
    out2, _ = fn(old, new, jnp.asarray(upd))
    ref = np.where(upd, np.asarray(new), 0.0)
    np.testing.assert_array_equal(np.asarray(out2), ref)

    # hybrid mode with a caller-supplied capacity below the density switch
    # point must keep the guard too: density 0.31 < 0.4 selects the sparse
    # branch, 20 updates > capacity 8 would truncate without it
    fn_h = shard_map_unchecked(
        lambda o, n, u: comm.hybrid_broadcast(o, n, u, "data",
                                              capacity=capacity, mode="hybrid"),
        mesh, in_specs=(P(), P(), P()), out_specs=(P(), P()))
    upd3 = np.zeros(nv, bool)
    upd3[:20] = True
    out3, _ = fn_h(old, new, jnp.asarray(upd3))
    ref3 = np.where(upd3, np.asarray(new), 0.0)
    np.testing.assert_array_equal(np.asarray(out3), ref3)


def test_compression_reduces_wire_bytes():
    rng = np.random.default_rng(0)
    nv = 10000
    # correlated values compress well
    vals = np.repeat(rng.normal(size=nv // 10), 10).astype(np.float32)
    upd = np.ones(nv, bool)
    raw = comm.plan_broadcast(vals, upd, compressor="none")
    z = comm.plan_broadcast(vals, upd, compressor="zstd-1")
    assert z.wire_bytes < raw.wire_bytes


def test_sparse_capacity_bound():
    for nv in (100, 1000, 12345):
        k = comm.sparse_capacity(nv)
        assert k >= int(np.ceil(nv * comm.DENSITY_THRESHOLD))
        assert k <= nv or nv < 128


def test_comm_pool_shared_under_concurrent_first_use(monkeypatch):
    """Regression: the lazily-created broadcast executor was guarded by a
    bare None check — two threads racing the first plan_broadcast_async
    could each create a ThreadPoolExecutor and leak one.  Double-checked
    locking must hand every concurrent first caller the same pool (and
    register its atexit shutdown exactly once)."""
    import threading
    from concurrent.futures import ThreadPoolExecutor

    created = []

    class CountingPool(ThreadPoolExecutor):
        def __init__(self, *a, **kw):
            created.append(self)
            super().__init__(*a, **kw)

    comm._shutdown_comm_pool()     # reset any pool from earlier tests
    monkeypatch.setattr(comm, "ThreadPoolExecutor", CountingPool)
    vals = np.arange(64, dtype=np.float32)
    upd = np.ones(64, bool)
    barrier = threading.Barrier(8)
    futures = []
    flock = threading.Lock()

    def go():
        barrier.wait()   # maximize the race on the None check
        f = comm.plan_broadcast_async(vals, upd)
        with flock:
            futures.append(f)

    threads = [threading.Thread(target=go) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(created) == 1               # exactly one executor, shared
    for f in futures:
        assert f.result().raw_bytes > 0
    comm._shutdown_comm_pool()             # and it can be torn down cleanly
    assert comm._COMM_POOL is None
