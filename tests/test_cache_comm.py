"""Edge cache (paper §III-D-2) and hybrid communication (§III-D-3)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback, see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import comm
from repro.core.cache import DEFAULT_GAMMAS, EdgeCache, auto_select_mode
from repro.graphio import formats


# --------------------------- cache ---------------------------------------

def test_auto_select_mode_paper_rule():
    # min i s.t. working_set / gamma_i <= C, else mode 3
    assert auto_select_mode(100, 200) == 1          # raw fits
    assert auto_select_mode(300, 200) == 2          # needs 2x
    assert auto_select_mode(700, 200) == 3          # needs 4x
    assert auto_select_mode(900, 200) == 4          # needs 5x
    assert auto_select_mode(10_000, 200) == 3       # nothing fits -> mode 3


def test_cache_hit_miss_eviction(small_store):
    store, plan, _ = small_store
    sizes = [store.tile_disk_bytes(t) for t in range(plan.num_tiles)]
    cache = EdgeCache(store, capacity_bytes=sum(sizes[:3]) + 64, mode=1)
    cache.get(0), cache.get(1)
    assert cache.stats.misses == 2
    cache.get(0)
    assert cache.stats.hits == 1
    # fill beyond capacity -> eviction of LRU (tile 1 is older than 0)
    for t in range(plan.num_tiles):
        cache.get(t)
    assert cache.stats.evictions > 0
    assert cache.resident_bytes() <= cache.capacity_bytes


def test_cache_modes_equivalent_content(small_store):
    store, plan, _ = small_store
    tiles = {}
    for mode in (1, 2, 3, 4):
        c = EdgeCache(store, 1 << 30, mode)
        t = c.get(1)
        t2 = c.get(1)     # from cache (decompression path)
        assert c.stats.hits == 1
        np.testing.assert_array_equal(t.src, t2.src)
        tiles[mode] = t2
    for mode in (2, 3, 4):
        np.testing.assert_array_equal(tiles[1].src, tiles[mode].src)
        np.testing.assert_array_equal(tiles[1].dst_local, tiles[mode].dst_local)


def test_compressed_modes_smaller(small_store):
    store, plan, _ = small_store
    blob = formats.decompress_blob(store.read_tile_blob(0), store.disk_mode)
    raw = len(formats.compress_blob(blob, 1))
    z1 = len(formats.compress_blob(blob, 2))
    z9 = len(formats.compress_blob(blob, 4))
    assert z1 < raw and z9 <= z1


@given(st.binary(min_size=0, max_size=4096), st.sampled_from([1, 2, 3, 4]))
@settings(max_examples=30, deadline=None)
def test_blob_roundtrip(blob, mode):
    assert formats.decompress_blob(formats.compress_blob(blob, mode), mode) == blob


# --------------------------- hybrid comm ---------------------------------

def test_plan_broadcast_mode_switch():
    nv = 1000
    vals = np.random.default_rng(0).normal(size=nv).astype(np.float32)
    dense_upd = np.ones(nv, bool)
    sparse_upd = np.zeros(nv, bool)
    sparse_upd[:50] = True
    rec_d = comm.plan_broadcast(vals, dense_upd)
    rec_s = comm.plan_broadcast(vals, sparse_upd)
    assert rec_d.mode == "dense" and rec_s.mode == "sparse"
    # sparse payload is much smaller at 5% density
    assert rec_s.raw_bytes < rec_d.raw_bytes / 4
    # threshold boundary
    upd = np.zeros(nv, bool)
    upd[:400] = True
    assert comm.plan_broadcast(vals, upd).mode == "dense"
    upd[:] = False
    upd[:399] = True
    assert comm.plan_broadcast(vals, upd).mode == "sparse"


def test_wire_bytes_model_matches_payloads():
    nv = 4096
    vals = np.zeros(nv, np.float32)
    upd = np.zeros(nv, bool)
    upd[:100] = True
    est = comm.wire_bytes_estimate(nv, 100 / nv)
    assert est == len(comm.sparse_payload(vals, upd))
    upd[:] = True
    est_d = comm.wire_bytes_estimate(nv, 1.0)
    assert est_d == len(comm.dense_payload(vals, upd))


def test_compression_reduces_wire_bytes():
    rng = np.random.default_rng(0)
    nv = 10000
    # correlated values compress well
    vals = np.repeat(rng.normal(size=nv // 10), 10).astype(np.float32)
    upd = np.ones(nv, bool)
    raw = comm.plan_broadcast(vals, upd, compressor="none")
    z = comm.plan_broadcast(vals, upd, compressor="zstd-1")
    assert z.wire_bytes < raw.wire_bytes


def test_sparse_capacity_bound():
    for nv in (100, 1000, 12345):
        k = comm.sparse_capacity(nv)
        assert k >= int(np.ceil(nv * comm.DENSITY_THRESHOLD))
        assert k <= nv or nv < 128
