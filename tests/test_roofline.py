"""Roofline machinery: trip-count-aware HLO cost model + term math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis as ra
from repro.roofline import hlo_cost, hw


def test_scan_flops_multiplied():
    def one(x, w):
        return x @ w

    def scan10(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    f1 = hlo_cost.analyze(jax.jit(one).lower(x, w).compile().as_text()).flops
    f10 = hlo_cost.analyze(jax.jit(scan10).lower(x, w).compile().as_text()).flops
    assert f1 == pytest.approx(2 * 256 ** 3, rel=0.01)
    assert f10 == pytest.approx(10 * f1, rel=0.02)


def test_nested_scan_multiplied():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    f = hlo_cost.analyze(jax.jit(nested).lower(x, w).compile().as_text()).flops
    assert f == pytest.approx(12 * 2 * 128 ** 3, rel=0.05)


def test_xla_cost_analysis_undercounts_loops_motivation():
    """Documents WHY hlo_cost exists: XLA counts loop bodies once."""
    def scan10(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = jax.jit(scan10).lower(x, w).compile()
    xla_flops, _ = ra.cost_analysis_terms(comp)
    ours = hlo_cost.analyze(comp.as_text()).flops
    assert ours >= 9 * xla_flops  # XLA missed ~10x


def test_collective_bytes_parse():
    hlo = """
HloModule test
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), to_apply=%add
  ROOT %ag = f32[8192]{0} all-gather(%ar), dimensions={0}
}
"""
    out = ra.collective_bytes(hlo)
    assert out["all-reduce"] == 4096
    assert out["all-gather"] == 4096      # operand bytes, not result
    assert out["total"] == 8192


def test_roofline_terms_math():
    t = ra.roofline(flops=hw.PEAK_FLOPS_BF16, hbm_bytes=hw.HBM_BW / 2,
                    coll_bytes=0, n_chips=4, model_flops_total=hw.PEAK_FLOPS_BF16)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.bottleneck == "compute"
    assert t.mfu_bound == pytest.approx(0.25)   # model/(4 chips * peak * 1s)


def test_model_flops():
    assert ra.model_flops("train", 10, 100) == 6000
    assert ra.model_flops("prefill", 10, 100) == 2000
    assert ra.model_flops("train", 10, 100, embed_params=4) == 3600


def test_conditional_takes_max_branch():
    def f(x, pred):
        return jax.lax.cond(pred, lambda a: a @ a, lambda a: a + 1.0, x)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    p = jax.ShapeDtypeStruct((), jnp.bool_)
    c = hlo_cost.analyze(jax.jit(f).lower(x, p).compile().as_text())
    assert c.flops >= 2 * 128 ** 3 * 0.95      # matmul branch counted
