"""Interval-sharded out-of-core vertex state (DESIGN.md §10).

Covers the VertexStateStore tier ladder (spill/reload round-trips, the
dirty-writeback-only invariant), the interval plan + footprint metadata
(partition/tiles/formats), per-dirty-interval broadcast accounting
(comm), and — the contract that matters — engine bit-identity against
the fully-resident path across serial/pipelined x tiled/stacked on
PageRank and MultiSourceBFS, with the vertex budget at <= 25% of the
full [V, Q] footprint.
"""
import os

import numpy as np
import pytest

from repro.core import comm
from repro.core.apps import SSSP, WCC, MultiSourceBFS, PageRank
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.core.partition import IntervalPlan, plan_intervals
from repro.core.tiles import attach_source_footprint, compute_source_footprint
from repro.core.vstate import VertexStateStore
from repro.graphio import formats, spe
from repro.graphio.formats import TileStore


# --------------------------- VertexStateStore ------------------------------

SPLIT = np.array([0, 40, 90, 150, 200], dtype=np.int64)


@pytest.mark.parametrize("dtype,tail", [
    (np.float32, ()), (np.float64, ()), (np.int64, ()),
    (np.float32, (5,)), (np.float64, (3,)),
], ids=["f32", "f64", "i64", "f32_q5", "f64_q3"])
def test_spill_reload_round_trip_bit_exact(tmp_path, dtype, tail):
    """Blocks forced down to the disk tier come back bit-identical, for
    1-D and [V, Q] arrays across dtypes."""
    rng = np.random.default_rng(0)
    arr = rng.standard_normal((200,) + tail)
    arr = (arr * 1000).astype(dtype)
    vs = VertexStateStore(SPLIT, budget_bytes=1, spill_dir=str(tmp_path / "s"))
    vs.add_array("value", arr)
    # budget of 1 byte: everything must have spilled to the cold tier
    snap = vs.tier_snapshot()
    assert snap["cold"]["blocks"] >= vs.num_intervals - 1
    assert vs.stats.spill_bytes > 0
    out = vs.materialize("value")
    assert out.dtype == arr.dtype
    np.testing.assert_array_equal(out, arr)
    vs.close()
    assert not os.path.exists(str(tmp_path / "s"))


def test_unlimited_budget_stays_hot(tmp_path):
    vs = VertexStateStore(SPLIT, budget_bytes=None,
                          spill_dir=str(tmp_path / "s"))
    vs.add_array("value", np.arange(200, dtype=np.float32))
    assert vs.hot_intervals() == set(range(vs.num_intervals))
    assert vs.stats.spills == 0 and vs.stats.faults == 0
    vs.close()


def test_close_without_spill_dir_is_noop():
    """The documented no-spill mode (budget None, no spill_dir) must be
    closeable — close() used to assert on the missing spill_dir."""
    vs = VertexStateStore(SPLIT, budget_bytes=None, spill_dir=None)
    vs.add_array("value", np.arange(200, dtype=np.float32))
    vs.close()                                  # no crash, nothing to do
    np.testing.assert_array_equal(vs.materialize("value"),
                                  np.arange(200, dtype=np.float32))


def test_block_get_write_and_interval_mapping(tmp_path):
    vs = VertexStateStore(SPLIT, budget_bytes=None,
                          spill_dir=str(tmp_path / "s"))
    vs.add_array("value", np.arange(200, dtype=np.float32))
    lo, hi = vs.interval_range(2)
    np.testing.assert_array_equal(vs.get_block("value", 2),
                                  np.arange(lo, hi, dtype=np.float32))
    blk = vs.get_block("value", 1).copy()
    blk[:] = -1.0
    vs.write_block("value", 1, blk)
    assert (vs.materialize("value")[40:90] == -1.0).all()
    np.testing.assert_array_equal(vs.interval_of(np.array([0, 39, 40, 199])),
                                  [0, 0, 1, 3])


def test_dirty_writeback_only_invariant(tmp_path):
    """Clean blocks demote for free once serialized: cycling reads under
    pressure re-spills nothing; only a *written* (dirty) block pays a new
    disk write on its way back down."""
    rng = np.random.default_rng(1)
    arr = rng.standard_normal((200, 4)).astype(np.float32)
    blk_bytes = arr[0:40].nbytes
    vs = VertexStateStore(SPLIT, budget_bytes=2 * blk_bytes,
                          spill_dir=str(tmp_path / "s"))
    vs.add_array("value", arr)
    # settle: everything serialized at least once
    for k in range(vs.num_intervals):
        vs.get_block("value", k)
    spills0 = vs.stats.spills
    for _ in range(3):                      # read-only cycles under pressure
        for k in range(vs.num_intervals):
            vs.get_block("value", k)
    assert vs.stats.spills == spills0       # clean demotions wrote nothing
    assert vs.stats.faults > 0              # but blocks did cycle through cold
    dirty = vs.get_block("value", 0).copy()
    dirty += 1.0
    vs.write_block("value", 0, dirty)
    for k in range(vs.num_intervals):       # pressure pushes block 0 back down
        vs.get_block("value", k)
    assert vs.stats.spills == spills0 + 1   # exactly the dirty block re-spilled
    np.testing.assert_array_equal(vs.materialize("value")[:40], dirty)
    vs.close()


def test_compact_columns(tmp_path):
    arr = np.arange(200 * 3, dtype=np.float32).reshape(200, 3)
    vs = VertexStateStore(SPLIT, budget_bytes=None,
                          spill_dir=str(tmp_path / "s"))
    vs.add_array("value", arr)
    vs.compact_columns(["value"], np.array([True, False, True]))
    assert vs.spec("value")[1] == (2,)
    np.testing.assert_array_equal(vs.materialize("value"), arr[:, [0, 2]])
    vs.close()


# --------------------------- interval plan + footprint ----------------------

def test_plan_intervals_aligned_to_tile_splitter(small_store):
    store, plan, _ = small_store
    iv = plan_intervals(plan.splitter, 4)
    assert iv.splitter[0] == 0 and iv.splitter[-1] == plan.num_vertices
    assert set(iv.splitter).issubset(set(plan.splitter.tolist()))
    # every tile's rows live in exactly one interval
    for t in range(plan.num_tiles):
        lo, hi = plan.tile_range(t)
        k = iv.tile_to_interval[t]
        assert iv.splitter[k] <= lo and hi <= iv.splitter[k + 1]
    # round-trip
    iv2 = IntervalPlan.from_dict(iv.to_dict())
    np.testing.assert_array_equal(iv.splitter, iv2.splitter)
    np.testing.assert_array_equal(iv.tile_to_interval, iv2.tile_to_interval)


def test_plan_intervals_clamps_k(small_store):
    store, plan, _ = small_store
    iv = plan_intervals(plan.splitter, 10 * plan.num_tiles)
    assert iv.num_intervals <= plan.num_tiles


def test_source_footprint_buckets_by_interval(small_store):
    store, plan, _ = small_store
    iv = plan_intervals(plan.splitter, 4)
    tile = store.read_tile(0)
    ids, ptr, perm = compute_source_footprint(
        tile.src, tile.meta.num_edges, iv.splitter)
    assert ptr[0] == 0 and ptr[-1] == tile.meta.num_edges
    assert sorted(perm) == list(range(tile.meta.num_edges))
    for j, k in enumerate(ids):
        lo, hi = iv.interval_range(k)
        bucket = tile.src[perm[ptr[j]: ptr[j + 1]]]
        assert ((bucket >= lo) & (bucket < hi)).all()
    # the union of buckets covers every real source id
    real = set(tile.src[: tile.meta.num_edges].tolist())
    assert set(np.unique(iv.interval_of(np.array(sorted(real))))) == set(ids)


def test_tile_format_v2_round_trip_and_v1_compat(small_store):
    store, plan, _ = small_store
    iv = plan_intervals(plan.splitter, 3)
    tile = store.read_tile(1)
    # v1: no footprint attached -> GHT1 bytes, iv_perm None after round-trip
    blob1 = formats.serialize_tile(tile)
    assert blob1[:4] == formats.MAGIC
    t1 = formats.deserialize_tile(blob1)
    assert t1.iv_perm is None and t1.meta.src_intervals is None
    # v2: footprint attached -> GHT2, metadata + permutation round-trip
    attach_source_footprint(tile, iv.splitter)
    blob2 = formats.serialize_tile(tile)
    assert blob2[:4] == formats.MAGIC_V2
    t2 = formats.deserialize_tile(blob2)
    assert t2.meta.src_intervals == tile.meta.src_intervals
    assert t2.meta.src_interval_ptr == tile.meta.src_interval_ptr
    np.testing.assert_array_equal(t2.iv_perm, tile.iv_perm)
    np.testing.assert_array_equal(t2.src, tile.src)
    t2.validate()


def test_spe_records_interval_plan_and_footprints(tmp_path, small_graph):
    nv, src, dst = small_graph
    store = TileStore(str(tmp_path / "ivstore"))
    spe.preprocess_arrays(src, dst, None, nv, store, tile_size=100,
                          num_intervals=3)
    iv = store.load_interval_plan()
    assert iv is not None and iv.num_intervals <= 3
    plan = store.load_plan()
    for t in range(plan.num_tiles):
        tile = store.read_tile(t)
        assert tile.meta.src_intervals is not None
        assert tile.iv_perm is not None
        tile.validate()


def test_store_without_plan_loads_none(small_store):
    store, _, _ = small_store
    assert store.load_interval_plan() is None


# --------------------------- per-interval broadcast -------------------------

def test_plan_broadcast_intervals_counts_and_bytes():
    splitter = np.array([0, 100, 200, 300], dtype=np.int64)
    idx = np.array([5, 7, 205], dtype=np.int64)         # intervals 0 and 2
    vals = np.array([1.0, 2.0, 3.0], dtype=np.float32)
    rec = comm.plan_broadcast_intervals(idx, vals, None, splitter,
                                        compressor="none")
    assert rec.mode == "interval" and rec.intervals == 2
    # sparse sections: 2 headers + per-update (u32 idx + f32 val)
    assert rec.raw_bytes == 2 * comm.INTERVAL_HEADER_BYTES + 3 * 8
    assert rec.density == pytest.approx(3 / 300)
    # clean intervals cost nothing: same updates, whole-V dense payload is
    # strictly bigger
    dense = np.zeros(300, np.float32)
    upd = np.zeros(300, bool)
    dense[idx], upd[idx] = vals, True
    whole = comm.plan_broadcast(dense, upd, compressor="none", mode="dense")
    assert rec.raw_bytes < whole.raw_bytes


def test_plan_broadcast_intervals_empty_and_multiquery():
    splitter = np.array([0, 50, 100], dtype=np.int64)
    rec = comm.plan_broadcast_intervals(
        np.zeros(0, np.int64), np.zeros((0, 2), np.float32),
        np.zeros((0, 2), bool), splitter)
    assert rec.intervals == 0 and rec.raw_bytes == 0 and rec.wire_bytes == 0
    idx = np.array([3, 60], dtype=np.int64)
    vals = np.array([[1.0, 0.0], [0.0, 2.0]], dtype=np.float32)
    mask = np.array([[True, False], [False, True]])
    rec = comm.plan_broadcast_intervals(idx, vals, mask, splitter,
                                        compressor="none")
    assert rec.intervals == 2 and rec.raw_bytes > 0


# --------------------------- engine bit-identity ----------------------------

def _budget_for(prog, nv):
    """<= 25% of the full [V, Q] vertex footprint (value + aux arrays)."""
    state = prog.init(nv, np.ones(nv), np.ones(nv))
    total = sum(np.asarray(a).nbytes for a in state.values())
    return max(1, total // 4)


def _run(store, prog, budget=None, **kw):
    cfg = EngineConfig(num_servers=3, max_supersteps=200,
                       vertex_memory_budget=budget, **kw)
    return OutOfCoreEngine(store, cfg).run(prog)


@pytest.mark.parametrize("pipeline", [False, True], ids=["serial", "pipelined"])
@pytest.mark.parametrize("prog_factory", [
    lambda: PageRank(update_tol=1e-10),
    lambda: MultiSourceBFS(sources=(0, 5, 17, 200)),
], ids=["pagerank", "msbfs"])
def test_ooc_vstate_bit_identical(small_store, prog_factory, pipeline):
    store, plan, _ = small_store
    nv = plan.num_vertices
    ref = _run(store, prog_factory(), pipeline=pipeline)
    res = _run(store, prog_factory(), pipeline=pipeline,
               budget=_budget_for(prog_factory(), nv))
    assert res.supersteps == ref.supersteps
    assert np.array_equal(ref.values, res.values)          # bit-identical
    if ref.per_query_supersteps is not None:
        np.testing.assert_array_equal(ref.per_query_supersteps,
                                      res.per_query_supersteps)
    for k in ref.aux:
        np.testing.assert_array_equal(ref.aux[k], res.aux[k])
    # the budget was real: state actually faulted and/or spilled
    assert sum(h.vstate_faults for h in res.history) > 0


@pytest.mark.parametrize("mode", ["tiled", "stacked"])
def test_ooc_vstate_engine_modes(small_store, mode):
    """engine_mode="stacked" needs the full value array on device, so ooc
    mode falls back to tiled — results must still match the in-memory run
    of the requested mode bit for bit."""
    store, plan, _ = small_store
    ref = _run(store, PageRank(update_tol=1e-10), engine_mode=mode)
    res = _run(store, PageRank(update_tol=1e-10), engine_mode=mode,
               budget=_budget_for(PageRank(), plan.num_vertices))
    assert np.array_equal(ref.values, res.values)


def test_ooc_vstate_sssp_wcc_and_meta_footprints(tmp_path, small_graph):
    """Weighted SSSP + WCC, on a store preprocessed WITH an interval plan
    (tile footprint metadata drives gather) — vs the in-memory path."""
    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=100,
                          num_intervals=4)
    for prog_factory in (lambda: SSSP(source=0), lambda: WCC()):
        ref = _run(store, prog_factory())
        res = _run(store, prog_factory(),
                   budget=_budget_for(prog_factory(), nv))
        assert np.array_equal(ref.values, res.values)
    # the engine honored the stored plan (footprint metadata usable)
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=2, max_supersteps=3,
        vertex_memory_budget=_budget_for(SSSP(), nv)))
    eng.run(SSSP(source=0))
    assert eng._use_meta_fp
    np.testing.assert_array_equal(eng._iv_splitter,
                                  store.load_interval_plan().splitter)


def test_ooc_dirty_interval_writeback_and_broadcast(tmp_path, small_graph):
    """Late SSSP supersteps touch a shrinking frontier: some supersteps
    must write back (and broadcast) fewer intervals than exist — clean
    intervals are never shipped or re-serialized."""
    nv, src, dst = small_graph
    rng = np.random.default_rng(3)
    val = rng.uniform(0.5, 2.0, len(src)).astype(np.float32)
    store = TileStore(str(tmp_path / "w2"))
    spe.preprocess_arrays(src, dst, val, nv, store, tile_size=60,
                          num_intervals=6)
    res = _run(store, SSSP(source=0), budget=nv)  # tight budget
    k = store.load_interval_plan().num_intervals
    dirty = [h.vstate_dirty_intervals for h in res.history]
    assert any(0 < d < k for d in dirty)
    assert dirty[-1] == 0                       # converged: nothing dirty
    # per-superstep broadcast records were per-interval
    assert all(h.vstate_dirty_intervals <= k for h in res.history)


def test_ooc_interval_aware_order_is_permutation(small_store):
    store, plan, _ = small_store
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=1, max_supersteps=2,
        vertex_memory_budget=plan.num_vertices))   # tight: forces tiering
    eng.run(PageRank(update_tol=1e-10))
    tids = list(eng.assignment[0])
    order = eng._order_joint_residency(0, tids)
    assert sorted(order) == sorted(tids)
    # footprints were recorded for the scheduler
    assert all(t in eng._tile_iv_ids for t in tids)


def test_ooc_interval_sweep_fallback(small_store):
    """The O(T log T) large-fleet ordering is a dst-interval sweep that
    starts from the hot end."""
    store, plan, _ = small_store
    eng = OutOfCoreEngine(store, EngineConfig(
        num_servers=1, max_supersteps=2,
        vertex_memory_budget=plan.num_vertices))
    eng.run(PageRank(update_tol=1e-10))
    tids = list(eng.assignment[0])
    order = eng._order_interval_sweep(tids)
    assert sorted(order) == sorted(tids)
    ivs = [int(eng._iv_t2i[t]) for t in order]
    assert ivs == sorted(ivs) or ivs == sorted(ivs, reverse=True)


def test_ooc_spill_dir_cleaned_up(small_store):
    store, plan, _ = small_store
    before = set(os.listdir(store.root))
    res = _run(store, PageRank(update_tol=1e-10), budget=plan.num_vertices)
    assert res.converged
    after = set(os.listdir(store.root))
    assert not any(d.startswith("_vstate_") for d in after - before)


def test_cli_vertex_memory_budget(tmp_path):
    from repro.launch import graph as cli

    res = cli.main([
        "--app", "pagerank", "--graph", "banded", "--vertices", "2000",
        "--edges", "8000", "--tile-size", "512", "--servers", "2",
        "--supersteps", "4", "--vertex-memory-budget", "0.004",
        "--num-intervals", "4",
        "--store", str(tmp_path / "clistore")])
    assert sum(h.vstate_faults for h in res.history) >= 0
    assert any(h.vstate_dirty_intervals > 0 for h in res.history)
