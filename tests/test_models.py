"""Per-arch smoke tests (reduced configs): one train step, prefill/decode
consistency, output shapes, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import RunConfig, ShapeCell
from repro.models.model_zoo import build_model, param_count

RUN = RunConfig(remat="none", q_chunk=16, kv_chunk=16, loss_chunk=16,
                compute_dtype="float32")
CELL = ShapeCell("smoke", "train", 32, 2)


def _loss(model, cfg, params, batch):
    if cfg.encoder_layers > 0:
        return model.loss(params, batch["tokens"], batch["labels"],
                          batch["enc_frames"])
    if cfg.frontend == "vision":
        return model.loss(params, batch["tokens"], batch["labels"],
                          extra_embeds=batch["patch_embeds"])
    return model.loss(params, batch["tokens"], batch["labels"])


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = registry.get_config(arch, reduced=True)
    model = build_model(cfg, RUN)
    params = model.init(jax.random.key(0))
    assert param_count(params) > 0
    batch = {k: jnp.asarray(v) for k, v in
             registry.synthetic_batch(cfg, CELL, batch=2, seq=32).items()}
    loss, grads = jax.value_and_grad(lambda p: _loss(model, cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b",
                                  "recurrentgemma-9b", "rwkv6-1.6b",
                                  "whisper-base"])
def test_decode_matches_full_forward(arch):
    cfg = registry.get_config(arch, reduced=True)
    model = build_model(cfg, RUN)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(0)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    if cfg.encoder_layers > 0:
        frames = jnp.asarray(rng.normal(size=(B, S // 2, cfg.d_model))
                             .astype(np.float32))
        enc = model.encode(params, frames)
        xkv = model._cross_kv(params, enc)
        h, _ = model._dec_forward(params, toks, xkv, "train", None, None)
        full = model._logits(params, h[:, -1:])
        cache = model.init_cache(B, S, dtype=jnp.float32)
        cache, _ = model.prefill(params, toks[:, :S - 1], cache, frames)
        _, dec = model.decode_step(params, toks[:, S - 1:S], cache,
                                   jnp.int32(S - 1))
    else:
        h, _ = model.hidden(params, toks, mode="train")
        full = model.logits(params, h[:, -1:])
        cache = model.init_cache(B, S, dtype=jnp.float32)
        cache, _ = model.prefill(params, toks[:, :S - 1], cache)
        _, dec = model.decode_step(params, toks[:, S - 1:S], cache,
                                   jnp.int32(S - 1))
    assert float(jnp.max(jnp.abs(full - dec))) < 2e-3, arch


def test_moe_decode_matches_with_high_capacity():
    cfg = dataclasses.replace(registry.get_config("dbrx-132b", reduced=True),
                              moe_capacity_factor=8.0)
    model = build_model(cfg, RUN)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    h, _ = model.hidden(params, toks, mode="train")
    full = model.logits(params, h[:, -1:])
    cache = model.init_cache(B, S, dtype=jnp.float32)
    cache, _ = model.prefill(params, toks[:, :S - 1], cache)
    _, dec = model.decode_step(params, toks[:, S - 1:S], cache, jnp.int32(S - 1))
    assert float(jnp.max(jnp.abs(full - dec))) < 2e-3


def test_sliding_window_cache_rolls():
    """gemma2-style local layer with S > window: rolling cache equals the
    full-forward last-token logits."""
    cfg = registry.get_config("gemma2-2b", reduced=True)   # window 16
    model = build_model(cfg, RUN)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(0)
    B, S = 1, 30   # exceeds window 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    h, _ = model.hidden(params, toks, mode="train")
    full = model.logits(params, h[:, -1:])
    cache = model.init_cache(B, S, dtype=jnp.float32)
    cache, _ = model.prefill(params, toks[:, :S - 1], cache)
    _, dec = model.decode_step(params, toks[:, S - 1:S], cache, jnp.int32(S - 1))
    assert float(jnp.max(jnp.abs(full - dec))) < 2e-3


def test_blockwise_attention_matches_naive():
    from repro.models.layers import blockwise_attention

    rng = np.random.default_rng(0)
    B, S, H, Hkv, Dh = 2, 37, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32))
    for window in (None, 9):
        out = blockwise_attention(q, k, v, causal=True, window=window,
                                  q_chunk=8, kv_chunk=8)
        # naive reference
        kk = jnp.repeat(k, H // Hkv, axis=2)
        vv = jnp.repeat(v, H // Hkv, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(Dh)
        pos = np.arange(S)
        mask = pos[None, :] <= pos[:, None]
        if window is not None:
            mask &= pos[None, :] > pos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_vocab_padding_masked():
    cfg = registry.get_config("whisper-base", reduced=True)
    from repro.models.transformer import padded_vocab
    assert padded_vocab(cfg) % 256 == 0
    model = build_model(cfg, RUN)
    params = model.init(jax.random.key(0))
    assert params["embed"]["tok"].shape[0] == padded_vocab(cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32))
    frames = jnp.asarray(rng.normal(size=(1, 4, cfg.d_model)).astype(np.float32))
    cache = model.init_cache(1, 16, dtype=jnp.float32)
    cache, logits = model.prefill(params, toks, cache, frames)
    pad_region = np.asarray(logits)[..., cfg.vocab_size:]
    assert np.all(pad_region < -1e20), "pad logits must be -inf-ish"
