"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    layer_pattern="G", rope_theta=5e5,
    moe=True, num_experts=16, experts_per_token=4,
    act="silu", norm="rmsnorm", tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512,
    layer_pattern="G", moe=True, num_experts=4, experts_per_token=2,
    act="silu", norm="rmsnorm", tie_embeddings=False,
)
