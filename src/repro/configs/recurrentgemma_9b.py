"""recurrentgemma-9b [hybrid] — RG-LRU + local attn 2:1 [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    layer_pattern="RRL", sliding_window=2048, rnn_width=4096,
    act="gelu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
)

REDUCED = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_pattern="RRL", sliding_window=16, rnn_width=64,
    act="gelu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
)
