"""internvl2-76b [vlm] — InternViT (stubbed) + 70B-class LLM [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    layer_pattern="G", rope_theta=5e5,
    act="silu", norm="rmsnorm", tie_embeddings=False,
    frontend="vision", frontend_tokens=256,
)

REDUCED = ModelConfig(
    name="internvl2-76b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_pattern="G", act="silu", norm="rmsnorm", tie_embeddings=False,
    frontend="vision", frontend_tokens=8,
)
