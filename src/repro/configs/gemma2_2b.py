"""gemma2-2b [dense] — local+global alternating, logit softcap [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    layer_pattern="LG", sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    act="gelu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
)

REDUCED = ModelConfig(
    name="gemma2-2b-smoke", family="dense",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_pattern="LG", sliding_window=16,
    attn_softcap=50.0, logit_softcap=30.0,
    act="gelu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
)
