"""granite-moe-1b-a400m [moe] — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    layer_pattern="G",
    moe=True, num_experts=32, experts_per_token=8,
    act="silu", norm="rmsnorm", tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="granite-moe-1b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512,
    layer_pattern="G", moe=True, num_experts=4, experts_per_token=2,
    act="silu", norm="rmsnorm", tie_embeddings=True,
)
