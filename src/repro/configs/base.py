"""Model / run configuration dataclasses and the shape-cell registry."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # layer pattern, cycled over the layer stack:
    #   G = global attention block   L = sliding-window attention block
    #   R = RG-LRU recurrent block   K = RWKV6 block
    # MoE applies to the FFN of every block when moe=True.
    layer_pattern: str = "G"

    # attention features
    qk_norm: bool = False
    attn_softcap: Optional[float] = None       # gemma2: 50.0
    logit_softcap: Optional[float] = None      # gemma2: 30.0
    rope_theta: float = 10000.0
    sliding_window: int = 4096

    # MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # recurrent (RG-LRU / RWKV6)
    rnn_width: int = 0               # 0 -> d_model
    conv_width: int = 4

    # encoder-decoder (whisper)
    encoder_layers: int = 0          # >0 => enc-dec; num_layers = decoder layers

    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_tokens: int = 256       # vision: patch embeddings prepended

    act: str = "silu"                # silu | gelu
    mlp_gated: bool = True           # gated (llama-style) vs plain 2-layer MLP
    embed_scale: bool = False        # gemma-style sqrt(d_model) embed scaling
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer needs a full-length KV cache for decode that
        grows quadratically with context in prefill (SSM/hybrid/local)."""
        return not any(c == "G" for c in self.layer_pattern)

    def pattern_for_layers(self) -> list[str]:
        pat = self.layer_pattern
        return [pat[i % len(pat)] for i in range(self.num_layers)]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Parallelism / numerics knobs resolved per (arch x shape x mesh)."""

    sharding_mode: str = "fsdp"      # "tp" (DP+TP) | "fsdp" (adds param sharding over data)
    param_dtype: str = "float32"     # master params
    compute_dtype: str = "bfloat16"
    remat: str = "block"             # none | block | full
    microbatch: int = 1              # grad-accumulation steps
    loss_chunk: int = 2048           # sequence chunk for vocab-sharded loss
    q_chunk: int = 1024              # blockwise attention chunks
    kv_chunk: int = 1024
    zero1: bool = True               # shard optimizer state over data axis
    grad_compression: str = "none"   # none | bf16 | topk  (GraphH hybrid comm)
    seq_shard_decode: bool = False   # flash-decoding over the data axis
    # --- §Perf knobs (baselines use the defaults) ---
    attn_shard: str = "heads"        # "heads" | "flat": constrain qkv on the
    #   flattened H*Dh dim (always divisible) instead of the head dim —
    #   keeps the projections tensor-parallel when H % tp_size != 0
    tp_comm: str = "activation"      # "activation" | "weight": weight-gathered
    #   TP for long-sequence inference (all-gather weights, not activations)
    scores_dtype: str = "float32"    # attention probability dtype (bf16 opt)
