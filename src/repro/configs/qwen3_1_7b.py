"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936,
    layer_pattern="G", qk_norm=True, rope_theta=1e6,
    act="silu", norm="rmsnorm", tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen3-1.7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_pattern="G", qk_norm=True, rope_theta=1e6,
    act="silu", norm="rmsnorm", tie_embeddings=True,
)
