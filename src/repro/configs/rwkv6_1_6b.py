"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    layer_pattern="K",
    act="silu", norm="layernorm", tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="rwkv6-1.6b-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=2, num_kv_heads=2, head_dim=64,
    d_ff=256, vocab_size=512,
    layer_pattern="K",
    act="silu", norm="layernorm", tie_embeddings=True,
)
