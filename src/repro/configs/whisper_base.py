"""whisper-base [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, encoder_layers=6,
    d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    layer_pattern="G",
    act="gelu", mlp_gated=False, norm="layernorm",
    tie_embeddings=True, frontend="audio",
)

REDUCED = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    num_layers=2, encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_pattern="G", act="gelu", mlp_gated=False, norm="layernorm",
    tie_embeddings=True, frontend="audio",
)
