"""Architecture registry: ``--arch <id>`` resolution, per-cell input specs,
and per-(arch x shape) runnability rules (long_500k skip list etc.)."""
from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, SHAPE_CELLS, ShapeCell

ARCH_MODULES = {
    "whisper-base": "repro.configs.whisper_base",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
}

ARCH_IDS = list(ARCH_MODULES)

# long_500k needs a sub-quadratic/KV-bounded decode path; pure full-attention
# archs are skipped per the assignment (DESIGN.md §6).  gemma2-2b runs: its
# local layers use a rolling window cache and its global layers' decode is
# O(S) per token.
LONG_CONTEXT_ARCHS = {"rwkv6-1.6b", "recurrentgemma-9b", "gemma2-2b"}

# archs where params+optimizer must shard over data too (FSDP)
FSDP_ARCHS = {"qwen3-14b", "deepseek-7b", "internvl2-76b", "dbrx-132b",
              "recurrentgemma-9b"}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(ARCH_MODULES[arch])
    return mod.REDUCED if reduced else mod.CONFIG


def cell_runnable(arch: str, cell_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-not) for an (arch, shape-cell) pair."""
    if cell_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k decode cache excluded by assignment"
    return True, ""


def default_run_config(arch: str, cell: ShapeCell,
                       n_devices: int = 256) -> RunConfig:
    fsdp = arch in FSDP_ARCHS
    micro = 1
    if cell.kind == "train":
        micro = 4 if arch in ("internvl2-76b", "dbrx-132b") else 2
    return RunConfig(
        sharding_mode="fsdp" if fsdp else "tp",
        remat="block" if cell.kind == "train" else "none",
        microbatch=micro,
        q_chunk=min(512, cell.seq_len),
        kv_chunk=min(512, cell.seq_len),
        loss_chunk=min(512, cell.seq_len),
    )


# ---------------------------------------------------------------------------
# input specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell,
                batch_override: Optional[int] = None) -> dict:
    """Abstract inputs for (arch, cell) — no allocation, dry-run safe.

    train:   tokens [B, S] + labels [B, S] (+ frontend embeds)
    prefill: tokens [B, S] (+ frontend embeds)
    decode:  token [B, 1] + cache handled by the serve step builder
    """
    B = batch_override or cell.global_batch
    S = cell.seq_len
    i32 = jnp.int32
    f32 = jnp.bfloat16
    sd = jax.ShapeDtypeStruct

    if cfg.encoder_layers > 0:  # whisper: enc frames stub + decoder tokens
        enc_len = S // 2
        specs = {
            "enc_frames": sd((B, enc_len, cfg.d_model), f32),
            "tokens": sd((B, S), i32),
        }
        if cell.kind == "train":
            specs["labels"] = sd((B, S), i32)
        if cell.kind == "decode":
            specs["tokens"] = sd((B, 1), i32)
        return specs

    if cfg.frontend == "vision":
        ft = cfg.frontend_tokens
        specs = {
            "patch_embeds": sd((B, ft, cfg.d_model), f32),
            "tokens": sd((B, S - ft), i32),
        }
        if cell.kind == "train":
            specs["labels"] = sd((B, S), i32)
        if cell.kind == "decode":
            specs = {"tokens": sd((B, 1), i32)}
        return specs

    if cell.kind == "decode":
        return {"tokens": sd((B, 1), i32)}
    specs = {"tokens": sd((B, S), i32)}
    if cell.kind == "train":
        specs["labels"] = sd((B, S), i32)
    return specs


def synthetic_batch(cfg: ModelConfig, cell: ShapeCell, batch: int,
                    seq: Optional[int] = None, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (for smoke tests/examples)."""
    import dataclasses

    rng = np.random.default_rng(seed)
    cell2 = dataclasses.replace(cell, seq_len=seq or cell.seq_len,
                                global_batch=batch)
    out: dict = {}
    for k, spec in input_specs(cfg, cell2).items():
        if k in ("tokens", "labels"):
            out[k] = rng.integers(0, cfg.vocab_size, spec.shape).astype(np.int32)
        else:
            out[k] = rng.normal(size=spec.shape).astype(np.float32)
    return out
