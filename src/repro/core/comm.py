"""Hybrid communication (paper §III-D-3).

Dense mode ships a |V| value array (+ update bitvector); sparse mode ships
(index, value) pairs for updated vertices only.  The paper switches to
sparse when the updated ratio drops below a threshold (0.4), and compresses
payloads (snappy by default).

Two layers:

  * host accounting (``plan_broadcast``/``plan_broadcast_intervals``) —
    used by the out-of-core engine to measure real payload bytes per
    superstep, including real zstd compression of the actual buffers
    (paper Fig. 9).  The payload builders/decoders here
    (``dense_payload``/``sparse_payload``/``multi_query_payload`` and
    their ``decode_*`` inverses) are also the wire formats the cluster
    transport ships between real server processes (core/transport.py,
    DESIGN.md §11).
  * device collectives (``hybrid_broadcast``) — shard_map building block:
    dense = psum of the additive delta; sparse = fixed-capacity
    all_gather of compacted (idx, delta) pairs; ``lax.cond`` picks at run
    time from the measured update density.  Value payloads can be narrowed
    to bf16 — the TPU-native analogue of byte-stream compression.
"""
from __future__ import annotations

import atexit
import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.graphio import formats

DENSITY_THRESHOLD = 0.4  # paper's sparsity switch point

# compressor name -> formats.MODE_CODECS mode (paper default: snappy; we use
# the zstd ladder, transparently zlib when zstandard is absent — compat.py)
COMPRESSORS = {"none": 1, "zstd-1": 2, "zstd-3": 3, "zstd-9": 4}


def resolve_compressor(name: str) -> tuple[int, str]:
    """Validate a compressor name and return (mode, actual codec label) —
    the label reflects what will really run, e.g. ``zlib-1`` when
    repro.compat has fallen back from zstd to stdlib zlib."""
    mode = COMPRESSORS.get(name)
    if mode is None:
        raise ValueError(
            f"unknown compressor {name!r}; valid: {', '.join(sorted(COMPRESSORS))}")
    if mode == 1:
        return mode, "none"
    _, level = formats.MODE_CODECS[mode]
    return mode, f"{'zstd' if compat.HAVE_ZSTD else 'zlib'}-{level}"


# ---------------------------------------------------------------------------
# Host-side accounting (out-of-core engine / benchmarks)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BroadcastRecord:
    """Measured size of one server's per-superstep broadcast payload
    (bytes pre/post compression + the mode the planner chose)."""
    mode: str                 # "dense" | "sparse" | "mixed" (2-D payloads)
    raw_bytes: int            # pre-compression payload
    wire_bytes: int           # post-compression payload
    density: float
    compressor: str
    # multi-query payloads: per-query-column mode choices ("dense"/"sparse"),
    # None for classic 1-D payloads
    query_modes: Optional[tuple] = None
    # interval-sharded payloads (DESIGN.md §10): number of dirty intervals
    # shipped; None for classic whole-V payloads
    intervals: Optional[int] = None


def dense_payload(values: np.ndarray, updated: np.ndarray) -> bytes:
    """Dense wire payload: ``ceil(V/8)``-byte update bitvector followed by
    the full ``[V]`` value array (raw little-endian bytes).  Inverse:
    :func:`decode_dense_payload`."""
    bitvec = np.packbits(updated.astype(np.uint8))
    return bitvec.tobytes() + values.tobytes()


def sparse_payload(values: np.ndarray, updated: np.ndarray) -> bytes:
    """Sparse wire payload: ``[U]`` uint32 updated vertex ids followed by
    their ``[U]`` values (raw bytes).  Inverse:
    :func:`decode_sparse_payload`."""
    idx = np.nonzero(updated)[0].astype(np.uint32)
    return idx.tobytes() + values[idx].tobytes()


def decode_dense_payload(buf: bytes, nv: int,
                         dtype) -> tuple[np.ndarray, np.ndarray]:
    """Invert :func:`dense_payload`: returns (updated vertex ids ``[U]``,
    their values ``[U]``) — value bytes round-trip exactly (no float
    re-encoding), which is what keeps cluster results bit-identical."""
    dtype = np.dtype(dtype)
    nb = (nv + 7) // 8
    bits = np.unpackbits(np.frombuffer(buf, np.uint8, count=nb))[:nv]
    vals = np.frombuffer(buf, dtype, count=nv, offset=nb)
    idx = np.nonzero(bits)[0].astype(np.int64)
    return idx, vals[idx].copy()


def decode_sparse_payload(buf: bytes, dtype) -> tuple[np.ndarray, np.ndarray]:
    """Invert :func:`sparse_payload`: returns (updated vertex ids ``[U]``,
    values ``[U]``).  The entry count is derived from the byte length
    (each entry is 4 index bytes + one value)."""
    dtype = np.dtype(dtype)
    per = 4 + dtype.itemsize
    count = len(buf) // per
    idx = np.frombuffer(buf, np.uint32, count=count).astype(np.int64)
    vals = np.frombuffer(buf, dtype, count=count, offset=4 * count)
    return idx, vals.copy()


def multi_query_payload(
    values: np.ndarray,          # [V, Q]
    updated: np.ndarray,         # [V, Q] bool
    threshold: float = DENSITY_THRESHOLD,
    mode: str = "hybrid",
) -> tuple[bytes, tuple]:
    """2-D broadcast payload (DESIGN.md §9) over values ``[V, Q]`` and the
    bool updated mask ``[V, Q]``: density is measured *per query column*.
    Dense columns ship a ceil(V/8) bitvector + the full column;
    sparse columns pool their updates into one packed section of
    (vertex: uint32, query: uint32) pairs followed by the values.  Returns
    (payload bytes, per-column mode tuple)."""
    nv, nq = values.shape
    parts: list[bytes] = []
    modes: list[str] = []
    sp_pairs: list[np.ndarray] = []
    sp_vals: list[np.ndarray] = []
    for q in range(nq):
        col_upd = updated[:, q]
        density_q = float(col_upd.mean()) if nv else 0.0
        use_dense = mode == "dense" or (mode == "hybrid" and density_q >= threshold)
        if use_dense:
            parts.append(dense_payload(values[:, q], col_upd))
            modes.append("dense")
        else:
            idx = np.nonzero(col_upd)[0].astype(np.uint32)
            sp_pairs.append(np.stack(
                [idx, np.full(idx.shape, q, dtype=np.uint32)], axis=1))
            sp_vals.append(values[idx, q])
            modes.append("sparse")
    if sp_pairs:
        pairs = np.concatenate(sp_pairs, axis=0)
        vals = np.concatenate(sp_vals, axis=0)
        parts.append(pairs.tobytes() + vals.tobytes())
    return b"".join(parts), tuple(modes)


def decode_multi_query_payload(
    buf: bytes, nv: int, qmodes: tuple, dtype,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Invert :func:`multi_query_payload` given the per-column mode tuple
    (carried in the transport frame header).

    Returns (updated vertex ids ``[U]``, values ``[U, Q]``, per-query
    updated mask ``[U, Q]``) — the same sparse-update triple the engine's
    barrier apply consumes.  Cells where the mask is False hold zeros; the
    engine only applies masked cells, so this is lossless."""
    dtype = np.dtype(dtype)
    nq = len(qmodes)
    off = 0
    cell_v: list[np.ndarray] = []
    cell_q: list[np.ndarray] = []
    cell_val: list[np.ndarray] = []
    for q, m in enumerate(qmodes):
        if m != "dense":
            continue
        nb = (nv + 7) // 8
        col_idx, col_vals = decode_dense_payload(
            buf[off: off + nb + nv * dtype.itemsize], nv, dtype)
        off += nb + nv * dtype.itemsize
        cell_v.append(col_idx)
        cell_q.append(np.full(col_idx.shape, q, dtype=np.int64))
        cell_val.append(col_vals)
    if any(m == "sparse" for m in qmodes):
        rest = buf[off:]
        per = 8 + dtype.itemsize
        count = len(rest) // per
        pairs = np.frombuffer(rest, np.uint32, count=2 * count).reshape(-1, 2)
        vals = np.frombuffer(rest, dtype, count=count, offset=8 * count)
        cell_v.append(pairs[:, 0].astype(np.int64))
        cell_q.append(pairs[:, 1].astype(np.int64))
        cell_val.append(vals.copy())
    if not cell_v:
        return (np.zeros(0, np.int64), np.zeros((0, nq), dtype),
                np.zeros((0, nq), dtype=bool))
    v = np.concatenate(cell_v)
    qcol = np.concatenate(cell_q)
    cval = np.concatenate(cell_val)
    idx, inv = np.unique(v, return_inverse=True)
    vals_out = np.zeros((len(idx), nq), dtype)
    mask_out = np.zeros((len(idx), nq), dtype=bool)
    vals_out[inv, qcol] = cval
    mask_out[inv, qcol] = True
    return idx, vals_out, mask_out


def plan_broadcast(
    values: np.ndarray,
    updated: np.ndarray,
    threshold: float = DENSITY_THRESHOLD,
    compressor: str = "zstd-1",       # paper default: snappy
    mode: str = "hybrid",             # "dense" | "sparse" | "hybrid"
) -> BroadcastRecord:
    """Measure one server's broadcast payload.  ``values``/``updated`` are
    ``[V]`` (classic) or ``[V, Q]`` (multi-query; per-column mode choice,
    see :func:`multi_query_payload`)."""
    comp_mode, codec = resolve_compressor(compressor)
    density = float(updated.mean()) if updated.size else 0.0
    if values.ndim == 2:
        payload, qmodes = multi_query_payload(values, updated, threshold, mode)
        uniq = set(qmodes)
        rec_mode = "sparse" if not qmodes else (
            qmodes[0] if len(uniq) == 1 else "mixed")
    else:
        use_dense = mode == "dense" or (mode == "hybrid" and density >= threshold)
        payload = (dense_payload(values, updated) if use_dense
                   else sparse_payload(values, updated))
        rec_mode, qmodes = ("dense" if use_dense else "sparse"), None
    raw = len(payload)
    wire = len(formats.compress_blob(payload, comp_mode))
    return BroadcastRecord(
        mode=rec_mode, raw_bytes=raw, wire_bytes=wire, density=density,
        compressor=codec, query_modes=qmodes,
    )


# 8-byte header per dirty-interval section: (interval id: u32, count: u32).
INTERVAL_HEADER_BYTES = 8


def plan_broadcast_intervals(
    idx: np.ndarray,              # [U] updated global vertex ids
    vals: np.ndarray,             # [U] or [U, Q] updated values
    mask: Optional[np.ndarray],   # [U, Q] per-query updated mask, or None
    splitter: np.ndarray,         # int64[K + 1] interval boundaries
    threshold: float = DENSITY_THRESHOLD,
    compressor: str = "zstd-1",
    mode: str = "hybrid",
) -> BroadcastRecord:
    """Measure one server's broadcast sharded per *dirty interval*
    (DESIGN.md §10) instead of one whole-V payload.  Shapes: idx ``[U]``
    global vertex ids, vals ``[U(, Q)]``, mask ``[U, Q]`` or None,
    splitter ``[K+1]`` interval boundaries.

    Each interval that received updates ships its own section — an 8-byte
    (interval id, count) header plus a :func:`plan_broadcast` payload built
    over that interval's local vertex range — so receivers holding their
    vertex state out of core apply updates block by block and clean
    intervals cost zero bytes.  Density on the sparse/dense switch is
    *local* to the interval, which is strictly better than the global
    switch when updates cluster (a dense-in-one-interval frontier no
    longer drags the whole |V| array onto the wire)."""
    _, codec = resolve_compressor(compressor)
    splitter = np.asarray(splitter, dtype=np.int64)
    nv = int(splitter[-1])
    qa = vals.shape[1] if vals.ndim == 2 else None
    cells = nv * (qa or 1)
    if len(idx) == 0:
        return BroadcastRecord(mode="interval", raw_bytes=0, wire_bytes=0,
                               density=0.0, compressor=codec, intervals=0)
    ivs = np.searchsorted(splitter, idx, side="right") - 1
    raw = wire = 0
    count = 0
    updated_cells = 0
    for iv in np.unique(ivs):
        lo, hi = int(splitter[iv]), int(splitter[iv + 1])
        sel = ivs == iv
        local = idx[sel] - lo
        n = hi - lo
        if qa is not None:
            dense = np.zeros((n, qa), dtype=vals.dtype)
            upd = np.zeros((n, qa), dtype=bool)
            dense[local] = vals[sel]
            upd[local] = mask[sel]
        else:
            dense = np.zeros(n, dtype=vals.dtype)
            upd = np.zeros(n, dtype=bool)
            dense[local] = vals[sel]
            upd[local] = True
        rec = plan_broadcast(dense, upd, threshold=threshold,
                             compressor=compressor, mode=mode)
        raw += rec.raw_bytes + INTERVAL_HEADER_BYTES
        wire += rec.wire_bytes + INTERVAL_HEADER_BYTES
        count += 1
        updated_cells += int(upd.sum())
    return BroadcastRecord(
        mode="interval", raw_bytes=raw, wire_bytes=wire,
        density=updated_cells / max(cells, 1), compressor=codec,
        intervals=count,
    )


def plan_broadcast_intervals_async(*args, **kw) -> "Future[BroadcastRecord]":
    """Submit :func:`plan_broadcast_intervals` onto the comm executor."""
    return _comm_pool().submit(plan_broadcast_intervals, *args, **kw)


# Payload compression is CPU-bound byte work with no dependence on the next
# server's gather/apply, so the pipelined engine ships it to a small executor
# and collects the BroadcastRecords at the superstep barrier (the "tile N-1
# broadcast-compression" leg of the I/O-compute-comm overlap).  Two workers:
# one per in-flight payload is plenty, and zlib/zstd release the GIL.
_COMM_POOL: Optional[ThreadPoolExecutor] = None
_COMM_POOL_LOCK = threading.Lock()


def _comm_pool() -> ThreadPoolExecutor:
    # Double-checked locking: concurrent first callers must share ONE
    # executor (an unguarded None-check let two threads each create a pool
    # and leak one of them), and the surviving pool is shut down at
    # interpreter exit instead of leaking its worker threads.
    global _COMM_POOL
    pool = _COMM_POOL
    if pool is None:
        with _COMM_POOL_LOCK:
            if _COMM_POOL is None:
                _COMM_POOL = ThreadPoolExecutor(
                    max_workers=2, thread_name_prefix="graphh-comm")
                atexit.register(_shutdown_comm_pool)
            pool = _COMM_POOL
    return pool


def _shutdown_comm_pool() -> None:
    global _COMM_POOL
    with _COMM_POOL_LOCK:
        pool, _COMM_POOL = _COMM_POOL, None
    if pool is not None:
        pool.shutdown(wait=False)


def plan_broadcast_async(
    values: np.ndarray,
    updated: np.ndarray,
    threshold: float = DENSITY_THRESHOLD,
    compressor: str = "zstd-1",
    mode: str = "hybrid",
) -> "Future[BroadcastRecord]":
    """Submit :func:`plan_broadcast` onto the comm executor over values
    ``[V(, Q)]`` and the updated mask ``[V(, Q)]``.  The caller owns
    ``values``/``updated`` after submission — pass freshly built arrays."""
    return _comm_pool().submit(plan_broadcast, values, updated,
                               threshold=threshold, compressor=compressor,
                               mode=mode)


# ---------------------------------------------------------------------------
# Device-side collectives (distributed GAB)
# ---------------------------------------------------------------------------

def sparse_capacity(num_vertices: int, threshold: float = DENSITY_THRESHOLD,
                    align: int = 128) -> int:
    """Static capacity for the sparse branch: density < threshold by
    construction, so ceil(threshold * V) entries always suffice."""
    k = int(np.ceil(num_vertices * threshold))
    return min(num_vertices, ((k + align - 1) // align) * align)


def dense_broadcast(old: jax.Array, new_masked: jax.Array,
                    updated: jax.Array, axis_names) -> jax.Array:
    """Dense mode: psum of masked new values + update flags.  Tiles own
    disjoint rows, so at most one server contributes per vertex.  (Masked
    values rather than additive deltas: +/-inf-valued programs like SSSP
    would produce inf-inf=NaN under a delta formulation.)  Shape-
    polymorphic: works for [V] and [V, Q] alike (elementwise + psum)."""
    vals = jax.lax.psum(new_masked, axis_names)
    cnt = jax.lax.psum(updated.astype(jnp.float32), axis_names)
    return jnp.where(cnt > 0, vals, old)


def sparse_broadcast(old: jax.Array, new_masked: jax.Array,
                     updated: jax.Array, capacity: int,
                     axis_name: str, value_dtype=None) -> jax.Array:
    """Sparse mode: compact (idx, new value), all_gather, scatter-set.

    Safety: the fixed-size ``jnp.nonzero`` compaction silently truncates
    when a shard has more than ``capacity`` updates — under forced
    ``mode="sparse"`` nothing upstream guarantees that bound (the hybrid
    path's density switch does).  The overflow check is *global* (pmax of
    per-shard update counts) so every shard takes the same branch and the
    collectives stay matched; on overflow the whole step falls back to a
    dense psum broadcast instead of dropping updates.

    2-D ``[V, Q]`` inputs are flattened so the compaction packs
    (vertex, query) cells; ``capacity`` then bounds flat cell updates.
    """
    if old.ndim > 1:
        shape = old.shape
        out = sparse_broadcast(old.reshape(-1), new_masked.reshape(-1),
                               updated.reshape(-1), capacity, axis_name,
                               value_dtype)
        return out.reshape(shape)
    nv = old.shape[0]
    if capacity >= nv:       # cannot truncate: skip the guard entirely
        return _sparse_broadcast_unchecked(old, new_masked, updated, capacity,
                                           axis_name, value_dtype)
    local_count = jnp.sum(updated.astype(jnp.int32))
    max_count = jax.lax.pmax(local_count, axis_name)

    def dense_fn(_):
        return dense_broadcast(old, new_masked, updated, axis_name)

    def sparse_fn(_):
        return _sparse_broadcast_unchecked(old, new_masked, updated, capacity,
                                           axis_name, value_dtype)

    return jax.lax.cond(max_count > capacity, dense_fn, sparse_fn,
                        operand=None)


def _sparse_broadcast_unchecked(old: jax.Array, new_masked: jax.Array,
                                updated: jax.Array, capacity: int,
                                axis_name: str, value_dtype=None) -> jax.Array:
    nv = old.shape[0]
    (idx,) = jnp.nonzero(updated, size=capacity, fill_value=nv)
    vals = jnp.where(idx < nv, new_masked[jnp.minimum(idx, nv - 1)], 0.0)
    if value_dtype is not None:
        vals = vals.astype(value_dtype).astype(old.dtype)
    all_idx = jax.lax.all_gather(idx, axis_name)        # [N, K]
    all_val = jax.lax.all_gather(vals, axis_name)       # [N, K]
    flat_idx = all_idx.reshape(-1)
    flat_val = all_val.reshape(-1).astype(old.dtype)
    # fill slots (idx == nv) land in the sink row of a padded buffer
    out = jnp.concatenate([old, jnp.zeros((1,), old.dtype)])
    out = out.at[flat_idx].set(flat_val, mode="drop")
    return out[:nv]


def hybrid_broadcast(
    old: jax.Array,
    new_masked: jax.Array,
    updated: jax.Array,
    axis_name: str,
    capacity: Optional[int] = None,
    threshold: float = DENSITY_THRESHOLD,
    mode: str = "hybrid",
    value_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (new values replicated across servers, global update density).

    mode="hybrid" follows the paper: measure the *global* density and pick
    dense (psum) vs sparse (compact+all_gather) inside lax.cond.

    ``[V, Q]`` multi-query state is handled by flattening to ``V*Q`` cells
    (density and sparse capacity are then measured over (vertex, query)
    pairs) and reshaping the result back.
    """
    if old.ndim > 1:
        shape = old.shape
        out, density = hybrid_broadcast(
            old.reshape(-1), new_masked.reshape(-1), updated.reshape(-1),
            axis_name, capacity=capacity, threshold=threshold, mode=mode,
            value_dtype=value_dtype)
        return out.reshape(shape), density
    nv = old.shape[0]
    capacity = capacity or sparse_capacity(nv, threshold)
    local_updates = jnp.sum(updated.astype(jnp.float32))
    global_updates = jax.lax.psum(local_updates, axis_name)
    density = global_updates / nv

    if mode == "dense":
        return dense_broadcast(old, new_masked, updated, axis_name), density
    if mode == "sparse":
        # forced sparse: sparse_broadcast's global overflow guard falls back
        # to dense when any shard's update count exceeds capacity
        return sparse_broadcast(old, new_masked, updated, capacity,
                                axis_name, value_dtype), density

    def dense_fn(_):
        return dense_broadcast(old, new_masked, updated, axis_name)

    # Unchecked is safe only when capacity covers the density switch point:
    # the sparse branch then runs only at global density < threshold, and
    # capacity >= ceil(threshold * nv) bounds every local update count.  A
    # caller-supplied smaller capacity keeps the overflow guard.
    safe_sparse = (_sparse_broadcast_unchecked
                   if capacity >= int(np.ceil(nv * threshold))
                   else sparse_broadcast)

    def sparse_fn(_):
        return safe_sparse(old, new_masked, updated, capacity,
                           axis_name, value_dtype)

    out = jax.lax.cond(density >= threshold, dense_fn, sparse_fn, operand=None)
    return out, density


def wire_bytes_estimate(num_vertices: int, density: float, itemsize: int = 4,
                        threshold: float = DENSITY_THRESHOLD,
                        index_bytes: int = 4) -> int:
    """Analytic per-server payload size (paper Fig. 9 model).

    ``index_bytes`` is the per-update index overhead on the sparse path:
    4 for classic 1-D payloads (uint32 vertex), 8 for multi-query 2-D
    payloads (uint32 vertex + uint32 query pair) — callers estimating a
    flattened [V, Q] payload pass ``num_vertices=V*Q, index_bytes=8``."""
    if density >= threshold:
        # bitvector is np.packbits output: ceil(V / 8) bytes
        return (num_vertices + 7) // 8 + num_vertices * itemsize
    u = int(density * num_vertices)
    return u * (index_bytes + itemsize)


# ---------------------------------------------------------------------------
# Session admission records (DESIGN.md §13)
# ---------------------------------------------------------------------------

def pack_admissions(admit=(), drain=(), pending: int = 0):
    """Pack a barrier's admission control record, or ``None`` when empty.

    ``admit`` is a sequence of ``(global qid, seed vertex)`` pairs for the
    query columns every rank must splice at this barrier; ``drain`` the
    global qids to force-retire; ``pending`` the number of queries still
    queued behind the slot limit (peers use it to keep the superstep loop
    alive while rank 0 has admissible backlog).  The record is JSON-safe —
    it rides in the transport frame header (``encode_frame(control=...)``)
    so all ranks see it at the same barrier as the update set."""
    admit = [[int(g), int(s)] for g, s in admit]
    drain = [int(g) for g in drain]
    if not admit and not drain and not pending:
        return None
    return {"admit": admit, "drain": drain, "pending": int(pending)}


def unpack_admissions(control) -> tuple[list, list, int]:
    """Invert :func:`pack_admissions`; ``None`` means an empty record."""
    if not control:
        return [], [], 0
    return (
        [(int(g), int(s)) for g, s in control.get("admit", [])],
        [int(g) for g in control.get("drain", [])],
        int(control.get("pending", 0)),
    )
