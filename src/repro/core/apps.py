"""Vertex-centric applications implemented with GAB (paper Algorithms 6/7).

PageRank and SSSP follow the paper's pseudo-code exactly; WCC, BFS and
in-degree-count are standard extras exercising min/sum monoids.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.gab import VertexProgram


@dataclasses.dataclass(eq=False)
class PageRank(VertexProgram):
    """Paper Algorithm 6 — unnormalized damped PageRank.

    gather: sum of src.value / src.out_degree over in-edges
    apply : 0.15 + 0.85 * accum
    """

    damping: float = 0.85
    combine: str = "sum"
    src_aux: tuple[str, ...] = ("inv_out_degree",)
    dst_aux: tuple[str, ...] = ()
    update_tol: float = 1e-9

    def init(self, num_vertices, out_degree, in_degree, **kw):
        inv = np.zeros(num_vertices, dtype=np.float32)
        nz = out_degree > 0
        inv[nz] = 1.0 / out_degree[nz]
        return {
            "value": np.full(num_vertices, 1.0, dtype=np.float32),
            "inv_out_degree": inv,
        }

    def gather(self, src_value, edge_val, aux):
        # edge_val is 1.0 for real edges and 0.0 for padding -> padding inert.
        return src_value * aux["inv_out_degree"] * edge_val

    def apply(self, old_value, accum, aux):
        return (1.0 - self.damping) + self.damping * accum


@dataclasses.dataclass(eq=False)
class SSSP(VertexProgram):
    """Paper Algorithm 7 — single-source shortest paths (min-plus)."""

    source: int = 0
    combine: str = "min"
    src_aux: tuple[str, ...] = ()
    dst_aux: tuple[str, ...] = ()

    def init(self, num_vertices, out_degree, in_degree, **kw):
        v = np.full(num_vertices, np.inf, dtype=np.float32)
        v[self.source] = 0.0
        return {"value": v}

    def gather(self, src_value, edge_val, aux):
        # Padding has edge_val == 0 but routes to the sink row anyway; use a
        # plain min-plus message.  inf + w == inf keeps unreached sources inert.
        return src_value + edge_val

    def apply(self, old_value, accum, aux):
        return jnp.minimum(old_value, accum)


@dataclasses.dataclass(eq=False)
class WCC(VertexProgram):
    """Weakly-connected components by min-label propagation.  Run on a
    symmetrized edge set for true WCC semantics."""

    combine: str = "min"

    def init(self, num_vertices, out_degree, in_degree, **kw):
        return {"value": np.arange(num_vertices, dtype=np.float32)}

    def gather(self, src_value, edge_val, aux):
        # Padded edges go to the sink row; forward src label as-is.
        return src_value

    def apply(self, old_value, accum, aux):
        return jnp.minimum(old_value, accum)


@dataclasses.dataclass(eq=False)
class BFS(VertexProgram):
    """Level-synchronous BFS (hop counts) from ``source``."""

    source: int = 0
    combine: str = "min"

    def init(self, num_vertices, out_degree, in_degree, **kw):
        v = np.full(num_vertices, np.inf, dtype=np.float32)
        v[self.source] = 0.0
        return {"value": v}

    def gather(self, src_value, edge_val, aux):
        return src_value + 1.0

    def apply(self, old_value, accum, aux):
        return jnp.minimum(old_value, accum)


@dataclasses.dataclass(eq=False)
class InDegree(VertexProgram):
    """Sanity app: value converges to in-degree after one superstep."""

    combine: str = "sum"

    def init(self, num_vertices, out_degree, in_degree, **kw):
        return {"value": np.zeros(num_vertices, dtype=np.float32)}

    def gather(self, src_value, edge_val, aux):
        return edge_val * 0.0 + jnp.where(edge_val > 0, 1.0, 0.0)

    def apply(self, old_value, accum, aux):
        return accum


APPS = {
    "pagerank": PageRank,
    "sssp": SSSP,
    "wcc": WCC,
    "bfs": BFS,
    "indegree": InDegree,
}
