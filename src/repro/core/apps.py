"""Vertex-centric applications implemented with GAB (paper Algorithms 6/7).

PageRank and SSSP follow the paper's pseudo-code exactly; WCC, BFS and
in-degree-count are standard extras exercising min/sum monoids.

Batched (multi-query) programs — DESIGN.md §9: PersonalizedPageRank,
MultiSourceBFS and LandmarkDistances evaluate Q program instances in one
edge pass; vertex state is [V, Q] and per-column convergence lets the
engine retire finished queries early.  Their hooks receive [E, Q] / [R, Q]
arrays and broadcast the shared 1-D aux/edge terms explicitly, so each
column's float ops are identical to a Q=1 run of the same program —
batched results are bit-identical to independent runs.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax.numpy as jnp
import numpy as np

from repro.core.gab import VertexProgram


class _BatchedQueries:
    """Mixin giving batched programs a uniform query interface.

    ``query_field`` names the dataclass field holding the per-query seed
    tuple (``seeds``/``sources``/``landmarks``); ``queries`` reads it and
    ``with_queries`` rebuilds the program for a different batch.  The engine
    session uses ``with_queries`` to construct the init state for columns
    admitted mid-run (DESIGN.md §13) — column math is independent of which
    other queries share the batch, so a spliced column is bit-identical to a
    fresh single-query run.
    """

    query_field: ClassVar[str] = "seeds"

    @property
    def queries(self) -> tuple[int, ...]:
        """The per-query seed vertices, one query column per entry."""
        return tuple(getattr(self, self.query_field))

    def with_queries(self, queries):
        """A copy of this program evaluating exactly ``queries`` columns."""
        return dataclasses.replace(self, **{self.query_field: tuple(queries)})


@dataclasses.dataclass(eq=False)
class PageRank(VertexProgram):
    """Paper Algorithm 6 — unnormalized damped PageRank.

    gather: sum of src.value / src.out_degree over in-edges
    apply : 0.15 + 0.85 * accum
    """

    damping: float = 0.85
    combine: str = "sum"
    src_aux: tuple[str, ...] = ("inv_out_degree",)
    dst_aux: tuple[str, ...] = ()
    update_tol: float = 1e-9

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V] = 1.0 (float32) + inv_out_degree [V] src aux."""
        inv = np.zeros(num_vertices, dtype=np.float32)
        nz = out_degree > 0
        inv[nz] = 1.0 / out_degree[nz]
        return {
            "value": np.full(num_vertices, 1.0, dtype=np.float32),
            "inv_out_degree": inv,
        }

    def gather(self, src_value, edge_val, aux):
        # edge_val is 1.0 for real edges and 0.0 for padding -> padding inert.
        # Association matters: src · (inv · ev) is the fused kernel's form
        # (the scale stream is pre-folded as a = inv · ev), so the unfused
        # path must group the same way to stay bit-identical on *weighted*
        # edges, where ev != 1.0 makes the two groupings round differently.
        """Per-edge message [E]: src rank / out-degree (padding inert: edge_val == 0)."""
        return src_value * (aux["inv_out_degree"] * edge_val)

    def apply(self, old_value, accum, aux):
        """Damped update over [R] rows: (1 - d) + d * accum."""
        return (1.0 - self.damping) + self.damping * accum

    def fused_spec(self):
        """Fused form: contrib = src · (inv_out_degree · edge_val), damped
        affine apply — the same association as :meth:`gather`, so the two
        paths agree bit-for-bit on weighted and unweighted edges alike
        (padding reduces into the discarded sink row)."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="sum", scale_aux="inv_out_degree",
                         apply="affine", alpha=1.0 - self.damping,
                         beta=self.damping, update_tol=self.update_tol)


@dataclasses.dataclass(eq=False)
class SSSP(VertexProgram):
    """Paper Algorithm 7 — single-source shortest paths (min-plus)."""

    source: int = 0
    combine: str = "min"
    src_aux: tuple[str, ...] = ()
    dst_aux: tuple[str, ...] = ()

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V] = +inf except 0.0 at ``source`` (float32)."""
        v = np.full(num_vertices, np.inf, dtype=np.float32)
        v[self.source] = 0.0
        return {"value": v}

    def gather(self, src_value, edge_val, aux):
        # Padding has edge_val == 0 but routes to the sink row anyway; use a
        # plain min-plus message.  inf + w == inf keeps unreached sources inert.
        """Min-plus message [E]: src distance + edge weight (inf stays inert)."""
        return src_value + edge_val

    def apply(self, old_value, accum, aux):
        """Relaxation over [R] rows: min(old distance, best incoming)."""
        return jnp.minimum(old_value, accum)

    def fused_spec(self):
        """Fused form: contrib = src + edge_val, min-relax apply."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="min", add_edge=True, apply="min")


@dataclasses.dataclass(eq=False)
class WCC(VertexProgram):
    """Weakly-connected components by min-label propagation.  Run on a
    symmetrized edge set for true WCC semantics."""

    combine: str = "min"

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V] = own vertex id as float32 label."""
        return {"value": np.arange(num_vertices, dtype=np.float32)}

    def gather(self, src_value, edge_val, aux):
        # Padded edges go to the sink row; forward src label as-is.
        """Label message [E]: forward the src label unchanged."""
        return src_value

    def apply(self, old_value, accum, aux):
        """Label update over [R] rows: min(old label, smallest incoming)."""
        return jnp.minimum(old_value, accum)

    def fused_spec(self):
        """Fused form: contrib = src (label forward), min-merge apply."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="min", apply="min")


@dataclasses.dataclass(eq=False)
class BFS(VertexProgram):
    """Level-synchronous BFS (hop counts) from ``source``."""

    source: int = 0
    combine: str = "min"

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V] = +inf hops except 0.0 at ``source``."""
        v = np.full(num_vertices, np.inf, dtype=np.float32)
        v[self.source] = 0.0
        return {"value": v}

    def gather(self, src_value, edge_val, aux):
        """Hop message [E]: src hop count + 1."""
        return src_value + 1.0

    def apply(self, old_value, accum, aux):
        """Hop update over [R] rows: min(old, best incoming)."""
        return jnp.minimum(old_value, accum)

    def fused_spec(self):
        """Fused form: contrib = src + 1, min-relax apply."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="min", add_const=1.0, apply="min")


@dataclasses.dataclass(eq=False)
class InDegree(VertexProgram):
    """Sanity app: value converges to in-degree after one superstep."""

    combine: str = "sum"

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V] = 0.0 counts."""
        return {"value": np.zeros(num_vertices, dtype=np.float32)}

    def gather(self, src_value, edge_val, aux):
        """Count message [E]: 1.0 per real edge, 0.0 for padding."""
        return edge_val * 0.0 + jnp.where(edge_val > 0, 1.0, 0.0)

    def apply(self, old_value, accum, aux):
        """Replace with the summed count over [R] rows."""
        return accum


# ---------------------------------------------------------------------------
# Batched multi-query programs (DESIGN.md §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class PersonalizedPageRank(_BatchedQueries, VertexProgram):
    """Q-seed personalized PageRank: column q solves
    ``pr = (1-d) * e_{seed_q} + d * P^T pr`` — teleport mass concentrated
    on that query's seed vertex instead of spread uniformly.

    One batched run shares every tile visit across all Q seed queries; the
    engine retires each column as it converges.
    """

    seeds: tuple[int, ...] = (0,)
    damping: float = 0.85
    combine: str = "sum"
    src_aux: tuple[str, ...] = ("inv_out_degree",)
    dst_aux: tuple[str, ...] = ("seed_mass",)
    update_tol: float = 1e-9

    @property
    def num_queries(self) -> int:
        """Q = number of seed vertices (one query column per seed)."""
        return len(self.seeds)

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V, Q] = seed one-hot mass; inv_out_degree [V]
        (shared) + seed_mass [V, Q] (per-query teleport vector)."""
        q = len(self.seeds)
        inv = np.zeros(num_vertices, dtype=np.float32)
        nz = out_degree > 0
        inv[nz] = 1.0 / out_degree[nz]
        seed_mass = np.zeros((num_vertices, q), dtype=np.float32)
        seed_mass[np.asarray(self.seeds, dtype=np.int64), np.arange(q)] = 1.0
        return {
            "value": seed_mass.copy(),   # start with all mass on the seed
            "inv_out_degree": inv,       # [V]: shared across queries
            "seed_mass": seed_mass,      # [V, Q]: per-query teleport vector
        }

    def gather(self, src_value, edge_val, aux):
        # src_value [E, Q]; shared per-edge factor broadcast over the query
        # axis (edge_val is 1.0 real / 0.0 padding -> padding inert)
        """Per-edge message [E, Q]: src mass scaled by the shared 1/out-degree
        factor broadcast over the query axis."""
        return src_value * (aux["inv_out_degree"] * edge_val)[:, None]

    def apply(self, old_value, accum, aux):
        """Damped update over [R, Q]: (1 - d) * seed_mass + d * accum."""
        return (1.0 - self.damping) * aux["seed_mass"] + self.damping * accum

    def fused_spec(self):
        """Fused form: contrib = src · (inv_out_degree · edge_val) per
        column, affine apply against the per-query seed_mass base — the
        exact expressions :meth:`gather`/:meth:`apply` trace."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="sum", scale_aux="inv_out_degree",
                         apply="affine", alpha=1.0 - self.damping,
                         beta=self.damping, base_aux="seed_mass",
                         update_tol=self.update_tol)


@dataclasses.dataclass(eq=False)
class MultiSourceBFS(_BatchedQueries, VertexProgram):
    """Level-synchronous BFS from Q sources at once (hop counts per column)."""

    sources: tuple[int, ...] = (0,)
    combine: str = "min"
    query_field: ClassVar[str] = "sources"

    @property
    def num_queries(self) -> int:
        """Q = number of BFS sources (one query column per source)."""
        return len(self.sources)

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V, Q] = +inf hops except 0.0 at each source."""
        q = len(self.sources)
        v = np.full((num_vertices, q), np.inf, dtype=np.float32)
        v[np.asarray(self.sources, dtype=np.int64), np.arange(q)] = 0.0
        return {"value": v}

    def gather(self, src_value, edge_val, aux):
        """Hop message [E, Q]: src hop count + 1, per column."""
        return src_value + 1.0

    def apply(self, old_value, accum, aux):
        """Hop update over [R, Q]: min(old, best incoming) per column."""
        return jnp.minimum(old_value, accum)

    def fused_spec(self):
        """Fused form: contrib = src + 1 per column, min-relax apply."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="min", add_const=1.0, apply="min")


@dataclasses.dataclass(eq=False)
class LandmarkDistances(_BatchedQueries, VertexProgram):
    """Weighted shortest-path distances from Q landmark vertices (min-plus)
    — the batched form of SSSP, e.g. for landmark-based distance oracles."""

    landmarks: tuple[int, ...] = (0,)
    combine: str = "min"
    query_field: ClassVar[str] = "landmarks"

    @property
    def num_queries(self) -> int:
        """Q = number of landmarks (one query column per landmark)."""
        return len(self.landmarks)

    def init(self, num_vertices, out_degree, in_degree, **kw):
        """Initial state: value [V, Q] = +inf except 0.0 at each landmark."""
        q = len(self.landmarks)
        v = np.full((num_vertices, q), np.inf, dtype=np.float32)
        v[np.asarray(self.landmarks, dtype=np.int64), np.arange(q)] = 0.0
        return {"value": v}

    def gather(self, src_value, edge_val, aux):
        # min-plus message per column; inf + w == inf keeps unreached inert
        """Min-plus message [E, Q]: src distance + edge weight per column."""
        return src_value + edge_val[:, None]

    def apply(self, old_value, accum, aux):
        """Relaxation over [R, Q]: min(old, best incoming) per column."""
        return jnp.minimum(old_value, accum)

    def fused_spec(self):
        """Fused form: contrib = src + edge_val per column, min-relax."""
        from repro.kernels.gab_fused import FusedSpec
        return FusedSpec(combine="min", add_edge=True, apply="min")


APPS = {
    "pagerank": PageRank,
    "sssp": SSSP,
    "wcc": WCC,
    "bfs": BFS,
    "indegree": InDegree,
    "ppr": PersonalizedPageRank,
    "msbfs": MultiSourceBFS,
    "landmarks": LandmarkDistances,
}
