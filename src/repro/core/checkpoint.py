"""Superstep-boundary graph checkpoints (DESIGN.md §12).

Builds on ``train.checkpoint.CheckpointManager`` (staged tmp-dir write,
atomic rename publish, ``LATEST`` pointer, keep-last-k GC) and adds what
the graph engine needs:

  * a **manifest** riding in ``meta.json`` — the superstep to resume at,
    live query columns, retirement/convergence state, and the per-server
    tile assignment (replicated, so any rank can restart from it and an
    N→M resize is just ``elastic.remap_assignment`` over it); serving
    sessions (DESIGN.md §13) extend it with per-slot query lineage —
    ``queries`` ({global qid: seed vertex} for every column ever
    admitted), ``admitted_at`` (per-column admission superstep) and
    ``next_qid`` — so a resumed session keeps renumbering and per-query
    accounting exactly where the saved one stopped;
  * **interval-block payloads** for ooc vertex state: each
    ``VertexStateStore`` block is serialized via its coldest
    already-current representation (``vstate.export_block`` — no
    recompression of clean spilled blocks) into ``blocks/``, and blocks
    unchanged since the previous checkpoint (version-tracked) are
    **hardlinked** from it instead of rewritten — the incremental flush
    the dirty-writeback invariant makes possible;
  * **collision-safe publish** for multi-rank writers: vertex state is
    fully replicated (All-in-All), so checkpoints at the same superstep
    are byte-identical on every rank; staging dirs are pid-suffixed and
    whichever rank publishes first wins, the rest discard.

Crash anywhere — including mid-write, torn by ``runtime.faults`` — and a
reader sees either the previous complete checkpoint or the new one,
never a mix.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Optional

import numpy as np

from repro.graphio import formats
from repro.train.checkpoint import CheckpointManager

#: manifest schema marker (DESIGN.md §12)
MANIFEST_KIND = "graphh-superstep"


@dataclasses.dataclass
class GraphCheckpoint:
    """One loaded checkpoint: ``manifest`` (see DESIGN.md §12 for the
    schema), ``state`` (the saved leaf arrays, nested dict), and
    ``vstate`` (ooc interval arrays reassembled to full ``[V(,Q)]``
    ndarrays keyed by name; empty for in-memory saves)."""

    step: int
    manifest: dict
    state: dict
    vstate: dict

    def live_queries(self) -> dict[int, int]:
        """{global qid: seed vertex} for the query columns still live at
        this checkpoint — what a resumed serving session (DESIGN.md §13)
        re-registers before admitting new work.  Pre-session checkpoints
        carry no lineage; they resume with an empty map."""
        seeds = {int(g): int(s)
                 # lint: allow(GH205): JSON manifest dict, keyed lookup only
                 for g, s in self.manifest.get("queries", {}).items()}
        return {int(g): seeds.get(int(g), -1)
                for g in self.manifest.get("active_q", [])}


class GraphCheckpointer(CheckpointManager):
    """Checkpoint writer/reader for the superstep engine (module docstring).

    One instance per (engine, program) — ``directory`` is per-program in
    multi-program cluster launches.  Rank 0 writes the periodic
    checkpoints; preempted ranks may also save, and the pid-suffixed
    staging + first-publish-wins rename keeps concurrent writers safe."""

    def __init__(self, directory: str, keep: int = 2, fault=None):
        super().__init__(directory, keep=max(keep, 2), compress=False,
                         fault=fault)
        # (name, k) -> vstate block version at the last save, plus where
        # that save lives and its block metadata — the hardlink source
        self._versions: dict = {}
        self._last_dir: Optional[str] = None
        self._last_blocks: dict = {}

    # -- multi-writer safety -------------------------------------------------
    def _tmp_dir(self, step: int) -> str:
        """Pid-suffixed staging dir: two ranks saving the same superstep
        (preemption races) stage independently and race only on the
        atomic rename below."""
        return self._step_dir(step) + f".tmp.{os.getpid()}"

    def _publish(self, step: int, tmp: str) -> str:
        """First-publish-wins: replicated state makes same-step checkpoints
        byte-identical across ranks, so a loser just discards its copy."""
        final = self._step_dir(step)
        if os.path.isdir(final):
            shutil.rmtree(tmp, ignore_errors=True)
            return final
        try:
            os.replace(tmp, final)
        except OSError:
            # lost the rename race to a peer rank — its copy is identical
            shutil.rmtree(tmp, ignore_errors=True)
        return final

    # -- save ----------------------------------------------------------------
    def save_graph(self, superstep: int, state: dict, manifest: dict,
                   vstore=None) -> str:
        """Write one superstep-boundary checkpoint.

        ``state``: leaf arrays (values/aux/updated_ids/...), saved via the
        parent's staged-leaf path.  ``manifest``: the resume metadata dict
        (stored under meta.json ``extra``).  ``vstore``: when the engine
        runs ooc, its ``VertexStateStore`` — every interval block is
        flushed through its coldest current representation, unchanged
        blocks hardlink to the previous checkpoint's copy."""
        manifest = dict(manifest, kind=MANIFEST_KIND)
        tmp, meta = self._stage(superstep, state, extra_meta=manifest)
        new_versions: dict = {}
        if vstore is not None:
            bdir = os.path.join(tmp, "blocks")
            os.makedirs(bdir, exist_ok=True)
            arrays_meta: dict = {}
            for name in vstore.names():
                dt, tail = vstore.spec(name)
                entries = []
                for k in range(vstore.num_intervals):
                    ver = vstore.block_version(name, k)
                    fn = f"{name}.{k}.blk"
                    entry = self._stage_block(vstore, name, k, ver,
                                              os.path.join(bdir, fn),
                                              superstep)
                    entry["file"] = fn
                    entries.append(entry)
                    new_versions[(name, k)] = ver
                arrays_meta[name] = dict(dtype=np.dtype(dt).str,
                                         tail=list(tail), blocks=entries)
            manifest["vstate"] = dict(
                splitter=[int(x) for x in vstore.splitter],
                arrays=arrays_meta)
            meta["extra"] = manifest
        final = self._finalize(superstep, tmp, meta)
        if vstore is not None:
            self._versions = new_versions
            self._last_dir = final
            self._last_blocks = manifest["vstate"]["arrays"]
        return final

    def _stage_block(self, vstore, name: str, k: int, ver: int,
                     dest: str, superstep: int) -> dict:
        """Stage one interval block file; hardlink the previous save's copy
        when the block version is unchanged (fallback: copy, then
        re-export).  Returns its manifest entry ({"mode": int})."""
        prev_ver = self._versions.get((name, k))
        if (prev_ver == ver and self._last_dir is not None):
            src = os.path.join(self._last_dir, "blocks", f"{name}.{k}.blk")
            prev_entry = next(
                (e for e in self._last_blocks.get(name, {}).get("blocks", [])
                 if e.get("file") == f"{name}.{k}.blk"), None)
            if prev_entry is not None and os.path.exists(src):
                try:
                    # lint: allow(GH301): dest is inside the pid-suffixed staging dir built by save_graph
                    os.link(src, dest)
                    return {"mode": prev_entry["mode"]}
                except OSError:
                    try:
                        # lint: allow(GH301): dest is inside the pid-suffixed staging dir built by save_graph
                        shutil.copy2(src, dest)
                        return {"mode": prev_entry["mode"]}
                    except OSError:
                        pass        # source vanished mid-copy: re-export
        mode, blob = vstore.export_block(name, k)
        if self.fault is not None:
            self.fault.write(dest, blob, "ckpt.block", superstep)
        else:
            # lint: allow(GH301): dest is inside the pid-suffixed staging dir built by save_graph
            with open(dest, "wb") as f:
                f.write(blob)
        return {"mode": int(mode)}

    # -- load ----------------------------------------------------------------
    def peek_manifest(self) -> Optional[tuple[int, dict]]:
        """(step, manifest) of the latest checkpoint without loading any
        array — what engine construction reads to adopt the saved tile
        assignment (cheap JSON).  None when no checkpoint exists."""
        step = self.latest_step()
        if step is None:
            return None
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            meta = json.load(f)
        return step, meta.get("extra", {})

    def load_graph(self, step: Optional[int] = None
                   ) -> Optional[GraphCheckpoint]:
        """Load the latest (or a specific) checkpoint: manifest + leaf
        state + ooc interval arrays reassembled into full ndarrays.
        Returns None when the directory holds no checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        manifest = meta.get("extra", {})
        _, state = self.restore(step)
        vstate: dict = {}
        vs = manifest.get("vstate")
        if vs:
            splitter = np.asarray(vs["splitter"], dtype=np.int64)
            # lint: allow(GH205): JSON-loaded dict — order fixed by the manifest file
            for name, info in vs["arrays"].items():
                dt = np.dtype(info["dtype"])
                tail = tuple(info["tail"])
                parts = []
                for k, entry in enumerate(info["blocks"]):
                    lo, hi = int(splitter[k]), int(splitter[k + 1])
                    with open(os.path.join(d, "blocks", entry["file"]),
                              "rb") as f:
                        raw = formats.decompress_blob(f.read(),
                                                      int(entry["mode"]))
                    parts.append(np.frombuffer(raw, dtype=dt).reshape(
                        (hi - lo,) + tail))
                vstate[name] = np.concatenate(parts)
        return GraphCheckpoint(step=step, manifest=manifest, state=state,
                               vstate=vstate)
