"""Distributed GAB on a device mesh via shard_map.

Mapping of the paper's cluster onto a TPU mesh (DESIGN.md §3):

  servers (MPI ranks)   -> mesh axes, e.g. ("pod", "data")
  workers (OpenMP)      -> "model" axis (more tile shards per server)
  AA vertex replication -> vertex values replicated across the whole mesh
  tile assignment       -> stacked tile arrays sharded on the leading axis
  Broadcast             -> psum of update-masked values (dense) or fixed-
                           capacity all_gather of (idx, val) pairs (sparse),
                           chosen by measured update density (hybrid, lax.cond)

The same superstep function serves (a) real execution on however many local
devices exist and (b) the production-mesh dry-run via .lower()/.compile().
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked

from repro.core import comm
from repro.core.gab import VertexProgram, stacked_tiles_step
from repro.core.tiles import Tile, stack_tiles


@dataclasses.dataclass
class DistConfig:
    comm_mode: str = "hybrid"       # dense | sparse | hybrid
    threshold: float = comm.DENSITY_THRESHOLD
    seg_impl: str = "jnp"
    wire_dtype: Optional[str] = None   # e.g. "bfloat16" for compressed wire
    max_supersteps: int = 200


def pad_tile_count(num_tiles: int, num_shards: int) -> int:
    return ((num_tiles + num_shards - 1) // num_shards) * num_shards


def make_empty_tile_arrays(stk: dict) -> dict:
    """An inert tile: every edge points at the global sink row, zero rows."""
    ecap, rcap = stk["edge_cap"], stk["row_cap"]
    return dict(
        src=np.zeros((1, ecap), np.int32),
        dst_local=np.full((1, ecap), rcap, np.int32),
        val=np.zeros((1, ecap), np.float32),
        row_start=np.zeros((1,), np.int32),
        num_rows=np.zeros((1,), np.int32),
        num_edges=np.zeros((1,), np.int32),
    )


def pad_stack_to(stk: dict, total: int) -> dict:
    """Pad a ``stack_tiles`` dict along the tile axis to exactly ``total``
    tiles using inert tiles (all edges at the sink row, zero rows).  Padding
    changes no per-row result — used by the distributed engine to even out
    shards and by the pipelined engine to fix the batch shape."""
    pad = total - len(stk["row_start"])
    if pad > 0:
        empty = make_empty_tile_arrays(stk)
        for k in ("src", "dst_local", "val", "row_start", "num_rows", "num_edges"):
            stk[k] = np.concatenate([stk[k]] + [empty[k]] * pad, axis=0)
    return stk


def stack_and_pad(tiles: list[Tile], row_cap: int, num_shards: int) -> dict:
    """Stack tiles and pad the tile axis to a multiple of num_shards."""
    stk = stack_tiles(tiles, row_cap)
    return pad_stack_to(stk, pad_tile_count(len(tiles), num_shards))


def build_superstep(
    prog: VertexProgram,
    mesh: Mesh,
    tile_axes: tuple[str, ...],
    row_cap: int,
    num_vertices: int,
    cfg: DistConfig = DistConfig(),
):
    """Returns a jit-able superstep: (values, aux, stk) -> (values', density).

    values/aux are replicated; stk arrays are sharded along ``tile_axes``.
    Multi-query programs (values [V, Q]) work unchanged: the stacked step
    is shape-polymorphic and hybrid_broadcast flattens to (vertex, query)
    cells — sparse capacity is therefore scaled by Q.
    """
    nq = max(getattr(prog, "num_queries", 1), 1)
    capacity = comm.sparse_capacity(num_vertices * nq, cfg.threshold)
    axis = tile_axes if len(tile_axes) > 1 else tile_axes[0]

    def local_step(values, aux, src, dst_local, val, row_start, num_rows):
        stk = dict(src=src, dst_local=dst_local, val=val,
                   row_start=row_start, num_rows=num_rows)
        new_masked, upd = stacked_tiles_step(
            prog, values, aux, stk, row_cap, cfg.seg_impl
        )
        new_values, density = comm.hybrid_broadcast(
            values, new_masked, upd, axis,
            capacity=capacity, threshold=cfg.threshold,
            mode=cfg.comm_mode,
            value_dtype=None if cfg.wire_dtype is None else jnp.dtype(cfg.wire_dtype),
        )
        return new_values, density

    tile_spec = P(axis)
    rep = P()
    fn = shard_map_unchecked(
        local_step,
        mesh=mesh,
        in_specs=(rep, rep, tile_spec, tile_spec, tile_spec, tile_spec, tile_spec),
        out_specs=(rep, rep),
    )

    def superstep(values, aux, stk):
        return fn(values, aux, stk["src"], stk["dst_local"], stk["val"],
                  stk["row_start"], stk["num_rows"])

    return superstep


class DistributedGABEngine:
    """In-memory distributed GAB over the local device set (the multi-device
    execution path; the out-of-core disk tier is engine.py's job)."""

    def __init__(self, mesh: Mesh, tile_axes: tuple[str, ...],
                 cfg: DistConfig = DistConfig()):
        self.mesh = mesh
        self.tile_axes = tile_axes
        self.cfg = cfg
        self.num_shards = int(np.prod([mesh.shape[a] for a in tile_axes]))

    def shard_tiles(self, tiles: list[Tile], row_cap: int) -> dict:
        stk = stack_and_pad(tiles, row_cap, self.num_shards)
        sharding = NamedSharding(
            self.mesh,
            P(self.tile_axes if len(self.tile_axes) > 1 else self.tile_axes[0]),
        )
        out = {}
        for k in ("src", "dst_local", "val", "row_start", "num_rows"):
            out[k] = jax.device_put(stk[k], sharding)
        out["row_cap"] = stk["row_cap"]
        out["edge_cap"] = stk["edge_cap"]
        return out

    def run(self, prog: VertexProgram, tiles: list[Tile], num_vertices: int,
            out_degree: np.ndarray, in_degree: np.ndarray,
            row_cap: int, max_supersteps: Optional[int] = None):
        state = prog.init(num_vertices, out_degree.astype(np.float64),
                          in_degree.astype(np.float64))
        rep = NamedSharding(self.mesh, P())
        values = jax.device_put(jnp.asarray(state.pop("value")), rep)
        aux = {k: jax.device_put(jnp.asarray(v), rep) for k, v in state.items()}
        stk = self.shard_tiles(tiles, row_cap)

        step = jax.jit(build_superstep(
            prog, self.mesh, self.tile_axes, row_cap, num_vertices, self.cfg
        ))
        history = []
        max_ss = max_supersteps or self.cfg.max_supersteps
        for ss in range(max_ss):
            values, density = step(values, aux, stk)
            d = float(density)
            history.append(dict(superstep=ss, density=d))
            if d == 0.0:
                break
        return np.asarray(values), history
