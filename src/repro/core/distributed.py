"""Distributed GAB: the device-mesh path (shard_map) and the
multi-process cluster exchange protocol (DESIGN.md §11).

Mapping of the paper's cluster onto a TPU mesh (DESIGN.md §3):

  servers (MPI ranks)   -> mesh axes, e.g. ("pod", "data")
  workers (OpenMP)      -> "model" axis (more tile shards per server)
  AA vertex replication -> vertex values replicated across the whole mesh
  tile assignment       -> stacked tile arrays sharded on the leading axis
  Broadcast             -> psum of update-masked values (dense) or fixed-
                           capacity all_gather of (idx, val) pairs (sparse),
                           chosen by measured update density (hybrid, lax.cond)

The same superstep function serves (a) real execution on however many local
devices exist and (b) the production-mesh dry-run via .lower()/.compile().

The second half of this module is the *process* cluster: ``ClusterExchange``
implements the per-superstep BSP barrier between N real server processes —
encode this server's updates into a ``core.transport`` frame, broadcast it
to the N-1 peers, merge the peers' decoded frames in rank order, and (with
stealing enabled) rebalance tile ownership from the measured per-server
compute times.  The out-of-core engine calls it at its barrier when built
with ``server_rank``/``exchange`` (engine.py); ``launch.cluster`` owns the
process spawning.
"""
from __future__ import annotations

import dataclasses
import struct
import threading
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked

from repro.core import comm, transport as transport_mod
from repro.core.gab import VertexProgram, stacked_tiles_step
from repro.core.tiles import Tile, stack_tiles


@dataclasses.dataclass
class DistConfig:
    """Knobs for the device-mesh (shard_map) distributed engine."""
    comm_mode: str = "hybrid"       # dense | sparse | hybrid
    threshold: float = comm.DENSITY_THRESHOLD
    seg_impl: str = "jnp"
    wire_dtype: Optional[str] = None   # e.g. "bfloat16" for compressed wire
    max_supersteps: int = 200


def pad_tile_count(num_tiles: int, num_shards: int) -> int:
    """Round ``num_tiles`` up to a multiple of ``num_shards``."""
    return ((num_tiles + num_shards - 1) // num_shards) * num_shards


def make_empty_tile_arrays(stk: dict) -> dict:
    """An inert tile: every edge points at the global sink row, zero rows."""
    ecap, rcap = stk["edge_cap"], stk["row_cap"]
    return dict(
        src=np.zeros((1, ecap), np.int32),
        dst_local=np.full((1, ecap), rcap, np.int32),
        val=np.zeros((1, ecap), np.float32),
        row_start=np.zeros((1,), np.int32),
        num_rows=np.zeros((1,), np.int32),
        num_edges=np.zeros((1,), np.int32),
    )


def pad_stack_to(stk: dict, total: int) -> dict:
    """Pad a ``stack_tiles`` dict along the tile axis to exactly ``total``
    tiles using inert tiles (all edges at the sink row, zero rows).  Padding
    changes no per-row result — used by the distributed engine to even out
    shards and by the pipelined engine to fix the batch shape."""
    pad = total - len(stk["row_start"])
    if pad > 0:
        empty = make_empty_tile_arrays(stk)
        for k in ("src", "dst_local", "val", "row_start", "num_rows", "num_edges"):
            stk[k] = np.concatenate([stk[k]] + [empty[k]] * pad, axis=0)
    return stk


def stack_and_pad(tiles: list[Tile], row_cap: int, num_shards: int) -> dict:
    """Stack tiles and pad the tile axis to a multiple of num_shards."""
    stk = stack_tiles(tiles, row_cap)
    return pad_stack_to(stk, pad_tile_count(len(tiles), num_shards))


def build_superstep(
    prog: VertexProgram,
    mesh: Mesh,
    tile_axes: tuple[str, ...],
    row_cap: int,
    num_vertices: int,
    cfg: DistConfig = DistConfig(),
):
    """Returns a jit-able superstep: (values, aux, stk) -> (values', density).

    values/aux are replicated; stk arrays are sharded along ``tile_axes``.
    Multi-query programs (values [V, Q]) work unchanged: the stacked step
    is shape-polymorphic and hybrid_broadcast flattens to (vertex, query)
    cells — sparse capacity is therefore scaled by Q.
    """
    nq = max(getattr(prog, "num_queries", 1), 1)
    capacity = comm.sparse_capacity(num_vertices * nq, cfg.threshold)
    axis = tile_axes if len(tile_axes) > 1 else tile_axes[0]

    def local_step(values, aux, src, dst_local, val, row_start, num_rows):
        stk = dict(src=src, dst_local=dst_local, val=val,
                   row_start=row_start, num_rows=num_rows)
        new_masked, upd = stacked_tiles_step(
            prog, values, aux, stk, row_cap, cfg.seg_impl
        )
        new_values, density = comm.hybrid_broadcast(
            values, new_masked, upd, axis,
            capacity=capacity, threshold=cfg.threshold,
            mode=cfg.comm_mode,
            value_dtype=None if cfg.wire_dtype is None else jnp.dtype(cfg.wire_dtype),
        )
        return new_values, density

    tile_spec = P(axis)
    rep = P()
    fn = shard_map_unchecked(
        local_step,
        mesh=mesh,
        in_specs=(rep, rep, tile_spec, tile_spec, tile_spec, tile_spec, tile_spec),
        out_specs=(rep, rep),
    )

    def superstep(values, aux, stk):
        return fn(values, aux, stk["src"], stk["dst_local"], stk["val"],
                  stk["row_start"], stk["num_rows"])

    return superstep


class DistributedGABEngine:
    """In-memory distributed GAB over the local device set (the multi-device
    execution path; the out-of-core disk tier is engine.py's job)."""

    def __init__(self, mesh: Mesh, tile_axes: tuple[str, ...],
                 cfg: DistConfig = DistConfig()):
        self.mesh = mesh
        self.tile_axes = tile_axes
        self.cfg = cfg
        self.num_shards = int(np.prod([mesh.shape[a] for a in tile_axes]))

    def shard_tiles(self, tiles: list[Tile], row_cap: int) -> dict:
        """Stack + pad tiles and device_put the arrays sharded along the tile
        axes; values/aux stay replicated."""
        stk = stack_and_pad(tiles, row_cap, self.num_shards)
        sharding = NamedSharding(
            self.mesh,
            P(self.tile_axes if len(self.tile_axes) > 1 else self.tile_axes[0]),
        )
        out = {}
        for k in ("src", "dst_local", "val", "row_start", "num_rows"):
            out[k] = jax.device_put(stk[k], sharding)
        out["row_cap"] = stk["row_cap"]
        out["edge_cap"] = stk["edge_cap"]
        return out

    def run(self, prog: VertexProgram, tiles: list[Tile], num_vertices: int,
            out_degree: np.ndarray, in_degree: np.ndarray,
            row_cap: int, max_supersteps: Optional[int] = None):
        """Run supersteps to convergence (global update density == 0) or the
        cap; returns (final values [V(, Q)], per-superstep history)."""
        state = prog.init(num_vertices, out_degree.astype(np.float64),
                          in_degree.astype(np.float64))
        rep = NamedSharding(self.mesh, P())
        values = jax.device_put(jnp.asarray(state.pop("value")), rep)
        # lint: allow(GH205): program-defined init dict, consumed by keyed lookup only
        aux = {k: jax.device_put(jnp.asarray(v), rep) for k, v in state.items()}
        stk = self.shard_tiles(tiles, row_cap)

        step = jax.jit(build_superstep(
            prog, self.mesh, self.tile_axes, row_cap, num_vertices, self.cfg
        ))
        history = []
        max_ss = max_supersteps or self.cfg.max_supersteps
        for ss in range(max_ss):
            values, density = step(values, aux, stk)
            d = float(density)
            history.append(dict(superstep=ss, density=d))
            if d == 0.0:
                break
        return np.asarray(values), history


# ---------------------------------------------------------------------------
# Multi-process cluster exchange (DESIGN.md §11)
# ---------------------------------------------------------------------------

# Fixed-width exchange envelope prepended to every frame: (sequence number,
# sender's measured compute seconds, sender's updated-cell count).  Wire
# *measurements* live here — NOT in the frame — so frame bytes are a pure
# function of the update set (plus rank 0's deterministic admission control
# record, which rides in the frame header; DESIGN.md §13) and wire sizes
# are reproducible run to run.
_ENVELOPE = struct.Struct("<IdQ")


@dataclasses.dataclass
class ExchangeResult:
    """Merged cluster-wide update set for one superstep (what the engine's
    barrier apply consumes), plus measured wire accounting and — when
    stealing moved tiles — the next superstep's full tile assignment."""

    idx: np.ndarray                 # [U] updated vertex ids, all servers
    vals: np.ndarray                # [U] or [U, Q] update values
    mask: Optional[np.ndarray]      # [U, Q] per-query mask; None for 1-D
    raw_bytes: int                  # cluster total, pre-compression
    wire_bytes: int                 # cluster total, actual frame bytes
    assignment: Optional[list] = None   # new per-server tile lists, or None
    peer_seconds: dict = dataclasses.field(default_factory=dict)
    #: rank 0's admission/drain control record for this barrier (DESIGN.md
    #: §13) — identical on every rank, None when rank 0 shipped none
    control: Optional[dict] = None


class ClusterExchange:
    """Per-superstep BSP exchange between N server processes.

    Each server encodes its update set into one ``core.transport`` frame
    (hybrid dense/sparse chosen per server per superstep from the measured
    sizes), ships it to all peers, and blocks until every peer's frame for
    the same sequence number has arrived.  A background receiver thread
    drains and *decodes* inbound frames as they arrive, so a fast peer's
    broadcast overlaps this server's remaining tile compute — the
    cluster-level leg of the paper's I/O–compute–comm overlap.

    The merge is deterministic (rank order) and every server derives the
    same merged update set, so convergence checks and multi-query column
    retirement in the engine come out identical on every server with no
    extra control round — the exchange IS the global barrier.

    Stealing: with ``steal=True`` every frame carries its server's
    measured compute seconds; each server runs the same
    ``runtime.scheduler.rebalance_assignment`` on the same inputs, so all
    servers agree on the next superstep's tile ownership without a
    coordinator (the thief reads stolen tiles from the shared store, the
    victim's cache keeps its copies).

    Thread-safety: ``exchange()`` must be called by one thread (the engine
    loop); the receiver thread only touches the inbox under its lock.
    """

    #: lock discipline, enforced by tools/analyze.py --check locks
    #: (_cond wraps the inbox mutex shared with the receiver thread)
    _guarded_by = {"_inbox": "_cond", "_rx_error": "_cond"}

    def __init__(self, transport, *, comm_mode: str = "hybrid",
                 compressor: str = "zstd-1",
                 threshold: float = comm.DENSITY_THRESHOLD,
                 assignment: Optional[list] = None,
                 edges_per_tile: Optional[np.ndarray] = None,
                 steal: bool = False, straggler_factor: float = 1.5,
                 timeout: float = 180.0):
        self.transport = transport
        self.rank, self.n = transport.rank, transport.n
        self.comm_mode = comm_mode
        self.compressor = compressor
        self.threshold = threshold
        self.assignment = ([list(a) for a in assignment]
                           if assignment is not None else None)
        self.edges_per_tile = edges_per_tile
        self.steal = steal and self.n > 1
        self.straggler_factor = straggler_factor
        self.timeout = timeout
        self.steal_moves = 0
        #: bytes this server actually put on the wire / their raw size
        self.sent_wire_bytes = 0
        self.sent_raw_bytes = 0
        self._seq = 0
        self._inbox: dict[int, dict[int, transport_mod.DecodedFrame]] = {}
        self._cond = threading.Condition()
        self._stop = threading.Event()
        self._rx_error: Optional[BaseException] = None
        self._rx: Optional[threading.Thread] = None
        if self.n > 1:
            self._rx = threading.Thread(target=self._recv_loop, daemon=True,
                                        name=f"graphh-exchange-{self.rank}")
            self._rx.start()

    # -- receive side -----------------------------------------------------
    def _recv_loop(self) -> None:
        while not self._stop.is_set():
            item = self.transport.recv(timeout=0.1)
            if item is None:
                continue
            src, payload = item
            try:
                seq, secs, _updates = _ENVELOPE.unpack_from(payload, 0)
                dec = transport_mod.decode_frame(payload[_ENVELOPE.size:])
            except BaseException as exc:  # surfaced on the exchange caller
                with self._cond:
                    self._rx_error = exc
                    self._cond.notify_all()
                return
            with self._cond:
                self._inbox.setdefault(seq, {})[src] = (dec, secs)
                self._cond.notify_all()

    # -- exchange ---------------------------------------------------------
    def exchange(self, *, idx: np.ndarray, vals: np.ndarray,
                 mask: Optional[np.ndarray], nv: int,
                 splitter: Optional[np.ndarray] = None,
                 compute_seconds: float = 0.0,
                 control: Optional[dict] = None) -> ExchangeResult:
        """Broadcast this server's updates (idx ``[U]``, vals ``[U(, Q)]``,
        mask ``[U, Q]`` or None, splitter ``[K+1]``), wait for all peers,
        and return the rank-ordered merged update set (see class docstring).

        ``control`` (rank 0 only) is the session's admission/drain record
        for this barrier; it rides in rank 0's frame header and comes back
        in ``ExchangeResult.control`` on every rank, so all ranks splice
        the same query columns at the same barrier."""
        assert control is None or self.rank == 0, \
            "admission control records originate at rank 0 only"
        seq = self._seq
        self._seq += 1
        updates = int(mask.sum()) if mask is not None else len(idx)
        frame, header = transport_mod.encode_frame(
            idx, vals, mask, nv, splitter=splitter,
            threshold=self.threshold, compressor=self.compressor,
            mode=self.comm_mode, control=control)
        raw_b = header["raw_bytes"]
        wire_b = header["wire_bytes"]
        if self.n > 1:
            self.sent_raw_bytes += raw_b
            self.sent_wire_bytes += wire_b
        peers: dict[int, tuple] = {}
        if self.n > 1:
            env = _ENVELOPE.pack(seq, compute_seconds, updates) + frame
            for dst in range(self.n):
                if dst != self.rank:
                    self.transport.send(dst, env, timeout=self.timeout)
            peers = self._wait_peers(seq)
            # lint: allow(GH205): arrival-ordered; folded with commutative integer addition only
            for dec, _secs in peers.values():
                raw_b += dec.header["raw_bytes"]
                wire_b += dec.header["wire_bytes"]

        parts = []
        secs = {}
        for r in range(self.n):
            if r == self.rank:
                parts.append((idx, vals, mask))
                secs[r] = compute_seconds
            elif r in peers:
                dec, peer_secs = peers[r]
                parts.append((dec.idx, dec.vals, dec.mask))
                secs[r] = peer_secs
        m_idx = np.concatenate([p[0] for p in parts])
        m_val = np.concatenate([p[1] for p in parts])
        m_msk = (np.concatenate([p[2] for p in parts])
                 if mask is not None else None)

        new_assignment = None
        if self.steal and self.assignment is not None:
            from repro.runtime.scheduler import rebalance_assignment

            moved = rebalance_assignment(
                self.assignment, self.edges_per_tile,
                [secs[r] for r in range(self.n)],
                straggler_factor=self.straggler_factor)
            if moved is not None:
                self.assignment, nmoves = moved
                self.steal_moves += nmoves
                new_assignment = [list(a) for a in self.assignment]
        out_control = control
        if self.rank != 0 and 0 in peers:
            out_control = peers[0][0].header.get("control")
        return ExchangeResult(idx=m_idx, vals=m_val, mask=m_msk,
                              raw_bytes=raw_b, wire_bytes=wire_b,
                              assignment=new_assignment, peer_seconds=secs,
                              control=out_control)

    def _wait_peers(self, seq: int) -> dict:
        deadline = time.monotonic() + self.timeout
        with self._cond:
            while True:
                if self._rx_error is not None:
                    raise RuntimeError(
                        f"server {self.rank}: receiver thread failed"
                    ) from self._rx_error
                got = self._inbox.get(seq, {})
                if len(got) == self.n - 1:
                    return self._inbox.pop(seq)
                if not self._cond.wait(timeout=0.1):
                    if time.monotonic() > deadline:
                        missing = [r for r in range(self.n)
                                   if r != self.rank and r not in got]
                        raise TimeoutError(
                            f"server {self.rank} superstep seq {seq}: no "
                            f"frame from peers {missing} within "
                            f"{self.timeout}s")

    def close(self) -> None:
        """Stop the receiver thread (the transport is closed by its owner)."""
        self._stop.set()
        if self._rx is not None:
            self._rx.join(timeout=2.0)
