"""Tile data structures — GraphH's basic graph-processing unit (paper §III-B).

A *tile* holds a contiguous target-vertex (row) range of the |V|x|V|
adjacency matrix with ~S = |E|/P edges, in an "enhanced CSR" layout.

TPU adaptation: XLA wants static shapes, so a tile is materialized as a
*padded sorted-COO* block (`src`, `dst_local`, `val`) of fixed capacity
``edge_cap`` plus a fixed row capacity ``row_cap``.  Padding edges point at a
sink row (index ``row_cap``) so they are algebraically inert for any
monoid with an identity element — no masks needed in the hot loop.  The CSR
``row_ptr`` is kept as well for the scalar-prefetch kernel variant and for
host-side analytics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Sink-row convention: padded edges use dst_local == num_rows(tile) and the
# output buffer has row_cap + 1 rows; the last row is discarded.


@dataclasses.dataclass
class TileMeta:
    """Host-side metadata for one tile (cheap to keep resident)."""

    tile_id: int
    row_start: int          # first target vertex id covered by this tile
    row_end: int            # one past the last target vertex id
    num_edges: int          # real (un-padded) edge count
    edge_cap: int           # padded edge capacity (static shape)
    row_cap: int            # padded row capacity (static shape)
    weighted: bool
    # --- source-interval footprint (DESIGN.md §10; None when the store was
    # built without an interval plan — the engine then computes it lazily) ---
    # interval ids this tile's real src ids touch, ascending
    src_intervals: Optional[tuple] = None
    # cumulative real-edge counts per footprint interval
    # (len == len(src_intervals) + 1); together with Tile.iv_perm these let
    # gather run interval-by-interval over contiguous slices
    src_interval_ptr: Optional[tuple] = None

    @property
    def num_rows(self) -> int:
        """Target rows this tile owns (row_end - row_start)."""
        return self.row_end - self.row_start

    def to_dict(self) -> dict:
        """JSON-serializable form (embedded in the tile blob header)."""
        d = dataclasses.asdict(self)
        if self.src_intervals is not None:
            d["src_intervals"] = list(self.src_intervals)
            d["src_interval_ptr"] = list(self.src_interval_ptr)
        return d

    @staticmethod
    def from_dict(d: dict) -> "TileMeta":
        """Inverse of ``to_dict``."""
        d = dict(d)
        for key in ("src_intervals", "src_interval_ptr"):
            if d.get(key) is not None:
                d[key] = tuple(int(x) for x in d[key])
        return TileMeta(**d)


@dataclasses.dataclass
class Tile:
    """One tile: metadata + padded edge arrays.

    Arrays (all length ``edge_cap`` unless noted):
      src        int32 — global source vertex id (0 for padding)
      dst_local  int32 — target vertex id minus row_start; padding = num_rows
      val        float32 — edge value; absent (None) for unweighted graphs
      row_ptr    int32[num_rows + 1] — CSR offsets into the un-padded prefix
      iv_perm    int32[num_edges] — edge indices bucket-sorted by source
                 interval (stable), or None when no footprint is attached;
                 slice j of ``meta.src_interval_ptr`` selects the edges whose
                 src lives in ``meta.src_intervals[j]``
    """

    meta: TileMeta
    src: np.ndarray
    dst_local: np.ndarray
    val: Optional[np.ndarray]
    row_ptr: np.ndarray
    iv_perm: Optional[np.ndarray] = None

    def nbytes(self) -> int:
        """Uncompressed in-memory array bytes (excludes metadata)."""
        n = self.src.nbytes + self.dst_local.nbytes + self.row_ptr.nbytes
        if self.val is not None:
            n += self.val.nbytes
        return n

    def source_ids(self) -> np.ndarray:
        """Unique real source vertex ids ``[U]`` (for bloom filters / skip
        bitmaps)."""
        return np.unique(self.src[: self.meta.num_edges])

    def validate(self) -> None:
        """Assert every structural invariant (shapes, CSR sort order, padding
        sink rows, footprint consistency) — test/debug aid."""
        m = self.meta
        assert self.src.shape == (m.edge_cap,), (self.src.shape, m.edge_cap)
        assert self.dst_local.shape == (m.edge_cap,)
        assert self.row_ptr.shape == (m.num_rows + 1,)
        assert self.row_ptr[0] == 0 and self.row_ptr[-1] == m.num_edges
        real_dst = self.dst_local[: m.num_edges]
        if m.num_edges:
            assert real_dst.min() >= 0 and real_dst.max() < m.num_rows
            # sorted by target row (CSR invariant)
            assert np.all(np.diff(real_dst) >= 0)
        pad = self.dst_local[m.num_edges :]
        if pad.size:
            assert np.all(pad == m.num_rows)
        if self.val is not None:
            assert self.val.shape == (m.edge_cap,)
        if self.iv_perm is not None:
            assert m.src_intervals is not None and m.src_interval_ptr is not None
            assert self.iv_perm.shape == (m.num_edges,)
            assert len(m.src_interval_ptr) == len(m.src_intervals) + 1
            assert m.src_interval_ptr[0] == 0
            assert m.src_interval_ptr[-1] == m.num_edges


def compute_source_footprint(
    src: np.ndarray, num_edges: int, interval_splitter: np.ndarray
) -> tuple[tuple, tuple, np.ndarray]:
    """Source-interval footprint of a tile's real edges src ``[E]`` under
    interval_splitter ``[K+1]``.

    Returns (interval ids ascending, cumulative edge counts per interval,
    edge-index permutation ``[E]`` bucket-sorting the real edges by
    interval) — the
    layout gather needs to run interval-by-interval with one contiguous
    block read per touched interval."""
    real = np.asarray(src[:num_edges], dtype=np.int64)
    if num_edges == 0:
        return (), (0,), np.zeros(0, dtype=np.int32)
    iv = np.searchsorted(np.asarray(interval_splitter, dtype=np.int64),
                         real, side="right") - 1
    perm = np.argsort(iv, kind="stable").astype(np.int32)
    ids, counts = np.unique(iv, return_counts=True)
    ptr = np.zeros(len(ids) + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    return (tuple(int(i) for i in ids), tuple(int(p) for p in ptr), perm)


def attach_source_footprint(tile: Tile, interval_splitter: np.ndarray) -> Tile:
    """Record the tile's source-interval footprint (interval_splitter
    ``[K+1]``) in its metadata (and the bucket-sort permutation in
    ``iv_perm``).  In place; returns the tile."""
    ids, ptr, perm = compute_source_footprint(
        tile.src, tile.meta.num_edges, interval_splitter)
    tile.meta.src_intervals = ids
    tile.meta.src_interval_ptr = ptr
    tile.iv_perm = perm
    tile.validate()
    return tile


def build_tile(
    tile_id: int,
    row_start: int,
    row_end: int,
    src: np.ndarray,
    dst: np.ndarray,
    val: Optional[np.ndarray],
    edge_cap: int,
    row_cap: int,
    interval_splitter: Optional[np.ndarray] = None,
) -> Tile:
    """Build a padded tile from raw (src ``[E]``, dst ``[E]``[, val
    ``[E]``]) edges with
    row_start <= dst < row_end.  Edges are sorted by (dst, src).  When an
    ``interval_splitter`` is given, the source-interval footprint is
    recorded in the tile's metadata (DESIGN.md §10)."""
    num_edges = int(src.shape[0])
    num_rows = row_end - row_start
    if num_edges > edge_cap:
        raise ValueError(f"tile {tile_id}: {num_edges} edges > edge_cap {edge_cap}")
    if num_rows > row_cap:
        raise ValueError(f"tile {tile_id}: {num_rows} rows > row_cap {row_cap}")

    dst_local = (dst - row_start).astype(np.int32)
    order = np.lexsort((src, dst_local))
    src = src[order].astype(np.int32)
    dst_local = dst_local[order]
    if val is not None:
        val = val[order].astype(np.float32)

    # CSR row pointers over the un-padded prefix.
    counts = np.bincount(dst_local, minlength=num_rows).astype(np.int64)
    row_ptr = np.zeros(num_rows + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])

    # Pad to capacity: sink row, src 0, val 0.
    pad = edge_cap - num_edges
    src_p = np.concatenate([src, np.zeros(pad, dtype=np.int32)])
    dst_p = np.concatenate([dst_local, np.full(pad, num_rows, dtype=np.int32)])
    val_p = None
    if val is not None:
        val_p = np.concatenate([val, np.zeros(pad, dtype=np.float32)])

    meta = TileMeta(
        tile_id=tile_id,
        row_start=int(row_start),
        row_end=int(row_end),
        num_edges=num_edges,
        edge_cap=int(edge_cap),
        row_cap=int(row_cap),
        weighted=val is not None,
    )
    t = Tile(meta=meta, src=src_p, dst_local=dst_p, val=val_p, row_ptr=row_ptr)
    if interval_splitter is not None:
        return attach_source_footprint(t, interval_splitter)
    t.validate()
    return t


def tile_edge_values(tile: Tile) -> np.ndarray:
    """Edge-value array ``[E]`` (E = edge_cap) with inert padding: real val
    (or 1.0 if unweighted), 0.0 for padded slots."""
    if tile.val is not None:
        return tile.val
    v = np.zeros(tile.meta.edge_cap, dtype=np.float32)
    v[: tile.meta.num_edges] = 1.0
    return v


def stack_tiles(tiles: list[Tile], row_cap: int) -> dict:
    """Stack equally-shaped tiles into dense arrays for scan-based processing.

    dst_local is re-padded so every tile uses the *global* sink row
    ``row_cap`` (not its own num_rows) — all tiles then share one output
    shape [row_cap + 1].

    Returns dict of arrays with leading dim = len(tiles):
      src[i, E], dst_local[i, E], val[i, E] (zeros if unweighted),
      row_start[i], num_rows[i], num_edges[i]
    """
    assert tiles, "stack_tiles needs at least one tile"
    ecap = tiles[0].meta.edge_cap
    for t in tiles:
        assert t.meta.edge_cap == ecap, "all tiles must share edge_cap"
        assert t.meta.num_rows <= row_cap
    n = len(tiles)
    src = np.zeros((n, ecap), dtype=np.int32)
    dstl = np.full((n, ecap), row_cap, dtype=np.int32)
    val = np.zeros((n, ecap), dtype=np.float32)
    row_start = np.zeros((n,), dtype=np.int32)
    num_rows = np.zeros((n,), dtype=np.int32)
    num_edges = np.zeros((n,), dtype=np.int32)
    for i, t in enumerate(tiles):
        m = t.meta
        src[i] = t.src
        d = t.dst_local.copy()
        d[m.num_edges :] = row_cap          # re-point padding at global sink
        dstl[i] = d
        if t.val is not None:
            val[i] = t.val
        else:
            val[i, : m.num_edges] = 1.0     # unweighted => implicit weight 1
        row_start[i] = m.row_start
        num_rows[i] = m.num_rows
        num_edges[i] = m.num_edges
    return dict(
        src=src,
        dst_local=dstl,
        val=val,
        row_start=row_start,
        num_rows=num_rows,
        num_edges=num_edges,
        row_cap=row_cap,
        edge_cap=ecap,
    )
