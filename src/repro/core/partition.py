"""Two-stage graph partitioning (paper §III-A/B).

Stage 1 ("SPE"): split the input graph's edges into P tiles, 1-D by target
vertex, each holding ~S = |E|/P edges, target ranges contiguous.  The
splitter array is derived from the in-degree array exactly as the paper's
Algorithm 4: walk vertices in id order, open a new tile whenever the current
tile exceeds S edges.

Stage 2 ("MPE"): assign tile i to server ``i mod N`` (round-robin), and
within a server spread tiles over T workers.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PartitionPlan:
    """Output of stage 1: target-vertex splitter + static shape capacities."""

    num_vertices: int
    num_edges: int
    splitter: np.ndarray     # int64[P + 1]; tile t covers [splitter[t], splitter[t+1])
    edges_per_tile: np.ndarray  # int64[P]
    edge_cap: int            # padded edge capacity shared by all tiles
    row_cap: int             # padded row capacity shared by all tiles

    @property
    def num_tiles(self) -> int:
        """P = number of tiles (len(splitter) - 1)."""
        return len(self.splitter) - 1

    def tile_range(self, t: int) -> tuple[int, int]:
        """[row_start, row_end) target-vertex range of tile ``t``."""
        return int(self.splitter[t]), int(self.splitter[t + 1])

    def tile_of_vertex(self, v: int) -> int:
        """Owning tile of target vertex ``v`` (binary search on the splitter)."""
        return int(np.searchsorted(self.splitter, v, side="right") - 1)

    def to_dict(self) -> dict:
        """JSON-serializable form (stored in the tile store's meta.json)."""
        return dict(
            num_vertices=self.num_vertices,
            num_edges=self.num_edges,
            splitter=self.splitter.tolist(),
            edges_per_tile=self.edges_per_tile.tolist(),
            edge_cap=self.edge_cap,
            row_cap=self.row_cap,
        )

    @staticmethod
    def from_dict(d: dict) -> "PartitionPlan":
        """Inverse of ``to_dict``."""
        return PartitionPlan(
            num_vertices=d["num_vertices"],
            num_edges=d["num_edges"],
            splitter=np.asarray(d["splitter"], dtype=np.int64),
            edges_per_tile=np.asarray(d["edges_per_tile"], dtype=np.int64),
            edge_cap=d["edge_cap"],
            row_cap=d["row_cap"],
        )


def _round_up(x: int, mult: int) -> int:
    return ((max(x, 1) + mult - 1) // mult) * mult


def make_splitter(in_degree: np.ndarray, tile_size: int) -> np.ndarray:
    """Paper Algorithm 4 lines 3-8: accumulate in-degrees in vertex-id order,
    cut a new tile once the running sum exceeds S.  Vectorized.

    Returns splitter int64[P+1] with splitter[0] == 0, splitter[-1] == |V|.
    """
    n = int(in_degree.shape[0])
    if n == 0:
        return np.array([0, 0], dtype=np.int64)
    csum = np.cumsum(in_degree.astype(np.int64))
    total = int(csum[-1])
    cuts = [0]
    # A tile closes at the first vertex where its running edge count > S.
    # Equivalent vectorized form: repeatedly searchsorted on the cumsum.
    base = 0
    pos = 0
    while pos < n:
        target = base + tile_size
        nxt = int(np.searchsorted(csum, target, side="left")) + 1
        nxt = min(max(nxt, pos + 1), n)
        cuts.append(nxt)
        base = int(csum[nxt - 1])
        pos = nxt
    assert base == total
    return np.asarray(cuts, dtype=np.int64)


def plan_partition(
    in_degree: np.ndarray,
    tile_size: int,
    pad_edges_to: int = 128,
    pad_rows_to: int = 128,
) -> PartitionPlan:
    """Stage 1: derive the tile splitter and shared static capacities
    from in_degree ``[V]``."""
    splitter = make_splitter(in_degree, tile_size)
    csum = np.concatenate([[0], np.cumsum(in_degree.astype(np.int64))])
    edges_per_tile = csum[splitter[1:]] - csum[splitter[:-1]]
    rows_per_tile = np.diff(splitter)
    edge_cap = _round_up(int(edges_per_tile.max(initial=1)), pad_edges_to)
    row_cap = _round_up(int(rows_per_tile.max(initial=1)), pad_rows_to)
    return PartitionPlan(
        num_vertices=int(in_degree.shape[0]),
        num_edges=int(edges_per_tile.sum()),
        splitter=splitter,
        edges_per_tile=np.asarray(edges_per_tile, dtype=np.int64),
        edge_cap=edge_cap,
        row_cap=row_cap,
    )


@dataclasses.dataclass
class IntervalPlan:
    """Source-interval plan for out-of-core vertex state (DESIGN.md §10).

    V is split into K contiguous intervals whose boundaries are *aligned to
    tile row ranges* (every interval boundary is a tile splitter entry), so
    each tile's target rows fall inside exactly one interval and a tile's
    dst-side state is a single block.  The src side of a tile may touch any
    subset of intervals — that subset is its *source-interval footprint*
    (recorded in ``TileMeta.src_intervals`` / computed lazily by the
    engine)."""

    splitter: np.ndarray        # int64[K + 1]; interval k = [splitter[k], splitter[k+1])
    tile_to_interval: np.ndarray  # int64[P]; owning interval of each tile's rows

    @property
    def num_intervals(self) -> int:
        """K = number of source intervals."""
        return len(self.splitter) - 1

    def interval_range(self, k: int) -> tuple[int, int]:
        """[lo, hi) vertex range of interval ``k``."""
        return int(self.splitter[k]), int(self.splitter[k + 1])

    def interval_of(self, vertex_ids) -> np.ndarray:
        """Owning interval id ``[U]`` per vertex id ``[U]`` (vectorized)."""
        return np.searchsorted(self.splitter, vertex_ids, side="right") - 1

    def to_dict(self) -> dict:
        """JSON-serializable form (stored in the tile store's meta.json)."""
        return dict(
            splitter=self.splitter.tolist(),
            tile_to_interval=self.tile_to_interval.tolist(),
        )

    @staticmethod
    def from_dict(d: dict) -> "IntervalPlan":
        """Inverse of ``to_dict``."""
        return IntervalPlan(
            splitter=np.asarray(d["splitter"], dtype=np.int64),
            tile_to_interval=np.asarray(d["tile_to_interval"], dtype=np.int64),
        )


def plan_intervals(tile_splitter: np.ndarray, num_intervals: int) -> IntervalPlan:
    """Group consecutive tiles into ``num_intervals`` vertex intervals of
    roughly |V|/K vertices each, given tile_splitter ``[P+1]``.  Boundaries
    are chosen *from the tile
    splitter*, so intervals always align to tile row ranges; K is clamped to
    the tile count when there are fewer tiles than requested intervals."""
    tile_splitter = np.asarray(tile_splitter, dtype=np.int64)
    nv = int(tile_splitter[-1])
    num_tiles = len(tile_splitter) - 1
    k = max(1, min(int(num_intervals), num_tiles))
    target = nv / k
    cuts = [0]
    for t in range(1, num_tiles):
        b = int(tile_splitter[t])
        if b >= len(cuts) * target and len(cuts) < k:
            cuts.append(b)
    cuts.append(nv)
    splitter = np.asarray(cuts, dtype=np.int64)
    t2i = np.searchsorted(splitter, tile_splitter[:-1], side="right") - 1
    return IntervalPlan(splitter=splitter,
                        tile_to_interval=t2i.astype(np.int64))


def assign_tiles(num_tiles: int, num_servers: int) -> list[list[int]]:
    """Stage 2 (paper §III-C-1): tile i -> server ``i mod N``."""
    out: list[list[int]] = [[] for _ in range(num_servers)]
    for t in range(num_tiles):
        out[t % num_servers].append(t)
    return out


def assign_tiles_balanced(
    edges_per_tile: np.ndarray, num_servers: int
) -> list[list[int]]:
    """Beyond-paper variant: greedy longest-processing-time assignment over
    edges_per_tile ``[P]``, which
    balances *edges* (not tile counts) per server.  Used by the scheduler when
    tiles have uneven real edge counts (last tile is usually short)."""
    order = np.argsort(-edges_per_tile)
    loads = np.zeros(num_servers, dtype=np.int64)
    out: list[list[int]] = [[] for _ in range(num_servers)]
    for t in order:
        s = int(np.argmin(loads))
        out[s].append(int(t))
        loads[s] += int(edges_per_tile[t])
    for lst in out:
        lst.sort()
    return out


def server_vertex_ranges(
    splitter: np.ndarray, assignment: list[list[int]]
) -> list[list[tuple[int, int]]]:
    """Per-server owned dst-vertex ranges from splitter ``[P+1]``, merged
    where contiguous.

    Server s owns the union of its tiles' row ranges — the vertices whose
    new values that server (and only that server) produces each superstep.
    The cluster runtime (DESIGN.md §11) reports these so an operator can
    see how stage-2 ownership maps onto the vertex space; tile stealing
    moves entries between servers but never overlaps them."""
    out: list[list[tuple[int, int]]] = []
    for tids in assignment:
        ranges = sorted((int(splitter[t]), int(splitter[t + 1]))
                        for t in tids)
        merged: list[tuple[int, int]] = []
        for lo, hi in ranges:
            if merged and merged[-1][1] == lo:
                merged[-1] = (merged[-1][0], hi)
            else:
                merged.append((lo, hi))
        out.append(merged)
    return out


def balance_stats(edges_per_tile: np.ndarray, assignment: list[list[int]]) -> dict:
    """Edge/tile balance metrics over edges_per_tile ``[P]`` (paper Fig. 5
    reproduces these per tile)."""
    per_server = np.array(
        [sum(int(edges_per_tile[t]) for t in ts) for ts in assignment], dtype=np.int64
    )
    return dict(
        per_server_edges=per_server.tolist(),
        max_over_mean=float(per_server.max() / max(per_server.mean(), 1e-9)),
        cv=float(per_server.std() / max(per_server.mean(), 1e-9)),
    )
