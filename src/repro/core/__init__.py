"""GraphH core: partitioning, GAB model, caches, comm, engines.

Submodules are imported explicitly by users (no eager imports here, to
keep ``import repro.core`` cheap and cycle-free) — see the module map in
README.md and the stage-by-stage walkthrough in docs/ARCHITECTURE.md.
"""
# GraphH core: the paper's primary contribution in JAX.
# - tiles/partition: two-stage graph partitioning (paper §III-B)
# - gab/apps:        GAB computation model + vertex programs (§III-C)
# - cache:           edge cache with compression modes (§III-D-2)
# - comm:            hybrid dense/sparse broadcast (§III-D-3)
# - bloom:           tile-skipping filters (§III-C-4)
# - engine:          out-of-core MPE (measurable CPU path)
# - distributed:     shard_map multi-device path (cluster/dry-run path)
# - baselines:       Pregel/GAS/GraphD/Chaos-style comparison engines
# Submodules are imported explicitly by users (no eager imports here to
# keep `import repro.core` cheap and cycle-free).
