"""Baseline engines the paper compares against (§II, Table III, Fig. 1/10/11).

Faithful *mechanism-level* reimplementations at laptop scale — each engine
moves the same data the real system moves (per Table III), with real
compute and real file I/O for the out-of-core ones:

  PregelStyle  (Pregel+)   : hash edge-cut, in-memory out-edges, sender-side
                             message combining (eta), messages over "network"
  GASStyle     (PowerGraph): random vertex-cut, mirrors/master, partial
                             gathers + 2M|V| value exchanges
  GraphDStyle  (GraphD)    : Pregel semantics, edges streamed from disk every
                             superstep, messages spilled to disk at sender
  ChaosStyle   (Chaos)     : edge-centric streaming partitions; edges and
                             messages streamed via disk each superstep

All reuse the GAB VertexProgram hooks (message = gather(src_value, edge_val),
monoid combine, apply), so PageRank/SSSP run unmodified on every engine.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Optional

import numpy as np

from repro.core.gab import VertexProgram


@dataclasses.dataclass
class BaselineStats:
    """Per-superstep accounting of one baseline engine (bytes are modelled
    network/disk traffic, not measured wire bytes)."""
    superstep: int
    seconds: float
    network_bytes: int
    disk_read_bytes: int
    disk_write_bytes: int
    updated_vertices: int


@dataclasses.dataclass
class BaselineResult:
    """Final values + per-superstep history of one baseline run."""
    name: str
    values: np.ndarray
    history: list[BaselineStats]

    def mean_superstep_seconds(self, skip_first: bool = True) -> float:
        # single-superstep runs fall back to the full history instead of
        # averaging an empty slice (same guard as engine.RunResult)
        """Steady-state mean seconds per superstep (warm-up dropped unless
        that would leave nothing to average)."""
        hs = self.history[1:] if skip_first else self.history
        hs = hs or self.history
        return float(np.mean([h.seconds for h in hs])) if hs else 0.0


def _np_combine(combine: str):
    if combine == "sum":
        return lambda vals, idx, n: np.bincount(idx, weights=vals, minlength=n).astype(np.float64)
    if combine == "min":
        def seg_min(vals, idx, n):
            out = np.full(n, np.inf)
            np.minimum.at(out, idx, vals)
            return out
        return seg_min
    raise ValueError(combine)


def _gather_np(prog: VertexProgram, values, edge_src, edge_val, aux):
    src_vals = values[edge_src]
    src_aux = {k: np.asarray(aux[k])[edge_src] for k in prog.src_aux}
    return np.asarray(prog.gather(src_vals, edge_val, src_aux))


def _apply_np(prog: VertexProgram, values, accum, aux):
    # Apply everywhere: min-monoid apps are unchanged by the identity
    # accumulator, sum-monoid apps (PageRank) recompute every vertex —
    # identical semantics to the GAB engine.
    dst_aux = {k: np.asarray(aux[k]) for k in prog.dst_aux}
    return np.asarray(prog.apply(values, accum, dst_aux))


class _Base:
    name = "base"

    def __init__(self, src, dst, val, num_vertices, num_servers=4,
                 msg_bytes=12):
        self.src = np.asarray(src, dtype=np.int64)
        self.dst = np.asarray(dst, dtype=np.int64)
        self.val = (np.ones(len(src), np.float32) if val is None
                    else np.asarray(val, np.float32))
        self.nv = num_vertices
        self.ns = num_servers
        self.msg_bytes = msg_bytes
        self.out_deg = np.bincount(self.src, minlength=num_vertices).astype(np.float64)
        self.in_deg = np.bincount(self.dst, minlength=num_vertices).astype(np.float64)

    def run(self, prog: VertexProgram, max_supersteps=30) -> BaselineResult:
        state = prog.init(self.nv, self.out_deg, self.in_deg)
        values = np.asarray(state.pop("value"), dtype=np.float64)
        aux = state
        combine = _np_combine(prog.combine)
        history = []
        for ss in range(max_supersteps):
            t0 = time.perf_counter()
            new_values, net, dr, dw = self.superstep(prog, values, aux, combine)
            if prog.update_tol > 0:
                upd = np.abs(new_values - values) > prog.update_tol
            else:
                upd = new_values != values
            values = new_values
            history.append(BaselineStats(
                superstep=ss, seconds=time.perf_counter() - t0,
                network_bytes=net, disk_read_bytes=dr, disk_write_bytes=dw,
                updated_vertices=int(upd.sum()),
            ))
            if upd.sum() == 0:
                break
        return BaselineResult(self.name, values, history)

    def superstep(self, prog, values, aux, combine):
        raise NotImplementedError


class PregelStyle(_Base):
    """Pregel+ mechanism: hash edge-cut; per-sender message combining."""

    name = "pregel+"

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        owner = self.src % self.ns            # edge lives with its source
        self.by_server = [np.nonzero(owner == s)[0] for s in range(self.ns)]
        self.dst_owner = self.dst % self.ns

    def superstep(self, prog, values, aux, combine):
        """One superstep: per-server gather with sender-side combining; network
        bytes = combined messages crossing server boundaries."""
        net = 0
        accum = np.full(self.nv, prog.identity)
        cmb = combine
        for s in range(self.ns):
            es = self.by_server[s]
            contrib = _gather_np(prog, values, self.src[es], self.val[es], aux)
            # sender-side combining per (dst) within this server
            dsts, inv = np.unique(self.dst[es], return_inverse=True)
            combined = cmb(contrib, inv, len(dsts))
            # network: combined messages whose target lives elsewhere
            remote = (dsts % self.ns) != s
            net += int(remote.sum()) * self.msg_bytes
            if prog.combine == "sum":
                np.add.at(accum, dsts, combined)
            else:
                np.minimum.at(accum, dsts, combined)
        new_values = _apply_np(prog, values, accum, aux)
        return new_values, net, 0, 0


class GASStyle(_Base):
    """PowerGraph mechanism: random vertex-cut, mirror/master exchanges."""

    name = "powergraph"

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        rng = np.random.default_rng(0)
        self.edge_server = rng.integers(0, self.ns, len(self.src))
        self.by_server = [np.nonzero(self.edge_server == s)[0] for s in range(self.ns)]
        # replica sets: vertices present on a server (as src or dst)
        self.replicas = []
        total = 0
        for s in range(self.ns):
            es = self.by_server[s]
            vs = np.unique(np.concatenate([self.src[es], self.dst[es]]))
            self.replicas.append(vs)
            total += len(vs)
        self.M = total / max(self.nv, 1)

    def superstep(self, prog, values, aux, combine):
        """One superstep: per-server partial aggregation (GAS mirror-style);
        network bytes = per-(server, dst) partials shipped to masters."""
        net = 0
        accum = np.full(self.nv, prog.identity)
        for s in range(self.ns):
            es = self.by_server[s]
            contrib = _gather_np(prog, values, self.src[es], self.val[es], aux)
            dsts, inv = np.unique(self.dst[es], return_inverse=True)
            partial = combine(contrib, inv, len(dsts))
            # mirrors send partial accumulators to masters
            net += len(dsts) * self.msg_bytes
            if prog.combine == "sum":
                np.add.at(accum, dsts, partial)
            else:
                np.minimum.at(accum, dsts, partial)
        new_values = _apply_np(prog, values, accum, aux)
        # masters push new values back to every mirror
        net += int(sum(len(r) for r in self.replicas)) * self.msg_bytes
        return new_values, net, 0, 0


class GraphDStyle(PregelStyle):
    """GraphD mechanism: Pregel + edges re-streamed from disk every superstep and
    sender-side messages spilled to disk (Table III: read 2|E|, write |E|)."""

    name = "graphd"

    def __init__(self, *a, workdir: Optional[str] = None, **kw):
        super().__init__(*a, **kw)
        self.dir = workdir or tempfile.mkdtemp(prefix="graphd_")
        self.edge_files = []
        for s in range(self.ns):
            es = self.by_server[s]
            p = os.path.join(self.dir, f"edges{s}.bin")
            np.concatenate([
                self.src[es].astype("<i8"), self.dst[es].astype("<i8"),
            ]).tofile(p)
            with open(os.path.join(self.dir, f"vals{s}.bin"), "wb") as f:
                f.write(self.val[es].astype("<f4").tobytes())
            self.edge_files.append(p)

    def superstep(self, prog, values, aux, combine):
        """One superstep: edges streamed from disk each pass (no edge cache) —
        disk_read_bytes models the per-superstep re-read the paper criticizes."""
        net = dr = dw = 0
        accum = np.full(self.nv, prog.identity)
        for s in range(self.ns):
            # stream edges from disk (no cache — the paper's complaint)
            raw = np.fromfile(self.edge_files[s], dtype="<i8")
            n = len(raw) // 2
            e_src, e_dst = raw[:n], raw[n:]
            e_val = np.fromfile(os.path.join(self.dir, f"vals{s}.bin"), dtype="<f4")
            dr += raw.nbytes + e_val.nbytes
            contrib = _gather_np(prog, values, e_src, e_val, aux)
            # spill raw (uncombined) messages to disk at sender side
            spill = os.path.join(self.dir, f"msgs{s}.bin")
            buf = np.rec.fromarrays([e_dst, contrib.astype("<f8")],
                                    names="dst,val")
            with open(spill, "wb") as f:
                f.write(buf.tobytes())
            dw += buf.nbytes
            back = np.fromfile(spill, dtype=buf.dtype)
            dr += back.nbytes
            dsts, inv = np.unique(back["dst"], return_inverse=True)
            combined = combine(back["val"], inv, len(dsts))
            remote = (dsts % self.ns) != s
            net += int(remote.sum()) * self.msg_bytes
            if prog.combine == "sum":
                np.add.at(accum, dsts, combined)
            else:
                np.minimum.at(accum, dsts, combined)
        new_values = _apply_np(prog, values, accum, aux)
        return new_values, net, dr, dw


class ChaosStyle(_Base):
    """Chaos mechanism: streaming partitions spread over the cluster; every
    superstep streams edges and messages through (networked) storage
    (Table III: network O(3|E|+3|V|))."""

    name = "chaos"

    def __init__(self, *a, num_partitions: Optional[int] = None,
                 workdir: Optional[str] = None, **kw):
        super().__init__(*a, **kw)
        self.np_ = num_partitions or self.ns * 4
        self.dir = workdir or tempfile.mkdtemp(prefix="chaos_")
        part = self.src % self.np_           # streaming partition by source
        self.parts = [np.nonzero(part == p)[0] for p in range(self.np_)]
        for p, es in enumerate(self.parts):
            np.concatenate([self.src[es], self.dst[es]]).astype("<i8").tofile(
                os.path.join(self.dir, f"p{p}_edges.bin"))
            self.val[es].astype("<f4").tofile(
                os.path.join(self.dir, f"p{p}_vals.bin"))

    def superstep(self, prog, values, aux, combine):
        """One superstep: scatter messages spilled to disk partitions, then a
        gather pass re-reads them (Chaos-style 2-phase out-of-core)."""
        net = dr = dw = 0
        # scatter phase: stream edges, write messages into target partitions
        msg_bufs = [[] for _ in range(self.np_)]
        for p in range(self.np_):
            raw = np.fromfile(os.path.join(self.dir, f"p{p}_edges.bin"), dtype="<i8")
            n = len(raw) // 2
            e_src, e_dst = raw[:n], raw[n:]
            e_val = np.fromfile(os.path.join(self.dir, f"p{p}_vals.bin"), dtype="<f4")
            dr += raw.nbytes + e_val.nbytes
            net += raw.nbytes + e_val.nbytes      # partitions are remote
            contrib = _gather_np(prog, values, e_src, e_val, aux)
            tgt_part = e_dst % self.np_
            for q in range(self.np_):
                m = tgt_part == q
                if m.any():
                    msg_bufs[q].append((e_dst[m], contrib[m]))
        accum = np.full(self.nv, prog.identity)
        for q in range(self.np_):
            if not msg_bufs[q]:
                continue
            d = np.concatenate([x[0] for x in msg_bufs[q]])
            v = np.concatenate([x[1] for x in msg_bufs[q]])
            path = os.path.join(self.dir, f"p{q}_msgs.bin")
            rec = np.rec.fromarrays([d, v.astype("<f8")], names="dst,val")
            with open(path, "wb") as f:
                f.write(rec.tobytes())
            dw += rec.nbytes
            net += rec.nbytes
            back = np.fromfile(path, dtype=rec.dtype)
            dr += back.nbytes
            if prog.combine == "sum":
                np.add.at(accum, back["dst"], back["val"])
            else:
                np.minimum.at(accum, back["dst"], back["val"])
        new_values = _apply_np(prog, values, accum, aux)
        net += self.nv * self.msg_bytes * 3 // 2   # vertex state movement
        return new_values, net, dr, dw


ENGINES = {
    "pregel+": PregelStyle,
    "powergraph": GASStyle,
    "graphd": GraphDStyle,
    "chaos": ChaosStyle,
}
