"""Cluster transport: framed vertex-update broadcast between server
processes (paper §III-C/§IV; DESIGN.md §11).

The single-process engine *measures* broadcast payloads through
``comm.plan_broadcast``/``plan_broadcast_intervals``; this module makes the
same wire formats actually travel between N server processes:

  * **Frames** (``encode_frame``/``decode_frame``) — a self-describing
    envelope around the exact payload layouts the planners produce: dense
    (``ceil(V/8)`` bitvector + ``[V]`` values), sparse ((u32 vertex,
    value) pairs), multi-query per-column sections ((u32 vertex, u32
    query) pair pool), and per-dirty-interval sections (8-byte
    (interval, count) header + a local payload per interval).  Value bytes
    round-trip exactly, which is what keeps cluster results bit-identical
    to the single-process engine.
  * **Hybrid selection** — with ``mode="hybrid"`` the encoder builds the
    dense, sparse, *and* threshold-mixed candidate bodies from the
    measured update density, compresses each, and ships the smallest; the
    hybrid frame is therefore never larger than the best pure mode
    (``bench_cluster`` records this per superstep).
  * **Transports** — :class:`RingTransport`, a shared-memory SPSC byte
    ring per directed server pair (mmap over a file in the run directory:
    spawn-safe, no resource-tracker leaks), and :class:`SocketTransport`,
    a TCP fallback with file-based port rendezvous for servers that do not
    share memory.  Both expose ``send(dst, payload)`` / ``recv(timeout)``;
    delivery per channel is ordered and reliable.

Thread-safety: ``send`` may be called by one thread per destination;
``recv`` by one consumer thread.  The cluster exchange protocol that sits
on top lives in ``core.distributed.ClusterExchange``.
"""
from __future__ import annotations

import dataclasses
import json
import mmap
import os
import queue
import socket
import struct
import threading
import time
from typing import Optional

import numpy as np

from repro.core import comm
from repro.graphio import formats

#: frame magic — "GraphH Frame v1"
FRAME_MAGIC = b"GHF1"

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")


# ---------------------------------------------------------------------------
# Frame codec
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodedFrame:
    """A decoded update frame: the sparse-update triple the engine's
    barrier apply consumes, plus the frame header (mode choices, sizes)."""

    idx: np.ndarray            # [U] global updated vertex ids (int64)
    vals: np.ndarray           # [U] or [U, Q] update values (header dtype)
    mask: Optional[np.ndarray]  # [U, Q] per-query updated mask; None for 1-D
    header: dict               # frame header (mode, raw/wire bytes)


def _flat_body(vals_dense: np.ndarray, upd: np.ndarray, threshold: float,
               mode: str) -> tuple[bytes, str, Optional[tuple]]:
    """Uncompressed whole-range payload for one mode choice.  Returns
    (payload bytes, record mode label, per-column qmodes or None)."""
    if vals_dense.ndim == 2:
        payload, qmodes = comm.multi_query_payload(
            vals_dense, upd, threshold, mode)
        uniq = set(qmodes)
        label = "sparse" if not qmodes else (
            qmodes[0] if len(uniq) == 1 else "mixed")
        return payload, label, qmodes
    density = float(upd.mean()) if upd.size else 0.0
    use_dense = mode == "dense" or (mode == "hybrid" and density >= threshold)
    if use_dense:
        return comm.dense_payload(vals_dense, upd), "dense", None
    return comm.sparse_payload(vals_dense, upd), "sparse", None


def _range_body(vals_dense: np.ndarray, upd: np.ndarray, threshold: float,
                mode: str, comp_mode: int) -> tuple[bytes, int, str,
                                                    Optional[tuple]]:
    """Compressed body for one range under one fixed mode choice.  Returns
    (compressed body, raw payload bytes, mode label, qmodes)."""
    payload, label, qmodes = _flat_body(vals_dense, upd, threshold, mode)
    return (formats.compress_blob(payload, comp_mode), len(payload),
            label, qmodes)


def _densify_updates(idx: np.ndarray, vals: np.ndarray,
                     mask: Optional[np.ndarray], lo: int, hi: int,
                     dtype) -> tuple[np.ndarray, np.ndarray]:
    """Scatter a sparse update triple into dense local-range arrays
    ([hi-lo(, Q)] values + updated mask) for the payload builders."""
    n = hi - lo
    local = idx - lo
    if mask is not None:
        qa = vals.shape[1]
        dense = np.zeros((n, qa), dtype)
        upd = np.zeros((n, qa), dtype=bool)
        dense[local] = vals
        upd[local] = mask
    else:
        dense = np.zeros(n, dtype)
        upd = np.zeros(n, dtype=bool)
        dense[local] = vals
        upd[local] = True
    return dense, upd


def encode_frame(
    idx: np.ndarray,
    vals: np.ndarray,
    mask: Optional[np.ndarray],
    nv: int,
    *,
    splitter: Optional[np.ndarray] = None,
    threshold: float = comm.DENSITY_THRESHOLD,
    compressor: str = "zstd-1",
    mode: str = "hybrid",
    control: Optional[dict] = None,
) -> tuple[bytes, dict]:
    """Encode one server's per-superstep update set into a wire frame.

    ``idx`` [U] global updated vertex ids; ``vals`` [U] or [U, Q] values;
    ``mask`` [U, Q] per-query updated mask (None for 1-D).  With
    ``splitter`` (int64[K+1] interval boundaries, DESIGN.md §10) the body
    is per-dirty-interval sections exactly like
    ``comm.plan_broadcast_intervals``; otherwise one whole-V payload like
    ``comm.plan_broadcast``.  A frame is a pure function of the update
    set plus the barrier's ``control`` record (no timings or other
    run-varying measurements — the exchange carries those in its
    fixed-width envelope), so its size is reproducible across runs.
    ``control``, when given, is a JSON-safe dict shipped verbatim in the
    header — the session's admission/drain records (DESIGN.md §13) ride
    here so every rank splices the same columns at the same barrier.

    Returns (frame bytes, header dict).  ``header["wire_bytes"]`` is the
    full frame size (what actually travels); ``header["raw_bytes"]`` the
    uncompressed payload size, matching the planners' accounting.

    ``mode="hybrid"`` is the measured-size refinement of the paper's
    density-threshold switch (DESIGN.md §11): the complete frame is built
    under forced-dense, forced-sparse, and the per-column/per-interval
    threshold mix, and the smallest frame ships — so a hybrid frame is
    never larger than the best pure mode, per server per superstep
    (``bench_cluster`` asserts this).
    """
    if mode == "hybrid":
        best = None
        for m in ("dense", "sparse", "threshold"):
            cand = encode_frame(idx, vals, mask, nv, splitter=splitter,
                                threshold=threshold, compressor=compressor,
                                mode=m, control=control)
            if best is None or len(cand[0]) < len(best[0]):
                best = cand
        return best
    if mode == "threshold":
        mode = "hybrid"   # payload builders' name for the threshold mix
    comp_mode, codec = comm.resolve_compressor(compressor)
    dtype = np.dtype(vals.dtype)
    qa = vals.shape[1] if vals.ndim == 2 else None
    idx = np.asarray(idx, dtype=np.int64)
    cells = nv * (qa or 1)
    updated_cells = int(mask.sum()) if mask is not None else len(idx)

    sections: list[dict] = []
    bodies: list[bytes] = []
    raw = 0
    if splitter is None:
        dense, upd = _densify_updates(idx, vals, mask, 0, nv, dtype)
        body, raw, label, qmodes = _range_body(
            dense, upd, threshold, mode, comp_mode)
        bodies.append(body)
        kind = "flat"
    else:
        kind = "intervals"
        label, qmodes = "interval", None
        splitter = np.asarray(splitter, dtype=np.int64)
        if len(idx):
            ivs = np.searchsorted(splitter, idx, side="right") - 1
            for iv in np.unique(ivs):
                lo, hi = int(splitter[iv]), int(splitter[iv + 1])
                sel = ivs == iv
                dense, upd = _densify_updates(
                    idx[sel], vals[sel],
                    mask[sel] if mask is not None else None, lo, hi, dtype)
                body, sraw, slabel, sqmodes = _range_body(
                    dense, upd, threshold, mode, comp_mode)
                bodies.append(body)
                raw += sraw + comm.INTERVAL_HEADER_BYTES
                sections.append(dict(
                    iv=int(iv), lo=lo, hi=hi, count=int(sel.sum()),
                    mode=slabel, qmodes=list(sqmodes) if sqmodes else None,
                    len=len(body)))

    header = dict(
        v=1, kind=kind, nv=int(nv), qa=qa, dtype=dtype.str,
        comp=comp_mode, codec=codec, mode=label,
        qmodes=list(qmodes) if qmodes else None,
        sections=sections or None,
        density=updated_cells / max(cells, 1),
        raw_bytes=int(raw),
    )
    if control:
        header["control"] = control
    body_all = b"".join(bodies)
    hb = json.dumps(header).encode()
    frame = b"".join([FRAME_MAGIC, _U32.pack(len(hb)), hb, body_all])
    header["wire_bytes"] = len(frame)
    return frame, header


def decode_frame(frame: bytes) -> DecodedFrame:
    """Invert :func:`encode_frame`.  Value bytes round-trip exactly (no
    float re-encoding); see tests/test_transport.py for the property
    sweep over every mode, including the zlib-fallback codec."""
    if frame[:4] != FRAME_MAGIC:
        raise ValueError("bad frame magic")
    (hlen,) = _U32.unpack_from(frame, 4)
    header = json.loads(frame[8: 8 + hlen].decode())
    body = frame[8 + hlen:]
    header["wire_bytes"] = len(frame)
    dtype = np.dtype(header["dtype"])
    nv, qa = header["nv"], header["qa"]
    comp = header["comp"]

    def _decode_range(buf: bytes, n: int, mode: str, qmodes):
        if qa is not None:
            return comm.decode_multi_query_payload(buf, n, tuple(qmodes), dtype)
        if mode == "dense":
            i, v = comm.decode_dense_payload(buf, n, dtype)
        else:
            i, v = comm.decode_sparse_payload(buf, dtype)
        return i, v, None

    if header["kind"] == "flat":
        payload = formats.decompress_blob(body, comp)
        i, v, m = _decode_range(payload, nv, header["mode"],
                                header["qmodes"])
        return DecodedFrame(idx=i, vals=v, mask=m, header=header)

    parts_i: list[np.ndarray] = []
    parts_v: list[np.ndarray] = []
    parts_m: list[np.ndarray] = []
    off = 0
    for sec in header["sections"] or []:
        payload = formats.decompress_blob(body[off: off + sec["len"]], comp)
        off += sec["len"]
        i, v, m = _decode_range(payload, sec["hi"] - sec["lo"],
                                sec["mode"], sec["qmodes"])
        parts_i.append(i + sec["lo"])
        parts_v.append(v)
        if m is not None:
            parts_m.append(m)
    if parts_i:
        idx = np.concatenate(parts_i)
        vals = np.concatenate(parts_v)
        mask = np.concatenate(parts_m) if parts_m else None
    else:
        idx = np.zeros(0, np.int64)
        vals = (np.zeros((0, qa), dtype) if qa is not None
                else np.zeros(0, dtype))
        mask = np.zeros((0, qa), dtype=bool) if qa is not None else None
    return DecodedFrame(idx=idx, vals=vals, mask=mask, header=header)


# ---------------------------------------------------------------------------
# Shared-memory ring (mmap-backed SPSC byte ring per directed channel)
# ---------------------------------------------------------------------------

class RingChannel:
    """Single-producer single-consumer byte ring over an mmap'd file.

    Layout: ``head`` u64 (consumer cursor) | ``tail`` u64 (producer
    cursor) | ``capacity`` data bytes.  Cursors increase monotonically
    (byte positions, not wrapped), so free space is
    ``capacity - (tail - head)`` and the ring never confuses full with
    empty.  Messages are framed with a u32 length and may wrap; writes
    larger than the free space proceed in chunks as the consumer drains,
    so the capacity bounds memory, not message size.

    File-backed mmap rather than ``multiprocessing.shared_memory``: same
    page-cache-shared memory on the runtime's single-host deployments, but
    spawn-safe by name with no resource-tracker teardown warnings.  One
    writer process/thread and one reader process/thread per channel.
    """

    HEADER = 16

    def __init__(self, path: str, writer: bool, poll_s: float = 0.0005):
        self.path = path
        self.writer = writer
        self.poll_s = poll_s
        self._f = open(path, "r+b")
        size = os.path.getsize(path)
        self.capacity = size - self.HEADER
        self._mm = mmap.mmap(self._f.fileno(), size)

    @staticmethod
    def create(path: str, capacity: int) -> None:
        """Pre-create a zeroed channel file (parent does this for every
        directed server pair before spawning)."""
        with open(path, "wb") as f:
            f.write(b"\0" * (RingChannel.HEADER + capacity))

    # -- cursor accessors (u64 little-endian; aligned loads/stores) -------
    def _head(self) -> int:
        return _U64.unpack_from(self._mm, 0)[0]

    def _tail(self) -> int:
        return _U64.unpack_from(self._mm, 8)[0]

    def _set_head(self, v: int) -> None:
        _U64.pack_into(self._mm, 0, v)

    def _set_tail(self, v: int) -> None:
        _U64.pack_into(self._mm, 8, v)

    # -- byte-stream primitives ------------------------------------------
    def _write_stream(self, data: bytes, deadline: Optional[float]) -> None:
        mm, cap = self._mm, self.capacity
        off = 0
        tail = self._tail()
        while off < len(data):
            free = cap - (tail - self._head())
            if free == 0:
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(f"ring write stalled: {self.path}")
                time.sleep(self.poll_s)
                continue
            n = min(free, len(data) - off)
            pos = tail % cap
            first = min(n, cap - pos)
            mm[self.HEADER + pos: self.HEADER + pos + first] = \
                data[off: off + first]
            if n > first:
                mm[self.HEADER: self.HEADER + n - first] = \
                    data[off + first: off + n]
            tail += n
            self._set_tail(tail)   # publish after the bytes land
            off += n

    def _read_stream(self, n: int, deadline: Optional[float]) -> Optional[bytes]:
        mm, cap = self._mm, self.capacity
        out = bytearray()
        head = self._head()
        while len(out) < n:
            avail = self._tail() - head
            if avail == 0:
                if deadline is not None and time.monotonic() > deadline:
                    return None if not out else self._fail_partial()
                time.sleep(self.poll_s)
                continue
            take = min(avail, n - len(out))
            pos = head % cap
            first = min(take, cap - pos)
            out += mm[self.HEADER + pos: self.HEADER + pos + first]
            if take > first:
                out += mm[self.HEADER: self.HEADER + take - first]
            head += take
            self._set_head(head)
        return bytes(out)

    def _fail_partial(self):
        raise TimeoutError(f"ring read stalled mid-message: {self.path}")

    # -- message framing --------------------------------------------------
    def send_msg(self, payload: bytes, timeout: Optional[float] = None) -> None:
        """Blocking framed send (u32 length + bytes)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        self._write_stream(_U32.pack(len(payload)) + payload, deadline)

    def recv_msg(self, timeout: Optional[float] = 0.0) -> Optional[bytes]:
        """Receive one framed message; returns None if no *complete header*
        arrives within ``timeout`` (a started message is always drained)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        hdr = self._read_stream(4, deadline)
        if hdr is None:
            return None
        (n,) = _U32.unpack(hdr)
        return self._read_stream(n, None)

    def poll(self) -> bool:
        """True if at least a message header is waiting."""
        return self._tail() - self._head() >= 4

    def close(self) -> None:
        """Unmap the ring (the file itself is owned by the run directory)."""
        self._mm.close()
        self._f.close()


class RingTransport:
    """Shared-memory transport: one :class:`RingChannel` per directed
    server pair, files named ``ring_<src>_<dst>.buf`` under the cluster
    run directory (created by the parent via :func:`create_ring_files`).
    ``recv`` round-robin-polls the inbound channels."""

    kind = "shm"

    def __init__(self, rank: int, n: int, run_dir: str):
        self.rank, self.n = rank, n
        self._out = {d: RingChannel(ring_path(run_dir, rank, d), writer=True)
                     for d in range(n) if d != rank}
        self._in = {s: RingChannel(ring_path(run_dir, s, rank), writer=False)
                    for s in range(n) if s != rank}

    def send(self, dst: int, payload: bytes,
             timeout: Optional[float] = None) -> None:
        """Ordered, reliable framed send to server ``dst``."""
        self._out[dst].send_msg(payload, timeout=timeout)

    def recv(self, timeout: float = 0.1) -> Optional[tuple[int, bytes]]:
        """Next (source rank, payload) from any inbound channel, or None
        after ``timeout`` seconds of silence."""
        deadline = time.monotonic() + timeout
        while True:
            # lint: allow(GH205): _in built in ascending rank order at construction
            for s, ch in self._in.items():
                if ch.poll():
                    msg = ch.recv_msg(timeout=None)
                    return s, msg
            if time.monotonic() > deadline:
                return None
            time.sleep(0.001)

    def close(self) -> None:
        """Unmap every channel."""
        # lint: allow(GH205): resource teardown — close order is irrelevant
        for ch in (*self._out.values(), *self._in.values()):
            ch.close()


def ring_path(run_dir: str, src: int, dst: int) -> str:
    """Channel file for the ``src -> dst`` ring under ``run_dir``."""
    return os.path.join(run_dir, f"ring_{src}_{dst}.buf")


def create_ring_files(run_dir: str, n: int, capacity: int = 1 << 22) -> None:
    """Pre-create all N*(N-1) directed ring files (parent-side setup)."""
    for s in range(n):
        for d in range(n):
            if s != d:
                RingChannel.create(ring_path(run_dir, s, d), capacity)


# ---------------------------------------------------------------------------
# Socket transport (TCP fallback, file-based port rendezvous)
# ---------------------------------------------------------------------------

class SocketTransport:
    """TCP transport for servers that do not share memory.

    Each server binds an ephemeral listener and publishes its port as
    ``port_<rank>`` in the run directory (atomic rename — the rendezvous
    needs only a shared filesystem, no coordinator).  Outbound connections
    are opened lazily per peer and announce the sender rank with a u32
    hello; an accept thread spawns one reader thread per inbound
    connection, all feeding a single ``recv`` queue.  Framing and ordering
    guarantees match :class:`RingTransport`."""

    kind = "tcp"

    #: lock discipline, enforced by tools/analyze.py --check locks
    #: (the lazily-connected outbound socket map; one lock per peer)
    _guarded_by = {"_out": "_out_locks"}

    def __init__(self, rank: int, n: int, run_dir: str,
                 host: str = "127.0.0.1", connect_timeout: float = 60.0):
        self.rank, self.n, self.run_dir = rank, n, run_dir
        self.host = host
        self.connect_timeout = connect_timeout
        self._q: "queue.Queue[tuple[int, bytes]]" = queue.Queue()
        self._out: dict[int, socket.socket] = {}
        self._out_locks = {d: threading.Lock() for d in range(n)}
        self._stop = threading.Event()
        self._listener = socket.create_server((host, 0))
        self._listener.settimeout(0.2)
        port = self._listener.getsockname()[1]
        tmp = os.path.join(run_dir, f"port_{rank}.tmp")
        with open(tmp, "w") as f:
            f.write(str(port))
        os.replace(tmp, os.path.join(run_dir, f"port_{rank}"))
        self._threads = [threading.Thread(target=self._accept_loop,
                                          name=f"graphh-accept-{rank}",
                                          daemon=True)]
        self._threads[0].start()

    # -- inbound ----------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            t = threading.Thread(target=self._reader, args=(conn,),
                                 name=f"graphh-sockrd-{self.rank}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _recv_exact(self, conn: socket.socket, n: int) -> Optional[bytes]:
        buf = bytearray()
        while len(buf) < n:
            try:
                chunk = conn.recv(n - len(buf))
            except socket.timeout:
                if self._stop.is_set():
                    return None
                continue
            except OSError:
                return None
            if not chunk:
                return None
            buf += chunk
        return bytes(buf)

    def _reader(self, conn: socket.socket) -> None:
        conn.settimeout(0.5)
        hello = self._recv_exact(conn, 4)
        if hello is None:
            return
        (src,) = _U32.unpack(hello)
        while not self._stop.is_set():
            hdr = self._recv_exact(conn, 4)
            if hdr is None:
                return
            (ln,) = _U32.unpack(hdr)
            payload = self._recv_exact(conn, ln)
            if payload is None:
                return
            self._q.put((src, payload))

    # -- outbound ---------------------------------------------------------
    def _connect(self, dst: int) -> socket.socket:
        deadline = time.monotonic() + self.connect_timeout
        path = os.path.join(self.run_dir, f"port_{dst}")
        while True:
            try:
                with open(path) as f:
                    port = int(f.read())
                s = socket.create_connection((self.host, port), timeout=5.0)
                # the 5s timeout is for *connecting* only: a data socket
                # must block on sendall (a timeout mid-frame would corrupt
                # the stream framing after a partial write — the exchange
                # protocol owns per-superstep deadlines, not the socket)
                s.settimeout(None)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                s.sendall(_U32.pack(self.rank))
                return s
            except (OSError, ValueError):
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"server {self.rank} could not reach peer {dst}")
                time.sleep(0.05)

    def send(self, dst: int, payload: bytes,
             timeout: Optional[float] = None) -> None:
        """Ordered, reliable framed send to server ``dst`` (lazy connect)."""
        with self._out_locks[dst]:
            if dst not in self._out:
                self._out[dst] = self._connect(dst)
            self._out[dst].sendall(_U32.pack(len(payload)) + payload)

    def recv(self, timeout: float = 0.1) -> Optional[tuple[int, bytes]]:
        """Next (source rank, payload) from any peer, or None on timeout."""
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def close(self) -> None:
        """Stop the accept/reader threads and close every socket."""
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        for dst in range(self.n):
            with self._out_locks[dst]:
                s = self._out.pop(dst, None)
            if s is None:
                continue
            try:
                s.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=2.0)


class FaultInjectingTransport:
    """Decorator transport that consults a ``runtime.faults.FaultInjector``
    on every send (site ``"transport.send"``) — drop a frame, delay, or
    kill the rank mid-exchange, deterministically (DESIGN.md §12).

    The step passed to the injector is the exchange sequence number read
    from the first 4 bytes of the payload (``distributed._ENVELOPE``
    leads with a ``<I`` seq) — i.e. specs match on the *superstep* whose
    barrier is being crossed.  Wraps any transport exposing
    send/recv/close + rank/n."""

    def __init__(self, inner, injector):
        self.inner = inner
        self.fault = injector
        self.rank = inner.rank
        self.n = inner.n

    def send(self, dst: int, payload: bytes,
             timeout: Optional[float] = None) -> None:
        """Send unless a fault spec fires first (drop => swallowed)."""
        seq = _U32.unpack_from(payload)[0] if len(payload) >= 4 else -1
        if self.fault.drop("transport.send", seq):
            return                      # the frame is lost on the "wire"
        self.fault.check("transport.send", seq)
        self.inner.send(dst, payload, timeout)

    def recv(self, timeout: float = 0.1) -> Optional[tuple[int, bytes]]:
        """Pass-through receive."""
        return self.inner.recv(timeout)

    def close(self) -> None:
        """Pass-through close."""
        self.inner.close()


TRANSPORTS = {"shm": RingTransport, "tcp": SocketTransport}


def make_transport(kind: str, rank: int, n: int, run_dir: str, **kw):
    """Construct a transport by name ("shm" ring | "tcp" sockets)."""
    cls = TRANSPORTS.get(kind)
    if cls is None:
        raise ValueError(
            f"unknown transport {kind!r}; valid: {', '.join(sorted(TRANSPORTS))}")
    return cls(rank, n, run_dir, **kw)
