"""Adaptive multi-tier edge cache (paper §III-D-2).

A cache over serialized tiles sitting in "idle" host memory.  Four codec
modes trade decompression CPU for capacity, exactly as the paper's
snappy/zlib ladder (we use zstd levels, see formats.MODE_CODECS):

  mode 1: raw blobs         (gamma_1 = 1)
  mode 2: zstd-1            (gamma_2 ~ 2,  snappy analogue)
  mode 3: zstd-3            (gamma_3 ~ 4,  zlib-1 analogue)
  mode 4: zstd-9            (gamma_4 ~ 5,  zlib-3 analogue)

Two ways to use the ladder:

* ``policy="lru"`` — the paper's whole-cache single mode, chosen once at
  startup (``auto_select_mode`` implements §III-D-2's rule: smallest i
  such that working_set / gamma_i <= capacity, else mode 3).  Plain LRU
  eviction.
* ``policy="tiered"`` / ``policy="cost-aware"`` — per-tile compression
  (GraphMP-style selective caching): tiles are admitted warm (zstd-1),
  promoted toward raw on repeated hits, and *demoted* (recompressed
  smaller) instead of evicted when capacity is tight; eviction only ever
  takes tiles already in the coldest tier.  ``cost-aware`` picks pressure
  victims by least decompress-seconds-saved per resident byte instead of
  recency.  ``maintain()`` re-tiers in the background of the superstep
  (the engine calls it at the BSP barrier; ``start_background()`` runs it
  on a timer thread instead).

      tier   mode  codec    role
      hot     1    raw      repeated hits, zero decode cost
      warm    2    zstd-1   admission tier
      cold    4    zstd-9   demotion target; the only evictable tier
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Iterable, Optional

from repro.core.tiles import Tile
from repro.graphio import formats
from repro.graphio.formats import TileStore

# Paper §III-D-2: gamma_0..3 = 1, 2, 4, 5 (we index modes from 1).
DEFAULT_GAMMAS = {1: 1.0, 2: 2.0, 3: 4.0, 4: 5.0}

# hot -> warm -> cold compression modes for the tiered policies.
TIER_LADDER = (1, 2, 4)
TIER_NAMES = {1: "hot", 2: "warm", 4: "cold"}

POLICIES = ("lru", "tiered", "cost-aware")


def tier_name(mode: int) -> str:
    """Human-readable tier label for a compression mode (1/2/4 -> hot/warm/cold)."""
    return TIER_NAMES.get(mode, f"mode{mode}")


def auto_select_mode(
    working_set_bytes: int,
    capacity_bytes: int,
    gammas: dict[int, float] = DEFAULT_GAMMAS,
) -> int:
    """min i s.t. working_set / gamma_i <= capacity, else mode 3."""
    for mode in sorted(gammas):
        if working_set_bytes / gammas[mode] <= capacity_bytes:
            return mode
    return 3


class CacheStats:
    """Cumulative cache counters (seconds are wall-clock busy time; bytes
    are compressed blob sizes).  The engine reports per-superstep deltas."""
    def __init__(self) -> None:
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.promotions = 0
        self.demotions = 0
        self.tier_hits: dict[str, int] = {}
        self.disk_bytes_read = 0
        self.decompress_seconds = 0.0
        self.retier_seconds = 0.0     # promote/demote codec time (off hot path)
        self.disk_seconds = 0.0

    @property
    def hit_ratio(self) -> float:
        """hits / (hits + misses); 0.0 before any lookup."""
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def as_dict(self) -> dict:
        """Plain-dict snapshot (for logs/benchmark JSON)."""
        return dict(
            hits=self.hits, misses=self.misses, evictions=self.evictions,
            promotions=self.promotions, demotions=self.demotions,
            tier_hits=dict(self.tier_hits),
            hit_ratio=self.hit_ratio, disk_bytes_read=self.disk_bytes_read,
            decompress_seconds=self.decompress_seconds,
            retier_seconds=self.retier_seconds,
            disk_seconds=self.disk_seconds,
        )


@dataclasses.dataclass
class CacheEntry:
    """One resident tile: its compressed blob plus the heat bookkeeping
    that drives promotion/demotion decisions."""

    blob: bytes
    mode: int                 # current compression mode (TIER_LADDER member
    #                           for tiered policies, the fixed mode for lru)
    last_access: int = 0      # logical clock, not wall time
    hits: int = 0
    hits_since_retier: int = 0
    miss_cost_s: float = 0.0  # measured disk+decode seconds a miss would pay

    def value_density(self) -> float:
        """Decompress-seconds a miss would cost, amortized per resident byte
        and weighted by observed reuse — the cost-aware eviction score."""
        return self.miss_cost_s * (1 + self.hits) / max(len(self.blob), 1)


class EdgeCache:
    """Tile cache.  ``get`` returns a deserialized Tile; blobs are held
    compressed per entry (see module docstring for the tier ladder).
    A miss reads from the TileStore (disk tier).

    Thread-safe: the pipelined engine's prefetch workers
    (``TileStore.prefetch_iter``) perform lookups concurrently, so
    bookkeeping and stats are guarded by a lock — but disk reads and
    compress/decompress (the expensive part; both release the GIL) run
    *outside* it, so concurrent ``get`` calls genuinely overlap.  Two
    threads missing on the same tile may both read it from disk; the
    second insert replaces the first (byte-identical) blob.  Re-tier
    swaps verify blob identity before committing, so a concurrent
    replace simply wins over a stale promotion/demotion.
    """

    PROMOTE_WATERMARK = 0.70  # maintain(): promote only below this pressure
    DEMOTE_WATERMARK = 0.95   # maintain(): pre-demote LRU hot above this

    #: lock discipline, enforced by tools/analyze.py --check locks
    _guarded_by = {"_entries": "_lock", "_bytes": "_lock",
                   "_clock": "_lock", "stats": "_lock"}

    def __init__(self, store: TileStore, capacity_bytes: int, mode: int = 1,
                 policy: str = "lru", promote_hits: int = 2):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown cache policy {policy!r}; valid: {', '.join(POLICIES)}")
        self.store = store
        self.capacity_bytes = int(capacity_bytes)
        self.mode = mode
        self.policy = policy
        self.promote_hits = max(1, int(promote_hits))
        self._entries: OrderedDict[int, CacheEntry] = OrderedDict()
        self._bytes = 0
        self._clock = 0
        self._lock = threading.RLock()
        self._bg_stop: Optional[threading.Event] = None
        self._bg_thread: Optional[threading.Thread] = None
        self.stats = CacheStats()

    # -- public -------------------------------------------------------------
    @property
    def tiered(self) -> bool:
        """True for the per-tile hot/warm/cold policies ("tiered"/"cost-aware")."""
        return self.policy != "lru"

    def admission_mode(self) -> int:
        """Mode newly admitted tiles are compressed at: the warm tier for
        tiered policies, the fixed whole-cache mode for lru."""
        return TIER_LADDER[1] if self.tiered else self.mode

    def get(self, tile_id: int) -> Tile:
        """Return the deserialized Tile, reading + admitting from the TileStore
        on a miss.  Thread-safe; codec work runs outside the lock."""
        tile = self.get_if_resident(tile_id)
        if tile is not None:
            return tile
        blob, raw, miss_cost = self._read_and_pack(tile_id)
        self._admit(tile_id, blob, self.admission_mode(), miss_cost)
        return formats.deserialize_tile(raw)

    def get_if_resident(self, tile_id: int) -> Optional[Tile]:
        """Decode a resident tile, or return None without touching the disk
        (the prefetcher's consult-cache-before-reading entry point).  Counts
        a hit when resident and nothing otherwise — the subsequent ``get``
        counts the miss."""
        with self._lock:
            e = self._entries.get(tile_id)
            if e is None:
                return None
            self._entries.move_to_end(tile_id)
            self._clock += 1
            e.last_access = self._clock
            e.hits += 1
            e.hits_since_retier += 1
            self.stats.hits += 1
            name = tier_name(e.mode)
            self.stats.tier_hits[name] = self.stats.tier_hits.get(name, 0) + 1
            blob, mode = e.blob, e.mode
            # inline promotion only under low pressure; under pressure the
            # hit credit accumulates and maintain()/resize() promotes once
            # pressure drops (demote-don't-evict keeps the tile resident)
            want_promote = (
                self.tiered and mode != TIER_LADDER[0]
                and e.hits_since_retier >= self.promote_hits
                and self._bytes < self.PROMOTE_WATERMARK * self.capacity_bytes)
        t0 = time.perf_counter()
        raw = formats.decompress_blob(blob, mode)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.decompress_seconds += dt
        if want_promote:
            self._try_promote(tile_id, blob, mode, raw)
        return formats.deserialize_tile(raw)

    def resident_bytes(self) -> int:
        """Current resident compressed bytes (<= capacity_bytes)."""
        with self._lock:
            return self._bytes

    def contains(self, tile_id: int) -> bool:
        """Residency test without touching stats or LRU order."""
        with self._lock:
            return tile_id in self._entries

    def clear(self) -> None:
        """Drop every entry (stats are kept; counters are cumulative)."""
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    def warm(self, tile_ids: Iterable[int]) -> int:
        """Pre-load tiles until the next one would no longer fit — warming a
        working set larger than capacity must not thrash out what was just
        admitted.  Returns how many of the requested tiles are resident when
        warming stops (already-resident tiles count, and count as hits)."""
        admitted = 0
        for tid in tile_ids:
            with self._lock:
                e = self._entries.get(tid)
                if e is not None:
                    self._entries.move_to_end(tid)
                    self._clock += 1
                    e.last_access = self._clock
                    self.stats.hits += 1
                    name = tier_name(e.mode)
                    self.stats.tier_hits[name] = \
                        self.stats.tier_hits.get(name, 0) + 1
                    admitted += 1
                    continue
            blob, _raw, miss_cost = self._read_and_pack(tid)
            with self._lock:
                if self._bytes + len(blob) > self.capacity_bytes:
                    return admitted      # full: stop, never evict while warming
                self._insert_locked(tid, blob, self.admission_mode(), miss_cost)
            admitted += 1
        return admitted

    def tier_snapshot(self) -> dict:
        """Resident tiles/bytes per tier plus cumulative hits per tier."""
        with self._lock:
            out: dict[str, dict] = {}
            for e in self._entries.values():
                d = out.setdefault(tier_name(e.mode), dict(tiles=0, bytes=0))
                d["tiles"] += 1
                d["bytes"] += len(e.blob)
            for name, h in self.stats.tier_hits.items():
                out.setdefault(name, dict(tiles=0, bytes=0))["hits"] = h
            return out

    def resize(self, capacity_bytes: int) -> dict:
        """Adjust the idle-memory budget at runtime — the "memory pressure
        changed" entry point.  Shrinking walks the policy's pressure ladder
        (demote before evict) down to the new budget; growing lets the
        follow-up ``maintain`` promote tiles whose hit credit accumulated
        while capacity was tight."""
        with self._lock:
            self.capacity_bytes = int(capacity_bytes)
        self._make_room(0)
        return self.maintain()

    def maintain(self, max_ops: int = 8) -> dict:
        """Background re-tiering: run off the tile hot path (the engine calls
        this at the superstep barrier).  Under low memory pressure, promote
        the hottest entries with pending hit credit; under very high
        pressure, pre-demote LRU non-cold entries so the next admissions
        don't pay the demotion cascade inline.  Bounded by ``max_ops``
        recompressions per call."""
        if not self.tiered or self.capacity_bytes <= 0:
            return dict(promoted=0, demoted=0)
        promoted = demoted = 0
        hot, cold = TIER_LADDER[0], TIER_LADDER[-1]
        for _ in range(max_ops):
            with self._lock:
                pressure = self._bytes / self.capacity_bytes
                action = None
                if pressure < self.PROMOTE_WATERMARK:
                    for tid in reversed(self._entries):       # MRU first
                        e = self._entries[tid]
                        if (e.mode != hot
                                and e.hits_since_retier >= self.promote_hits):
                            action = ("promote", tid, e.blob, e.mode)
                            break
                elif pressure > self.DEMOTE_WATERMARK:
                    for tid, e in self._entries.items():      # LRU first
                        # zero-reuse entries are cheaper to just evict at
                        # admission time — don't spend codec on them here
                        if e.mode != cold and e.hits > 0:
                            action = ("demote", tid, e.blob, e.mode)
                            break
            if action is None:
                break
            kind, tid, blob, mode = action
            if kind == "promote":
                t0 = time.perf_counter()
                raw = formats.decompress_blob(blob, mode)
                dt = time.perf_counter() - t0   # _try_promote times its own
                with self._lock:                # compress pass
                    self.stats.retier_seconds += dt
                if not self._try_promote(tid, blob, mode, raw):
                    break                 # promotion no longer fits: stop
                promoted += 1
            else:
                # _demote may abort (concurrent swap) or evict instead
                # (blob didn't shrink) — count only committed demotions
                if self._demote(tid, blob, mode):
                    demoted += 1
        return dict(promoted=promoted, demoted=demoted)

    def start_background(self, interval_s: float = 1.0) -> None:
        """Run ``maintain`` on a daemon timer thread (for long-running hosts;
        the engine prefers the deterministic barrier call)."""
        if self._bg_thread is not None:
            return
        self._bg_stop = threading.Event()
        stop = self._bg_stop

        def loop() -> None:
            while not stop.wait(interval_s):
                self.maintain()

        self._bg_thread = threading.Thread(target=loop, daemon=True,
                                           name="graphh-cache-retier")
        self._bg_thread.start()

    def stop_background(self) -> None:
        """Stop the background re-tier thread started by ``start_background``."""
        if self._bg_thread is None:
            return
        self._bg_stop.set()
        self._bg_thread.join(timeout=5.0)
        self._bg_thread = None
        self._bg_stop = None

    @staticmethod
    def auto(store: TileStore, capacity_bytes: int, working_set_bytes: int,
             gammas: dict[int, float] = DEFAULT_GAMMAS,
             policy: str = "lru") -> "EdgeCache":
        """Construct with the paper's auto-selected whole-cache mode for the
        given working set (see ``auto_select_mode``)."""
        mode = auto_select_mode(working_set_bytes, capacity_bytes, gammas)
        return EdgeCache(store, capacity_bytes, mode, policy=policy)

    # -- internals ----------------------------------------------------------
    def _read_and_pack(self, tile_id: int) -> tuple[bytes, bytes, float]:
        """Disk read + recompress at the admission mode; returns
        (cache_blob, raw_bytes, measured miss cost).  Stats are updated here
        so every load counts as exactly one miss."""
        t0 = time.perf_counter()
        disk_blob = self.store.read_tile_blob(tile_id)
        disk_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        raw = formats.decompress_blob(disk_blob, self.store.disk_mode)
        cache_blob = formats.compress_blob(raw, self.admission_mode())
        codec_s = time.perf_counter() - t0
        with self._lock:
            self.stats.misses += 1
            self.stats.disk_seconds += disk_s
            self.stats.decompress_seconds += codec_s
            self.stats.disk_bytes_read += len(disk_blob)
        return cache_blob, raw, disk_s + codec_s

    def _insert_locked(self, tile_id: int, blob: bytes, mode: int,
                       miss_cost: float) -> None:
        old = self._entries.pop(tile_id, None)   # concurrent double-miss
        if old is not None:
            self._bytes -= len(old.blob)
        self._clock += 1
        self._entries[tile_id] = CacheEntry(
            blob=blob, mode=mode, last_access=self._clock,
            miss_cost_s=miss_cost)
        self._bytes += len(blob)

    def _admit(self, tile_id: int, blob: bytes, mode: int,
               miss_cost: float) -> bool:
        if len(blob) > self.capacity_bytes:
            return False  # single tile larger than the whole cache
        for _ in range(8):  # bounded retry under concurrent churn
            if not self._make_room(len(blob), exclude=tile_id):
                return False
            with self._lock:
                old = self._entries.pop(tile_id, None)
                if old is not None:
                    self._bytes -= len(old.blob)
                if self._bytes + len(blob) > self.capacity_bytes:
                    if old is not None:  # another thread filled the room
                        self._entries[tile_id] = old
                        self._bytes += len(old.blob)
                    continue
                if old is not None:      # keep the hotter entry's heat
                    self._entries[tile_id] = old
                    self._bytes += len(old.blob)
                    return True
                self._insert_locked(tile_id, blob, mode, miss_cost)
                return True
        return False

    def _make_room(self, incoming: int, exclude: Optional[int] = None) -> bool:
        """Free space for ``incoming`` bytes by the policy's pressure ladder:
        demote non-cold entries (recompress smaller) before evicting, evict
        only from the coldest tier.  Codec work runs outside the lock."""
        demotions = 0
        while True:
            with self._lock:
                if self._bytes + incoming <= self.capacity_bytes:
                    return True
                # cap demotion churn per admission: after that, evict-only
                evict_only = demotions > 2 * len(TIER_LADDER)
                act = self._victim(exclude, evict_only=evict_only)
                if act is None:
                    return False
                kind, tid = act
                if kind == "evict":
                    self._evict_locked(tid)
                    continue
                e = self._entries[tid]
                blob, mode = e.blob, e.mode
            demotions += 1
            self._demote(tid, blob, mode)

    def _victim(self, exclude: Optional[int],
                evict_only: bool = False) -> Optional[tuple[str, int]]:
        """Pick the pressure victim (caller holds the lock): ("demote", id)
        or ("evict", id), or None when nothing can be freed."""
        cand = [(tid, e) for tid, e in self._entries.items() if tid != exclude]
        if not cand:
            return None
        if self.policy == "lru":
            return ("evict", cand[0][0])
        cold = TIER_LADDER[-1]
        # Selective caching (GraphMP): only tiles with demonstrated reuse
        # earn the demote-instead-of-evict treatment.  A never-hit entry is
        # coldest in the reuse sense — evicting it directly keeps a
        # streaming scan from paying a recompress per admitted tile.
        if self.policy == "cost-aware":
            tid, e = min(cand,
                         key=lambda kv: (kv[1].value_density(),
                                         kv[1].last_access))
            if (evict_only or e.hits == 0 or e.mode == cold
                    or e.mode not in TIER_LADDER):
                return ("evict", tid)
            return ("demote", tid)
        # tiered: evict the LRU zero-reuse entry if any; otherwise demote
        # the LRU reused non-cold entry; evict cold only as the last rung.
        for tid, e in cand:
            if e.hits == 0:
                return ("evict", tid)
        if not evict_only:
            for tid, e in cand:
                if e.mode in TIER_LADDER[:-1]:
                    return ("demote", tid)
        for tid, e in cand:
            if e.mode == cold or e.mode not in TIER_LADDER:
                return ("evict", tid)
        return ("evict", cand[0][0])   # evict_only with no cold entries

    def _evict_locked(self, tile_id: int) -> None:
        e = self._entries.pop(tile_id, None)
        if e is not None:
            self._bytes -= len(e.blob)
            self.stats.evictions += 1

    def _demote(self, tile_id: int, old_blob: bytes, old_mode: int) -> bool:
        """Recompress one tier colder (outside the lock); commit only if the
        entry is unchanged and the blob actually shrank — tiles that don't
        compress are treated as already-coldest and evicted.  True only
        when a demotion committed (aborts/evictions return False), so
        callers never re-read ``stats`` to learn the outcome."""
        if old_mode not in TIER_LADDER or old_mode == TIER_LADDER[-1]:
            with self._lock:
                e = self._entries.get(tile_id)
                if e is not None and e.blob is old_blob:
                    self._evict_locked(tile_id)
            return False
        target = TIER_LADDER[TIER_LADDER.index(old_mode) + 1]
        t0 = time.perf_counter()
        new_blob = formats.compress_blob(
            formats.decompress_blob(old_blob, old_mode), target)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.retier_seconds += dt
            e = self._entries.get(tile_id)
            if e is None or e.blob is not old_blob:
                return False
            if len(new_blob) >= len(old_blob):
                self._evict_locked(tile_id)
                return False
            self._bytes += len(new_blob) - len(old_blob)
            e.blob, e.mode = new_blob, target
            e.hits_since_retier = 0
            self.stats.demotions += 1
            return True

    def _try_promote(self, tile_id: int, old_blob: bytes, old_mode: int,
                     raw: bytes) -> bool:
        """Recompress one tier hotter (outside the lock).  Promotion grows
        the blob, so it only commits if it fits without evicting anything —
        under tight capacity the cache stays demoted instead.  True only
        when the promotion committed."""
        if old_mode not in TIER_LADDER or old_mode == TIER_LADDER[0]:
            return False
        target = TIER_LADDER[TIER_LADDER.index(old_mode) - 1]
        t0 = time.perf_counter()
        new_blob = formats.compress_blob(raw, target)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.retier_seconds += dt
            e = self._entries.get(tile_id)
            if e is None or e.blob is not old_blob:
                return False
            delta = len(new_blob) - len(e.blob)
            if self._bytes + delta > self.capacity_bytes:
                e.hits_since_retier = 0   # capacity tight: stay put
                return False
            self._bytes += delta
            e.blob, e.mode = new_blob, target
            e.hits_since_retier = 0
            self.stats.promotions += 1
            return True
