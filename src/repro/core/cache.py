"""Edge cache (paper §III-D-2).

An LRU cache over serialized tiles sitting in "idle" host memory.  Four
modes trade decompression CPU for capacity, exactly as the paper's
snappy/zlib ladder (we use zstd levels, see formats.MODE_CODECS):

  mode 1: raw blobs         (gamma_1 = 1)
  mode 2: zstd-1            (gamma_2 ~ 2,  snappy analogue)
  mode 3: zstd-3            (gamma_3 ~ 4,  zlib-1 analogue)
  mode 4: zstd-9            (gamma_4 ~ 5,  zlib-3 analogue)

Auto-selection follows the paper: pick the *smallest* i such that
P_resident_bytes / gamma_i <= capacity; if none fits, use mode 3.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Optional

from repro.core.tiles import Tile
from repro.graphio import formats
from repro.graphio.formats import TileStore

# Paper §III-D-2: gamma_0..3 = 1, 2, 4, 5 (we index modes from 1).
DEFAULT_GAMMAS = {1: 1.0, 2: 2.0, 3: 4.0, 4: 5.0}


def auto_select_mode(
    working_set_bytes: int,
    capacity_bytes: int,
    gammas: dict[int, float] = DEFAULT_GAMMAS,
) -> int:
    """min i s.t. working_set / gamma_i <= capacity, else mode 3."""
    for mode in sorted(gammas):
        if working_set_bytes / gammas[mode] <= capacity_bytes:
            return mode
    return 3


class CacheStats:
    def __init__(self) -> None:
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.disk_bytes_read = 0
        self.decompress_seconds = 0.0
        self.disk_seconds = 0.0

    @property
    def hit_ratio(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def as_dict(self) -> dict:
        return dict(
            hits=self.hits, misses=self.misses, evictions=self.evictions,
            hit_ratio=self.hit_ratio, disk_bytes_read=self.disk_bytes_read,
            decompress_seconds=self.decompress_seconds,
            disk_seconds=self.disk_seconds,
        )


class EdgeCache:
    """LRU tile cache.  ``get`` returns a deserialized Tile; blobs are held
    compressed at ``mode``.  A miss reads from the TileStore (disk tier).

    Thread-safe: the pipelined engine's prefetch workers
    (``TileStore.prefetch_iter``) perform lookups concurrently, so LRU
    bookkeeping and stats are guarded by a lock — but disk reads and
    compress/decompress (the expensive part; both release the GIL) run
    *outside* it, so concurrent ``get`` calls genuinely overlap.  Two
    threads missing on the same tile may both read it from disk; the
    second insert replaces the first (byte-identical) blob.
    """

    def __init__(self, store: TileStore, capacity_bytes: int, mode: int = 1):
        self.store = store
        self.capacity_bytes = int(capacity_bytes)
        self.mode = mode
        self._lru: OrderedDict[int, bytes] = OrderedDict()
        self._bytes = 0
        self._lock = threading.RLock()
        self.stats = CacheStats()

    # -- public -------------------------------------------------------------
    def get(self, tile_id: int) -> Tile:
        with self._lock:
            blob = self._lru.get(tile_id)
            if blob is not None:
                self._lru.move_to_end(tile_id)
                self.stats.hits += 1
        if blob is not None:
            return self._decode(blob)

        t0 = time.perf_counter()
        disk_blob = self.store.read_tile_blob(tile_id)
        disk_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        raw = formats.decompress_blob(disk_blob, self.store.disk_mode)
        cache_blob = formats.compress_blob(raw, self.mode)
        codec_s = time.perf_counter() - t0
        with self._lock:
            self.stats.misses += 1
            self.stats.disk_seconds += disk_s
            self.stats.decompress_seconds += codec_s
            self.stats.disk_bytes_read += len(disk_blob)
            self._insert(tile_id, cache_blob)
        return formats.deserialize_tile(raw)

    def resident_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def contains(self, tile_id: int) -> bool:
        with self._lock:
            return tile_id in self._lru

    def clear(self) -> None:
        with self._lock:
            self._lru.clear()
            self._bytes = 0

    def warm(self, tile_ids) -> None:
        for t in tile_ids:
            self.get(t)

    @staticmethod
    def auto(store: TileStore, capacity_bytes: int, working_set_bytes: int,
             gammas: dict[int, float] = DEFAULT_GAMMAS) -> "EdgeCache":
        mode = auto_select_mode(working_set_bytes, capacity_bytes, gammas)
        return EdgeCache(store, capacity_bytes, mode)

    # -- internals ----------------------------------------------------------
    def _decode(self, blob: bytes) -> Tile:
        t0 = time.perf_counter()
        raw = formats.decompress_blob(blob, self.mode)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.decompress_seconds += dt
        return formats.deserialize_tile(raw)

    def _insert(self, tile_id: int, blob: bytes) -> None:
        # caller holds self._lock
        if len(blob) > self.capacity_bytes:
            return  # single tile larger than the whole cache: don't thrash
        old = self._lru.pop(tile_id, None)  # concurrent double-miss
        if old is not None:
            self._bytes -= len(old)
        while self._bytes + len(blob) > self.capacity_bytes and self._lru:
            _, evicted = self._lru.popitem(last=False)
            self._bytes -= len(evicted)
            self.stats.evictions += 1
        self._lru[tile_id] = blob
        self._bytes += len(blob)
