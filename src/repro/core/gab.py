"""GAB (Gather-Apply-Broadcast) computation model (paper §III-C).

A vertex-centric program supplies:
  * ``init``     — initial vertex value array + auxiliary per-vertex arrays
  * ``gather``   — per-edge contribution f(src_value, edge_value, aux_src)
  * ``combine``  — the reduction monoid over contributions ("sum"/"min"/"max")
  * ``apply``    — new_value g(old_value, accumulator, aux_dst)

The engine runs supersteps: every server holds a replica of *all* vertex
values (All-in-All policy), processes its assigned tiles one at a time
(Gather+Apply are purely local), and Broadcasts only *updated* values.

This module contains the jit-friendly single-tile and stacked-tile step
functions; orchestration lives in engine.py (out-of-core) and
distributed.py (shard_map).

Multi-query axis (DESIGN.md §9): vertex values may be ``[V]`` (classic,
one program instance) or ``[V, Q]`` (Q program instances evaluated in the
same tile visit — personalized PageRank seeds, multi-source BFS, landmark
distances).  Every step function here is shape-polymorphic over that
trailing query axis; per-vertex aux arrays may likewise be ``[V]``
(shared across queries) or ``[V, Q]`` (per-query, e.g. PPR seed mass).
One edge pass then serves Q queries: the dominant out-of-core I/O cost is
paid once and the Pallas one-hot contraction becomes a real GEMM.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_COMBINE_IDENTITY = {
    "sum": 0.0,
    "min": jnp.inf,
    "max": -jnp.inf,
}


def segment_reduce(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    combine: str,
    impl: str = "jnp",
    sorted_ids: bool = True,
    blocks: Optional[tuple[int, int]] = None,
) -> Array:
    """Reduce ``data`` into ``num_segments`` buckets with the given monoid.

    ``data`` may be ``[E]`` or ``[E, Q]`` (multi-query); segments always
    run along axis 0.

    impl="jnp" uses XLA scatter-reduce; impl="pallas_onehot" routes through
    the Pallas block kernels (see kernels/gab_gather.py): the sum monoid
    becomes an MXU one-hot contraction, min/max a masked VPU reduction —
    ``blocks`` overrides the static ``(BE, BR)`` kernel block sizes (the
    roofline autotuner's choice, see roofline/kernel_tune.py).
    Tile edges are CSR-sorted by dst (build_tile invariant), so
    ``sorted_ids=True`` by default — XLA's sorted-scatter path (§Perf It4).
    """
    if impl == "pallas_onehot":
        from repro.kernels import ops as _kops

        fn = {"sum": _kops.segment_sum, "min": _kops.segment_min,
              "max": _kops.segment_max}.get(combine)
        if fn is None:
            raise ValueError(f"unknown combine: {combine}")
        if blocks is not None:
            return fn(data, segment_ids, num_segments,
                      block_e=blocks[0], block_r=blocks[1])
        return fn(data, segment_ids, num_segments)
    kw = dict(num_segments=num_segments, indices_are_sorted=sorted_ids)
    if combine == "sum":
        return jax.ops.segment_sum(data, segment_ids, **kw)
    if combine == "min":
        return jax.ops.segment_min(data, segment_ids, **kw)
    if combine == "max":
        return jax.ops.segment_max(data, segment_ids, **kw)
    raise ValueError(f"unknown combine: {combine}")


@dataclasses.dataclass(eq=False)  # identity hash: instances are jit static args
class VertexProgram:
    """Base class for GAB vertex programs.  Subclasses override the four
    hooks below; all jnp code must be jit-compatible.

    Batched (multi-query) programs override ``num_queries`` (> 1) and
    return a ``[V, Q]`` ``value`` from :meth:`init`; their hooks then see
    ``[E, Q]`` / ``[R, Q]`` arrays and must broadcast 1-D shared aux
    explicitly (e.g. ``aux[k][:, None]``).  Per-query aux arrays are
    ``[V, Q]`` and are column-compacted alongside values when queries
    retire (engine.py)."""

    combine: str = "sum"
    #: names of auxiliary per-vertex arrays gathered at the *source* side
    src_aux: tuple[str, ...] = ()
    #: names of auxiliary per-vertex arrays consumed by apply at the dst side
    dst_aux: tuple[str, ...] = ()
    #: tolerance used to decide whether a value "changed" (paper: broadcast
    #: only updated values); exact (0.0) for discrete programs.
    update_tol: float = 0.0

    # number of query instances batched into one edge pass; values are
    # [V, num_queries] when > 1 (plain class attr, not a dataclass field —
    # batched subclasses override it with a property derived from seeds)
    num_queries = 1

    # -- hooks ------------------------------------------------------------
    def init(self, num_vertices: int, out_degree: np.ndarray,
             in_degree: np.ndarray, **kw) -> dict[str, np.ndarray]:
        """Return {"value": ..., <aux name>: ...} — value ``[V(, Q)]``,
        aux arrays ``[V]``, given out/in degrees ``[V]``."""
        raise NotImplementedError

    def gather(self, src_value: Array, edge_val: Array,
               aux: dict[str, Array]) -> Array:
        """Per-edge message: f(src values [E(, Q)], edge values [E], src aux)."""
        raise NotImplementedError

    def apply(self, old_value: Array, accum: Array,
              aux: dict[str, Array]) -> Array:
        """New dst values g(old [R(, Q)], accumulated messages, dst aux)."""
        raise NotImplementedError

    # -- derived ----------------------------------------------------------
    @property
    def identity(self) -> float:
        """Identity element of the combine monoid (0 / +inf / -inf)."""
        return _COMBINE_IDENTITY[self.combine]

    def updated_mask(self, old: Array, new: Array) -> Array:
        """Elementwise "value changed" mask over old/new ``[V(, Q)]`` —
        exact (!=) or |new - old| > update_tol for tolerance-based
        programs like PageRank."""
        if self.update_tol > 0.0:
            return jnp.abs(new - old) > self.update_tol
        return new != old

    def fused_spec(self):
        """:class:`repro.kernels.gab_fused.FusedSpec` describing this
        program's gather/apply in the affine form the fused Pallas kernel
        executes, or ``None`` when the program has no such form — the
        ``pallas_fused`` path then falls back to the unfused one-hot
        kernel for this program."""
        return None


# ---------------------------------------------------------------------------
# jit-friendly tile step
# ---------------------------------------------------------------------------

def _bcast_rows(mask: Array, ref: Array) -> Array:
    """Broadcast a per-row [R] mask against [R] or [R, Q] data."""
    return mask[:, None] if ref.ndim == 2 else mask


def _dslice(buf: Array, start, rows: int) -> Array:
    """dynamic_slice of ``rows`` leading rows starting at ``start``,
    covering the full trailing (query) axis if present."""
    return jax.lax.dynamic_slice(
        buf, (start,) + (0,) * (buf.ndim - 1), (rows,) + buf.shape[1:])


def _dupdate(buf: Array, window: Array, start) -> Array:
    return jax.lax.dynamic_update_slice(
        buf, window, (start,) + (0,) * (buf.ndim - 1))


def _row_pad(arr: Array, pad: int) -> Array:
    """Append ``pad`` zero rows (any trailing shape) to ``arr``."""
    z = jnp.zeros((pad,) + arr.shape[1:], arr.dtype)
    return jnp.concatenate([arr, z])


def _fused_tile(prog, fs, src_vals, src_aux, edge_val, dst_local, old,
                dst_aux, num_rows, row_cap, blocks):
    """Dispatch one tile through the fused gather→combine→apply kernel
    (kernels/gab_fused.py).  The per-edge affine terms are formed here —
    ``a = src_aux[scale_aux] * edge_val`` matches the programs' own gather
    expressions bit-for-bit (edge_val is exactly 1.0 on real unweighted
    edges) — and the kernel returns the applied+masked row block."""
    from repro.kernels import gab_fused as _gf
    from repro.kernels import ops as _kops

    a = src_aux[fs.scale_aux] * edge_val if fs.scale_aux else None
    b = edge_val if fs.add_edge else None
    base = dst_aux[fs.base_aux] if fs.base_aux else None
    be, br = blocks if blocks is not None else (
        _gf.DEFAULT_BLOCK_E, _gf.DEFAULT_BLOCK_R)
    return _gf.gab_fused(
        fs, src_vals, a, b, dst_local, old, base, num_rows, row_cap,
        block_e=be, block_r=br, interpret=_kops._interpret(),
    )


def _unfused_impl(seg_impl: str) -> str:
    """The segment-reduce impl backing programs without a FusedSpec (and
    the merged path) when the engine asks for ``pallas_fused``."""
    return "pallas_onehot" if seg_impl == "pallas_fused" else seg_impl


def tile_gather_apply(
    prog: VertexProgram,
    values: Array,                # [V] replicated vertex values
    aux: dict[str, Array],        # per-vertex aux arrays, each [V]
    src: Array,                   # [E] global source ids (padding -> sink row)
    dst_local: Array,             # [E] dst - row_start; padding == row_cap
    edge_val: Array,              # [E]
    row_start: Array,             # scalar int32
    num_rows: Array,              # scalar int32 (<= row_cap)
    row_cap: int,
    seg_impl: str = "jnp",
    blocks: Optional[tuple[int, int]] = None,
) -> tuple[Array, Array, Array]:
    """Gather+Apply for one tile.

    Returns (rows [row_cap] global ids clipped to V-1, new_values
    [row_cap(, Q)], updated [row_cap(, Q)] bool).  Rows beyond num_rows are
    masked not-updated.  ``values`` may be [V] or [V, Q] (multi-query).
    seg_impl="pallas_fused" runs gather/combine/apply/mask as one fused
    Pallas kernel (DESIGN.md §14); ``blocks`` carries the autotuned
    ``(BE, BR)`` to either Pallas path.
    """
    nv = values.shape[0]
    src_vals = jnp.take(values, src, axis=0)
    src_aux = {k: jnp.take(aux[k], src, axis=0) for k in prog.src_aux}
    local_rows = jnp.arange(row_cap, dtype=jnp.int32)
    rows = jnp.minimum(row_start + local_rows, nv - 1)

    fs = prog.fused_spec() if seg_impl == "pallas_fused" else None
    if fs is not None:
        old = jnp.take(values, rows, axis=0)
        dst_aux = {k: jnp.take(aux[k], rows, axis=0) for k in prog.dst_aux}
        new, updated = _fused_tile(prog, fs, src_vals, src_aux, edge_val,
                                   dst_local, old, dst_aux, num_rows,
                                   row_cap, blocks)
        return rows, new, updated

    contrib = prog.gather(src_vals, edge_val, src_aux)
    accum = segment_reduce(
        contrib, dst_local, row_cap + 1, prog.combine,
        impl=_unfused_impl(seg_impl), blocks=blocks,
    )[:row_cap]

    old = jnp.take(values, rows, axis=0)
    dst_aux = {k: jnp.take(aux[k], rows, axis=0) for k in prog.dst_aux}
    new = prog.apply(old, accum, dst_aux)
    valid = _bcast_rows(local_rows < num_rows, new)
    new = jnp.where(valid, new, old)
    updated = jnp.logical_and(valid, prog.updated_mask(old, new))
    return rows, new, updated


def tile_gather_apply_sharded(
    prog: VertexProgram,
    src_vals: Array,              # [E(, Q)] pre-gathered source values
    src_aux: dict[str, Array],    # pre-gathered per-edge aux, each [E(, ...)]
    edge_val: Array,              # [E]
    dst_local: Array,             # [E] dst - row_start; padding routes inert
    old: Array,                   # [row_cap(, Q)] this tile's current rows
    dst_aux: dict[str, Array],    # dst-side aux rows, each [row_cap(, ...)]
    num_rows: Array,              # scalar int32 (<= row_cap)
    row_cap: int,
    seg_impl: str = "jnp",
    blocks: Optional[tuple[int, int]] = None,
) -> tuple[Array, Array]:
    """Gather+Apply for one tile with *pre-gathered* source-side inputs —
    the out-of-core vertex-state path (DESIGN.md §10).

    The engine materializes ``src_vals``/``src_aux`` interval-by-interval
    from the :class:`~repro.core.vstate.VertexStateStore` (so no full [V]
    array ever exists) and slices ``old``/``dst_aux`` from the tile's own
    dst-interval block.  Edge *order* is untouched — only the fill of the
    pre-gathered buffers walks intervals — so contributions reduce in
    exactly the same order as :func:`tile_gather_apply` and valid rows are
    bit-identical to the in-memory path.  Padding slots hold zeros instead
    of ``values[0]``; they only ever reduce into the masked-out sink row.

    Returns (new_values [row_cap(, Q)], updated [row_cap(, Q)] bool).
    """
    fs = prog.fused_spec() if seg_impl == "pallas_fused" else None
    if fs is not None:
        return _fused_tile(prog, fs, src_vals, src_aux, edge_val, dst_local,
                           old, dst_aux, num_rows, row_cap, blocks)

    contrib = prog.gather(src_vals, edge_val, src_aux)
    accum = segment_reduce(
        contrib, dst_local, row_cap + 1, prog.combine,
        impl=_unfused_impl(seg_impl), blocks=blocks,
    )[:row_cap]
    new = prog.apply(old, accum, dst_aux)
    local_rows = jnp.arange(row_cap, dtype=jnp.int32)
    valid = _bcast_rows(local_rows < num_rows, new)
    new = jnp.where(valid, new, old)
    updated = jnp.logical_and(valid, prog.updated_mask(old, new))
    return new, updated


def stacked_tiles_step(
    prog: VertexProgram,
    values: Array,
    aux: dict[str, Array],
    stk: dict[str, Array],        # stacked tiles (tiles.stack_tiles output)
    row_cap: int,
    seg_impl: str = "jnp",
    blocks: Optional[tuple[int, int]] = None,
) -> tuple[Array, Array]:
    """Process a stack of tiles via lax.scan (one server's local work for a
    superstep).  Returns (new_masked [V(, Q)], updated [V(, Q)] bool): the
    updated value where updated, else 0.

    Masked values (new where updated, else 0) + the update mask make the
    cross-server Broadcast a plain psum pair: tiles own disjoint row
    ranges, so exactly one server contributes per vertex.  (Additive
    deltas would NaN on +/-inf-valued programs like SSSP.)

    Tiles own *contiguous* dst ranges (the paper's 1-D layout), so the
    per-tile update is a dynamic-slice read-modify-write on padded buffers
    rather than a scatter (§Perf It3: ~2x on the CPU engine; on TPU this is
    the difference between a DUS and a gather/scatter pair).
    """
    nv = values.shape[0]
    pad = row_cap + 1
    tail = values.shape[1:]            # () or (Q,) — the query axis
    values_p = _row_pad(values, pad)
    aux_p = {k: _row_pad(aux[k], pad) for k in prog.dst_aux}

    fs = prog.fused_spec() if seg_impl == "pallas_fused" else None

    def body(carry, tile):
        out_p, upd_p = carry
        row_start = tile["row_start"]
        num_rows = tile["num_rows"]

        src_vals = jnp.take(values, tile["src"], axis=0)
        src_aux = {k: jnp.take(aux[k], tile["src"], axis=0)
                   for k in prog.src_aux}
        old = _dslice(values_p, row_start, row_cap)
        dst_aux = {k: _dslice(aux_p[k], row_start, row_cap)
                   for k in prog.dst_aux}
        if fs is not None:
            new, updated = _fused_tile(
                prog, fs, src_vals, src_aux, tile["val"], tile["dst_local"],
                old, dst_aux, num_rows, row_cap, blocks)
        else:
            contrib = prog.gather(src_vals, tile["val"], src_aux)
            accum = segment_reduce(contrib, tile["dst_local"], row_cap + 1,
                                   prog.combine, impl=_unfused_impl(seg_impl),
                                   blocks=blocks)[:row_cap]
            new = prog.apply(old, accum, dst_aux)
            local = jnp.arange(row_cap, dtype=jnp.int32)
            valid = _bcast_rows(local < num_rows, new)
            new = jnp.where(valid, new, old)
            updated = jnp.logical_and(valid, prog.updated_mask(old, new))

        cur = _dslice(out_p, row_start, row_cap)
        window = jnp.where(updated, new, cur)   # set-where-updated (overlap-safe)
        out_p = _dupdate(out_p, window, row_start)
        cur_u = _dslice(upd_p, row_start, row_cap)
        upd_p = _dupdate(upd_p, cur_u | updated, row_start)
        return (out_p, upd_p), None

    delta0 = jnp.zeros((nv + pad,) + tail, values.dtype)
    upd0 = jnp.zeros((nv + pad,) + tail, dtype=bool)
    scan_tiles = {
        "src": stk["src"],
        "dst_local": stk["dst_local"],
        "val": stk["val"],
        "row_start": stk["row_start"],
        "num_rows": stk["num_rows"],
    }
    (out_p, upd_p), _ = jax.lax.scan(body, (delta0, upd0), scan_tiles)
    return out_p[:nv], upd_p[:nv]


def merged_server_step(
    prog: VertexProgram,
    values: Array,                # [V]
    aux: dict[str, Array],
    src: Array,                   # [E_s] all real edges of this server's tiles
    dst: Array,                   # [E_s] global dst ids, sorted (padding = V)
    edge_val: Array,              # [E_s]
    owned: Array,                 # [V] bool: rows covered by this server
    seg_impl: str = "jnp",
    blocks: Optional[tuple[int, int]] = None,
) -> tuple[Array, Array]:
    """§Perf It5: one fused gather/segment-sum/apply per server.

    Tiles' dst ranges are disjoint and each vertex's in-edges live in one
    tile, so merging a server's tiles into a single edge list and reducing
    straight into [V] is exact; apply runs on all rows and is masked by
    ownership.  Removes the tile scan, the per-tile slicing, and all edge
    padding (only real edges are stored).

    The merged path masks rows by *ownership* rather than a contiguous
    ``num_rows`` window, which the fused kernel's row test cannot express —
    ``pallas_fused`` therefore degrades to the unfused one-hot kernel here
    (same autotuned blocks)."""
    nv = values.shape[0]
    src_vals = jnp.take(values, src, axis=0)
    src_aux = {k: jnp.take(aux[k], src, axis=0) for k in prog.src_aux}
    contrib = prog.gather(src_vals, edge_val, src_aux)
    accum = segment_reduce(contrib, dst, nv + 1, prog.combine,
                           impl=_unfused_impl(seg_impl), blocks=blocks)[:nv]
    dst_aux = {k: aux[k] for k in prog.dst_aux}
    new = prog.apply(values, accum, dst_aux)
    own = _bcast_rows(owned, new)
    new = jnp.where(own, new, values)
    updated = jnp.logical_and(own, prog.updated_mask(values, new))
    new_masked = jnp.where(updated, new, jnp.zeros_like(values))
    return new_masked, updated


# ---------------------------------------------------------------------------
# Single-tile jit wrapper used by the out-of-core engine (static shapes keyed
# by (edge_cap, row_cap), so one compile serves every tile).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(0, 7, 8, 9))
def _jit_tile_step(prog, values, aux, src, dst_local, edge_val,
                   row_start_num_rows, row_cap, seg_impl, blocks):
    row_start, num_rows = row_start_num_rows
    return tile_gather_apply(
        prog, values, aux, src, dst_local, edge_val,
        row_start, num_rows, row_cap, seg_impl, blocks,
    )


def run_tile(prog, values, aux, tile_arrays, row_start, num_rows,
             row_cap, seg_impl="jnp", blocks=None):
    """Out-of-core engine entry point for one tile (host arrays ok)."""
    src, dst_local, edge_val = tile_arrays
    return _jit_tile_step(
        prog, values, aux, src, dst_local, edge_val,
        (jnp.int32(row_start), jnp.int32(num_rows)), row_cap, seg_impl,
        blocks,
    )


@partial(jax.jit, static_argnums=(0, 8, 9, 10))
def _jit_tile_step_sharded(prog, src_vals, src_aux, edge_val, dst_local,
                           old, dst_aux, num_rows, row_cap, seg_impl,
                           blocks):
    return tile_gather_apply_sharded(
        prog, src_vals, src_aux, edge_val, dst_local, old, dst_aux,
        num_rows, row_cap, seg_impl, blocks,
    )


def run_tile_sharded(prog, src_vals, src_aux, edge_val, dst_local, old,
                     dst_aux, num_rows, row_cap, seg_impl="jnp",
                     blocks=None):
    """Ooc-vstate engine entry point for one tile (host arrays ok); one
    compile serves every tile (shapes keyed by (edge_cap, row_cap, Q))."""
    return _jit_tile_step_sharded(
        prog, src_vals, src_aux, edge_val, dst_local, old, dst_aux,
        jnp.int32(num_rows), row_cap, seg_impl, blocks,
    )


# ---------------------------------------------------------------------------
# Stacked-tile batch entry used by the pipelined engine: K prefetched tiles,
# padded to a fixed stack size, dispatched as ONE jitted scan.  Amortizes
# per-tile dispatch overhead; compilation is keyed by (K, edge_cap, row_cap),
# so a fixed stack_size means a single compile for the whole run.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(0, 4, 5, 6))
def _jit_run_tile_stack(prog, values, aux, stk, row_cap, seg_impl, blocks):
    return stacked_tiles_step(prog, values, aux, stk, row_cap, seg_impl,
                              blocks)


def run_tile_stack(prog, values, aux, stk, row_cap, seg_impl="jnp",
                   blocks=None):
    """Process a K-tile stack (``tiles.stack_tiles`` output, possibly padded
    with inert tiles via ``distributed.pad_stack_to``) in one dispatch.

    Returns (new_masked [V], updated [V] bool) — identical per-row results
    to running ``run_tile`` over the same tiles one at a time, since tiles
    own disjoint row ranges.
    """
    scan = {k: jnp.asarray(stk[k])
            for k in ("src", "dst_local", "val", "row_start", "num_rows")}
    return _jit_run_tile_stack(prog, values, aux, scan, row_cap, seg_impl,
                               blocks)
