"""Interval-sharded out-of-core vertex state (DESIGN.md §10).

GraphH's All-in-All policy keeps the full ``[V(, Q)]`` value/aux arrays
resident on every server — the one remaining memory wall once edges
stream from disk.  GraphD and DFOGraph (PAPERS.md) go *fully* out of
core: vertex state is split into intervals and spilled to disk, so the
vertex footprint alone may exceed RAM.  This module is that layer.

V is cut into K contiguous *source intervals* aligned to tile row ranges
(``partition.plan_intervals``).  Every registered array ("value" plus the
program's aux arrays) is sharded into one block per interval, and blocks
move through the same hot/warm/cold ladder as the edge cache
(``cache.TIER_LADDER``):

    tier   representation                      cost to touch
    hot    resident ndarray                    zero
    warm   zstd-1 blob in memory               decompress
    cold   zstd-9 blob spilled to a disk file  read + decompress

A byte budget bounds hot + warm bytes; the cold tier is disk and
unbounded — this is what opens the "vertex set bigger than RAM"
scenario.  Demotion is clean-block-aware: a block whose warm blob or
spill file is still current is demoted by just dropping the hotter
representation (no codec, no write); only *dirty* blocks — written since
their last serialization — pay compression and disk writes on the way
down (the dirty-writeback-only invariant, tested in tests/test_vstate.py).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core.cache import TIER_LADDER
from repro.graphio import formats

# warm = admission blob (zstd-1 analogue), cold = on-disk spill (zstd-9)
WARM_MODE = TIER_LADDER[1]
COLD_MODE = TIER_LADDER[2]


class VStateStats:
    """Counters are cumulative over the store's lifetime; the engine reports
    per-superstep deltas (like the edge-cache stats)."""

    def __init__(self) -> None:
        self.hits = 0                 # get_block served from the hot tier
        self.faults = 0               # get_block had to decode (warm + cold)
        self.warm_faults = 0
        self.cold_faults = 0
        self.load_bytes = 0           # compressed bytes decoded on faults
        self.spills = 0               # blocks written to the disk tier
        self.spill_bytes = 0          # compressed bytes written to disk
        self.dirty_writebacks = 0     # write_block calls (state mutations)
        self.compress_seconds = 0.0
        self.decompress_seconds = 0.0
        self.disk_seconds = 0.0

    def as_dict(self) -> dict:
        """Plain-dict snapshot (for logs/benchmark JSON)."""
        return dict(
            hits=self.hits, faults=self.faults,
            warm_faults=self.warm_faults, cold_faults=self.cold_faults,
            load_bytes=self.load_bytes, spills=self.spills,
            spill_bytes=self.spill_bytes,
            dirty_writebacks=self.dirty_writebacks,
            compress_seconds=self.compress_seconds,
            decompress_seconds=self.decompress_seconds,
            disk_seconds=self.disk_seconds,
        )


@dataclasses.dataclass
class _Block:
    """One interval of one array.  Representations, newest first:
    ``arr`` (hot) > ``blob`` (warm, current iff not None) > spill file
    (current iff ``file_ok``).  ``write_block`` invalidates the colder
    copies; demotion reuses a still-current colder copy for free."""

    name: str
    k: int
    shape: tuple
    dtype: np.dtype
    arr: Optional[np.ndarray] = None
    blob: Optional[bytes] = None
    file_ok: bool = False
    #: content version — bumped on every mutation (write_block /
    #: compact_columns).  The graph checkpointer keys its incremental
    #: "unchanged block -> hardlink" decision on this counter.
    version: int = 0

    @property
    def raw_bytes(self) -> int:
        return int(np.prod(self.shape)) * self.dtype.itemsize

    def mem_bytes(self) -> int:
        n = 0
        if self.arr is not None:
            n += self.arr.nbytes
        if self.blob is not None:
            n += len(self.blob)
        return n


class VertexStateStore:
    """Interval-sharded container for the engine's per-vertex arrays.

    ``get_block`` returns the hot ndarray for one interval (callers must
    treat it as read-only); ``write_block`` replaces an interval's content
    and marks it dirty.  ``budget_bytes=None`` disables spilling entirely
    (everything stays hot) — the engine only builds a store when a budget
    is set, but unit tests use the unlimited mode as the oracle."""

    #: lock discipline, enforced by tools/analyze.py --check locks
    _guarded_by = {"_blocks": "_lock", "_specs": "_lock",
                   "_mem": "_lock", "stats": "_lock"}

    def __init__(self, splitter: np.ndarray,
                 budget_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        self.splitter = np.asarray(splitter, dtype=np.int64)
        assert len(self.splitter) >= 2
        self.budget_bytes = None if budget_bytes is None else int(budget_bytes)
        self.spill_dir = spill_dir
        self.stats = VStateStats()
        self._blocks: OrderedDict[tuple[str, int], _Block] = OrderedDict()
        self._specs: dict[str, tuple[np.dtype, tuple]] = {}  # name -> (dtype, tail)
        self._mem = 0
        self._lock = threading.RLock()

    # -- geometry -----------------------------------------------------------
    @property
    def num_intervals(self) -> int:
        """K = number of vertex intervals."""
        return len(self.splitter) - 1

    @property
    def num_vertices(self) -> int:
        """V = total vertices covered by the splitter."""
        return int(self.splitter[-1])

    def interval_range(self, k: int) -> tuple[int, int]:
        """[lo, hi) vertex range of interval ``k``."""
        return int(self.splitter[k]), int(self.splitter[k + 1])

    def interval_of(self, vertex_ids) -> np.ndarray:
        """Owning interval id ``[U]`` per vertex id ``[U]`` (vectorized
        searchsorted)."""
        return np.searchsorted(self.splitter, vertex_ids, side="right") - 1

    # -- registration / access ----------------------------------------------
    def add_array(self, name: str, arr: np.ndarray) -> None:
        """Shard a full ``[V(, Q)]`` array into interval blocks.  Blocks
        start hot; budget enforcement may immediately demote/spill the tail
        (the "initial state lives on disk" case)."""
        arr = np.asarray(arr)
        assert arr.shape[0] == self.num_vertices, (arr.shape, self.num_vertices)
        with self._lock:
            self._specs[name] = (arr.dtype, arr.shape[1:])
            for k in range(self.num_intervals):
                lo, hi = self.interval_range(k)
                blk = _Block(name=name, k=k, shape=(hi - lo,) + arr.shape[1:],
                             dtype=arr.dtype,
                             arr=np.ascontiguousarray(arr[lo:hi]))
                self._blocks[(name, k)] = blk
                self._mem += blk.mem_bytes()
            self._enforce_budget()

    def spec(self, name: str) -> tuple[np.dtype, tuple]:
        """(dtype, trailing shape) of a registered array."""
        with self._lock:
            return self._specs[name]

    def names(self) -> tuple[str, ...]:
        """Registered array names ("value" + the program's aux arrays)."""
        with self._lock:
            return tuple(self._specs)

    def get_block(self, name: str, k: int) -> np.ndarray:
        """Interval ``k`` of array ``name`` as a hot ndarray ``[B(, Q)]``
        (B = interval rows; read-only by convention — use ``write_block``
        to mutate)."""
        with self._lock:
            b = self._blocks[(name, k)]
            self._blocks.move_to_end((name, k))
            if b.arr is not None:
                self.stats.hits += 1
                return b.arr
            self.stats.faults += 1
            if b.blob is not None:
                self.stats.warm_faults += 1
                self.stats.load_bytes += len(b.blob)
                t0 = time.perf_counter()
                raw = formats.decompress_blob(b.blob, WARM_MODE)
                self.stats.decompress_seconds += time.perf_counter() - t0
            else:
                assert b.file_ok, f"block {(name, k)} has no representation"
                t0 = time.perf_counter()
                with open(self._path(b), "rb") as f:
                    fb = f.read()
                self.stats.disk_seconds += time.perf_counter() - t0
                self.stats.load_bytes += len(fb)
                t0 = time.perf_counter()
                raw = formats.decompress_blob(fb, COLD_MODE)
                self.stats.decompress_seconds += time.perf_counter() - t0
            b.arr = np.frombuffer(raw, dtype=b.dtype).reshape(b.shape).copy()
            self._mem += b.arr.nbytes
            self._enforce_budget(exclude=(name, k))
            return b.arr

    def write_block(self, name: str, k: int, arr: np.ndarray) -> None:
        """Replace interval ``k``'s content with arr ``[B(, Q)]`` — the
        dirty-writeback entry
        point.  Invalidates the warm/cold copies, so the block pays
        (re)serialization only when pressure later demotes it."""
        with self._lock:
            b = self._blocks[(name, k)]
            assert arr.shape == b.shape and arr.dtype == b.dtype, \
                (arr.shape, b.shape, arr.dtype, b.dtype)
            self._mem -= b.mem_bytes()
            b.arr = np.ascontiguousarray(arr)
            b.blob = None
            b.file_ok = False
            b.version += 1
            self._mem += b.mem_bytes()
            self._blocks.move_to_end((name, k))
            self.stats.dirty_writebacks += 1
            self._enforce_budget(exclude=(name, k))

    def materialize(self, name: str) -> np.ndarray:
        """Assemble the full array ``[V(, Q)]`` (used once, when a run
        finishes)."""
        return np.concatenate(
            [self.get_block(name, k) for k in range(self.num_intervals)])

    def compact_columns(self, names: list[str], keep: np.ndarray) -> None:
        """Multi-query retirement support: drop query columns (trailing-axis
        selection) from ``[V, Q]`` arrays, block by block."""
        keep = np.asarray(keep)
        with self._lock:
            for name in names:
                dt, tail = self._specs[name]
                assert len(tail) == 1, f"{name} has no query axis"
                self._specs[name] = (dt, (int(keep.sum()),))
                for k in range(self.num_intervals):
                    cur = self.get_block(name, k)
                    b = self._blocks[(name, k)]
                    self._mem -= b.mem_bytes()
                    b.arr = np.ascontiguousarray(cur[:, keep])
                    b.shape = b.arr.shape
                    b.blob = None
                    b.file_ok = False
                    b.version += 1
                    self._mem += b.mem_bytes()
            self._enforce_budget()

    def append_columns(self, cols: dict[str, np.ndarray]) -> None:
        """Multi-query admission support (DESIGN.md §13): splice fresh query
        columns onto the trailing axis of ``[V, Q]`` arrays, block by block.

        The inverse of ``compact_columns`` — but tier-preserving: each block
        is re-encoded *at its current tier* (hot blocks concat in memory;
        warm blobs decompress → concat → recompress warm; cold spill files
        are rewritten in place at cold mode) so admitting a query never
        promotes cold state into the byte budget.  ``cols`` maps array name
        to the ``[V, q_new]`` columns to append; every name must already be
        registered with a 1-D query tail."""
        with self._lock:
            for name, new in cols.items():
                new = np.asarray(new)
                dt, tail = self._specs[name]
                assert len(tail) == 1, f"{name} has no query axis"
                assert new.ndim == 2 and new.shape[0] == self.num_vertices, \
                    (name, new.shape, self.num_vertices)
                new = np.ascontiguousarray(new, dtype=dt)
                self._specs[name] = (dt, (int(tail[0]) + new.shape[1],))
                for k in range(self.num_intervals):
                    lo, hi = self.interval_range(k)
                    piece = new[lo:hi]
                    b = self._blocks[(name, k)]
                    self._mem -= b.mem_bytes()
                    if b.arr is not None:
                        b.arr = np.ascontiguousarray(
                            np.concatenate([b.arr, piece], axis=1))
                        b.shape = b.arr.shape
                        b.blob = None
                        b.file_ok = False
                    elif b.blob is not None:
                        t0 = time.perf_counter()
                        raw = formats.decompress_blob(b.blob, WARM_MODE)
                        self.stats.decompress_seconds += (
                            time.perf_counter() - t0)
                        cur = np.frombuffer(raw, dtype=b.dtype).reshape(b.shape)
                        cur = np.ascontiguousarray(
                            np.concatenate([cur, piece], axis=1))
                        b.shape = cur.shape
                        t0 = time.perf_counter()
                        b.blob = formats.compress_blob(cur.tobytes(), WARM_MODE)
                        self.stats.compress_seconds += (
                            time.perf_counter() - t0)
                        b.file_ok = False
                    else:
                        assert b.file_ok, \
                            f"block {(name, k)} has no representation"
                        t0 = time.perf_counter()
                        with open(self._path(b), "rb") as f:
                            fb = f.read()
                        self.stats.disk_seconds += time.perf_counter() - t0
                        t0 = time.perf_counter()
                        raw = formats.decompress_blob(fb, COLD_MODE)
                        self.stats.decompress_seconds += (
                            time.perf_counter() - t0)
                        cur = np.frombuffer(raw, dtype=b.dtype).reshape(b.shape)
                        cur = np.ascontiguousarray(
                            np.concatenate([cur, piece], axis=1))
                        b.shape = cur.shape
                        self._spill(b, cur.tobytes())
                    b.version += 1
                    self._mem += b.mem_bytes()
            self._enforce_budget()

    # -- checkpoint support (DESIGN.md §12) ----------------------------------
    def block_version(self, name: str, k: int) -> int:
        """Content version of one block — bumped on every mutation, so an
        unchanged version between two checkpoints means identical bytes
        (the checkpointer then hardlinks instead of re-serializing)."""
        with self._lock:
            return self._blocks[(name, k)].version

    def export_block(self, name: str, k: int) -> tuple[int, bytes]:
        """(compression mode, blob) for one block, reusing the *coldest
        already-current* representation — a clean spilled block's file
        bytes ship as-is (no recompression), a warm blob ships as-is,
        and only a dirty hot block pays one warm-mode compression.  Pure
        read: block state, tiers and budget accounting are untouched."""
        with self._lock:
            b = self._blocks[(name, k)]
            if b.file_ok:
                with open(self._path(b), "rb") as f:
                    return COLD_MODE, f.read()
            if b.blob is not None:
                return WARM_MODE, b.blob
            assert b.arr is not None, f"block {(name, k)} has no representation"
            return WARM_MODE, formats.compress_blob(b.arr.tobytes(), WARM_MODE)

    # -- introspection -------------------------------------------------------
    def resident_bytes(self) -> int:
        """Current in-memory bytes across hot ndarrays + warm blobs."""
        with self._lock:
            return self._mem

    def hot_intervals(self, name: str = "value") -> set[int]:
        """Intervals whose ``name`` block is in the hot tier right now —
        the scheduler's joint-residency signal."""
        with self._lock:
            return {k for (n, k), b in self._blocks.items()
                    if n == name and b.arr is not None}

    def hot_block_capacity(self, name: str = "value") -> int:
        """~How many ``name`` blocks fit hot under the budget (>= 1)."""
        if self.budget_bytes is None:
            return self.num_intervals
        with self._lock:
            per = max(1, max((self._blocks[(name, k)].raw_bytes
                              for k in range(self.num_intervals)), default=1))
        return max(1, self.budget_bytes // per)

    def tier_snapshot(self) -> dict:
        """Per-tier {blocks, bytes} residency snapshot (hot/warm/cold)."""
        with self._lock:
            out = dict(hot=dict(blocks=0, bytes=0),
                       warm=dict(blocks=0, bytes=0),
                       cold=dict(blocks=0, bytes=0))
            for b in self._blocks.values():
                if b.arr is not None:
                    out["hot"]["blocks"] += 1
                    out["hot"]["bytes"] += b.arr.nbytes
                elif b.blob is not None:
                    out["warm"]["blocks"] += 1
                    out["warm"]["bytes"] += len(b.blob)
                else:
                    out["cold"]["blocks"] += 1
            return out

    def close(self) -> None:
        """Remove spill files (the store is per-run scratch state).  A
        store without a spill_dir never touched disk — nothing to do."""
        if self.spill_dir is None:
            return
        with self._lock:
            for b in self._blocks.values():
                p = self._path(b)
                if os.path.exists(p):
                    os.remove(p)
                b.file_ok = False
            if (os.path.isdir(self.spill_dir)
                    and not os.listdir(self.spill_dir)):
                os.rmdir(self.spill_dir)

    # -- internals -----------------------------------------------------------
    def _path(self, b: _Block) -> str:
        assert self.spill_dir is not None, \
            "VertexStateStore needs a spill_dir to use the cold tier"
        return os.path.join(self.spill_dir, f"{b.name}.{b.k}.blk")

    def _enforce_budget(self, exclude: Optional[tuple] = None) -> None:
        """Demote LRU blocks down the ladder until hot+warm fits the budget.
        The just-touched block is excluded so a gather can always hold its
        current interval hot, even when one block exceeds the budget."""
        if self.budget_bytes is None:
            return
        while self._mem > self.budget_bytes:
            victim = None
            for key, b in self._blocks.items():   # LRU first
                if key != exclude and b.mem_bytes() > 0:
                    victim = b
                    break
            if victim is None:
                return
            self._demote(victim)

    def _demote(self, b: _Block) -> None:
        if b.arr is not None:
            if b.blob is None and not b.file_ok:
                raw = b.arr.tobytes()
                t0 = time.perf_counter()
                blob = formats.compress_blob(raw, WARM_MODE)
                self.stats.compress_seconds += time.perf_counter() - t0
                if len(blob) < b.raw_bytes:
                    b.blob = blob
                    self._mem += len(blob)
                else:
                    # incompressible: a warm blob would not shrink memory,
                    # so spill straight to the disk tier
                    self._spill(b, raw)
            self._mem -= b.arr.nbytes
            b.arr = None
        elif b.blob is not None:
            if not b.file_ok:
                t0 = time.perf_counter()
                raw = formats.decompress_blob(b.blob, WARM_MODE)
                self.stats.decompress_seconds += time.perf_counter() - t0
                self._spill(b, raw)
            self._mem -= len(b.blob)
            b.blob = None

    def _spill(self, b: _Block, raw: bytes) -> None:
        t0 = time.perf_counter()
        fb = formats.compress_blob(raw, COLD_MODE)
        self.stats.compress_seconds += time.perf_counter() - t0
        t0 = time.perf_counter()
        path = self._path(b)
        if self.spill_dir:
            os.makedirs(self.spill_dir, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(fb)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self.stats.disk_seconds += time.perf_counter() - t0
        self.stats.spills += 1
        self.stats.spill_bytes += len(fb)
        b.file_ok = True
