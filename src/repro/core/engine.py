"""Out-of-core GAB engine — the paper's MPE (§III-C, Algorithm 5).

Emulates N servers x T workers in one process with *real* out-of-core
behaviour: tiles live in the TileStore (disk tier), each server owns a
round-robin tile subset and an EdgeCache over "idle" memory, vertex state
is fully replicated (All-in-All), and the per-superstep Broadcast payloads
are measured (and actually compressed) through core.comm.

This is the measurable CPU reference implementation; distributed.py maps
the identical superstep onto a device mesh with shard_map.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm
from repro.core.bloom import SourceBlockBitmap, BloomFilter
from repro.core.cache import EdgeCache, auto_select_mode, DEFAULT_GAMMAS
from repro.core.gab import VertexProgram, run_tile
from repro.core.partition import assign_tiles, assign_tiles_balanced
from repro.core.tiles import tile_edge_values
from repro.graphio.formats import TileStore


@dataclasses.dataclass
class EngineConfig:
    num_servers: int = 1
    num_workers: int = 1                    # paper's T (accounting only here)
    cache_capacity_bytes: int = 1 << 30     # per server
    cache_mode: int | str = "auto"          # 1..4 or "auto"
    comm_mode: str = "hybrid"               # dense | sparse | hybrid
    comm_compressor: str = "zstd-1"         # paper default: snappy
    comm_threshold: float = comm.DENSITY_THRESHOLD
    tile_skipping: bool = True
    skip_filter: str = "bitmap"             # "bitmap" (exact) | "bloom" (paper)
    skip_density_threshold: float = 0.05    # paper: only when few updates
    seg_impl: str = "jnp"
    max_supersteps: int = 200
    balanced_assignment: bool = False       # beyond-paper LPT stage-2
    bloom_bits: int = 1 << 16
    block_shift: int = 8
    # --- beyond-paper performance features (EXPERIMENTS.md §Perf) ---
    # "tiled": paper-faithful one-tile-at-a-time processing
    # "stacked": device-resident stacked tiles, one scan per server (the
    #            HBM tier of the cache hierarchy; falls back to tiled for
    #            tiles beyond device_budget_bytes or when skipping is on)
    engine_mode: str = "tiled"
    device_budget_bytes: int = 1 << 30      # per server, for "stacked"
    # wire accounting: "full" compresses every payload (measured bytes);
    # "sampled" compresses every 4th superstep and reuses the last ratio
    comm_accounting: str = "full"


@dataclasses.dataclass
class SuperstepStats:
    superstep: int
    seconds: float
    load_seconds: float
    compute_seconds: float
    updated_vertices: int
    density: float
    tiles_processed: int
    tiles_skipped: int
    raw_bytes: int            # sum over servers of broadcast payload
    wire_bytes: int           # after compression
    network_bytes: int        # wire * (N-1): each server ships to N-1 peers
    cache_hit_ratio: float
    disk_bytes_read: int


@dataclasses.dataclass
class RunResult:
    values: np.ndarray
    aux: dict
    history: list[SuperstepStats]
    supersteps: int
    converged: bool

    def total_seconds(self) -> float:
        return sum(h.seconds for h in self.history)

    def mean_superstep_seconds(self, skip_first: bool = True) -> float:
        hs = self.history[1:] if skip_first and len(self.history) > 1 else self.history
        return float(np.mean([h.seconds for h in hs])) if hs else 0.0


class OutOfCoreEngine:
    def __init__(self, store: TileStore, config: EngineConfig = EngineConfig()):
        self.store = store
        self.cfg = config
        self.plan = store.load_plan()
        self.in_degree, self.out_degree = store.load_degrees()
        P, N = self.plan.num_tiles, config.num_servers
        if config.balanced_assignment:
            self.assignment = assign_tiles_balanced(self.plan.edges_per_tile, N)
        else:
            self.assignment = assign_tiles(P, N)

        # Per-server edge caches (paper: idle memory on each server).
        if config.cache_mode == "auto":
            # Working set per server ~ share of total on-disk tile bytes.
            total = sum(store.tile_disk_bytes(t) for t in range(P))
            mode = auto_select_mode(total // max(N, 1), config.cache_capacity_bytes)
        else:
            mode = int(config.cache_mode)
        self.cache_mode = mode
        self.caches = [
            EdgeCache(store, config.cache_capacity_bytes, mode) for _ in range(N)
        ]
        self._filters: Optional[list] = None  # built during first superstep
        self._stacks: Optional[list] = None   # per-server device-resident tiles
        self._stack_fn = None
        self._streamed: list[list[int]] = [[] for _ in range(N)]
        self._wire_ratio: Optional[float] = None

    # ------------------------------------------------------------------
    def run(self, prog: VertexProgram,
            max_supersteps: Optional[int] = None) -> RunResult:
        cfg = self.cfg
        nv = self.plan.num_vertices
        state = prog.init(nv, self.out_degree.astype(np.float64),
                          self.in_degree.astype(np.float64))
        values = np.asarray(state.pop("value"))
        aux_dev = {k: jnp.asarray(v) for k, v in state.items()}
        row_cap = self.plan.row_cap

        max_ss = max_supersteps or cfg.max_supersteps
        history: list[SuperstepStats] = []
        updated_ids = np.arange(nv)   # everything "updated" before step 0
        building_filters = cfg.tile_skipping
        filters: list = [None] * self.plan.num_tiles if building_filters else []

        converged = False
        for ss in range(max_ss):
            t_start = time.perf_counter()
            values_dev = jnp.asarray(values)
            load_s = 0.0
            comp_s = 0.0
            tiles_done = 0
            tiles_skipped = 0
            upd_idx_parts: list[np.ndarray] = []
            upd_val_parts: list[np.ndarray] = []
            per_server_updates: list[tuple[np.ndarray, np.ndarray]] = []

            skip_on = (
                cfg.tile_skipping
                and ss > 0
                and len(updated_ids) < cfg.skip_density_threshold * nv
                and self._filters is not None
            )
            active_words = None
            if skip_on and cfg.skip_filter == "bitmap":
                active_words = SourceBlockBitmap.active_words_from_ids(
                    updated_ids, nv, cfg.block_shift
                )

            for s in range(cfg.num_servers):
                s_idx: list[np.ndarray] = []
                s_val: list[np.ndarray] = []
                server_tiles = self.assignment[s]
                if cfg.engine_mode in ("stacked", "merged") and not skip_on:
                    if self._stacks is None:
                        t0 = time.perf_counter()
                        if cfg.engine_mode == "merged":
                            self._build_merged(nv)
                        else:
                            self._build_stacks(nv)
                        if building_filters:
                            for st in range(cfg.num_servers):
                                n_res = len(self.assignment[st]) - len(self._streamed[st])
                                for tid in self.assignment[st][:n_res]:
                                    if filters[tid] is None:
                                        filters[tid] = self._make_filter(
                                            self.caches[st].get(tid), nv)
                        load_s += time.perf_counter() - t0
                    t0 = time.perf_counter()
                    step_fn = (self._merged_step if cfg.engine_mode == "merged"
                               else self._stack_step)
                    new_masked, upd = step_fn(prog, values_dev, aux_dev,
                                              self._stacks[s])
                    si = np.nonzero(np.asarray(upd))[0]
                    sv = np.asarray(new_masked)[si]
                    comp_s += time.perf_counter() - t0
                    s_idx.append(si)
                    s_val.append(sv.astype(values.dtype))
                    tiles_done += len(self.assignment[s]) - len(self._streamed[s])
                    server_tiles = self._streamed[s]
                for tid in server_tiles:
                    if skip_on:
                        f = self._filters[tid]
                        hit = (
                            f.intersects(active_words)
                            if cfg.skip_filter == "bitmap"
                            else f.might_contain_any(updated_ids)
                        )
                        if not hit:
                            tiles_skipped += 1
                            continue
                    t0 = time.perf_counter()
                    tile = self.caches[s].get(tid)
                    load_s += time.perf_counter() - t0

                    if building_filters and filters[tid] is None:
                        filters[tid] = self._make_filter(tile, nv)

                    t0 = time.perf_counter()
                    rows, new, upd = run_tile(
                        prog, values_dev, aux_dev,
                        (tile.src, tile.dst_local, tile_edge_values(tile)),
                        tile.meta.row_start, tile.meta.num_rows,
                        row_cap, cfg.seg_impl,
                    )
                    rows = np.asarray(rows)
                    new = np.asarray(new)
                    upd = np.asarray(upd)
                    comp_s += time.perf_counter() - t0
                    s_idx.append(rows[upd])
                    s_val.append(new[upd])
                    tiles_done += 1
                si = np.concatenate(s_idx) if s_idx else np.zeros(0, np.int64)
                sv = np.concatenate(s_val) if s_val else np.zeros(0, values.dtype)
                per_server_updates.append((si, sv))
                upd_idx_parts.append(si)
                upd_val_parts.append(sv)

            if building_filters and all(f is not None for f in filters):
                self._filters = filters
                building_filters = False

            # --- Broadcast (BSP barrier): measure payloads, apply updates ---
            raw_b = wire_b = 0
            sample = not (cfg.comm_accounting == "sampled" and ss % 4 != 0
                          and self._wire_ratio is not None)
            for s in range(cfg.num_servers):
                si, sv = per_server_updates[s]
                if sample:
                    upd_mask = np.zeros(nv, dtype=bool)
                    upd_mask[si] = True
                    rec = comm.plan_broadcast(
                        _densify(sv, si, nv, values.dtype),
                        upd_mask,
                        threshold=cfg.comm_threshold,
                        compressor=cfg.comm_compressor,
                        mode=cfg.comm_mode,
                    )
                    raw_b += rec.raw_bytes
                    wire_b += rec.wire_bytes
                else:
                    est = comm.wire_bytes_estimate(nv, len(si) / max(nv, 1))
                    raw_b += est
                    wire_b += int(est * self._wire_ratio)
            if sample and raw_b:
                self._wire_ratio = wire_b / raw_b

            all_idx = np.concatenate(upd_idx_parts) if upd_idx_parts else np.zeros(0, np.int64)
            all_val = np.concatenate(upd_val_parts) if upd_val_parts else np.zeros(0, values.dtype)
            values[all_idx] = all_val
            updated_ids = all_idx

            cache_stats = self._agg_cache_stats()
            history.append(SuperstepStats(
                superstep=ss,
                seconds=time.perf_counter() - t_start,
                load_seconds=load_s,
                compute_seconds=comp_s,
                updated_vertices=int(len(all_idx)),
                density=float(len(all_idx)) / max(nv, 1),
                tiles_processed=tiles_done,
                tiles_skipped=tiles_skipped,
                raw_bytes=raw_b,
                wire_bytes=wire_b,
                network_bytes=wire_b * max(cfg.num_servers - 1, 0),
                cache_hit_ratio=cache_stats["hit_ratio"],
                disk_bytes_read=cache_stats["disk_bytes_read"],
            ))
            if len(all_idx) == 0:
                converged = True
                break

        return RunResult(values=values, aux=state, history=history,
                         supersteps=len(history), converged=converged)

    # ------------------------------------------------------------------
    # stacked fast path (engine_mode="stacked"): device-resident tiles
    # ------------------------------------------------------------------
    def _build_stacks(self, nv: int) -> None:
        from repro.core.tiles import stack_tiles

        budget = self.cfg.device_budget_bytes
        per_tile = self.plan.edge_cap * 12  # src+dst+val
        self._stacks = []
        for s in range(self.cfg.num_servers):
            fit = max(1, budget // per_tile)
            resident = self.assignment[s][:fit]
            self._streamed[s] = self.assignment[s][fit:]
            tiles = [self.caches[s].get(t) for t in resident]
            stk = stack_tiles(tiles, self.plan.row_cap)
            self._stacks.append({
                k: jnp.asarray(stk[k])
                for k in ("src", "dst_local", "val", "row_start", "num_rows")
            })

    def _build_merged(self, nv: int) -> None:
        """engine_mode="merged" (§Perf It5): per-server fused edge lists."""
        self._stacks = []
        for s in range(self.cfg.num_servers):
            self._streamed[s] = []
            srcs, dsts, vals = [], [], []
            owned = np.zeros(nv + 1, dtype=bool)
            for tid in self.assignment[s]:
                t = self.caches[s].get(tid)
                n = t.meta.num_edges
                srcs.append(t.src[:n])
                dsts.append(t.dst_local[:n].astype(np.int64) + t.meta.row_start)
                from repro.core.tiles import tile_edge_values
                vals.append(tile_edge_values(t)[:n])
                owned[t.meta.row_start: t.meta.row_end] = True
            self._stacks.append(dict(
                src=jnp.asarray(np.concatenate(srcs).astype(np.int32)),
                dst=jnp.asarray(np.concatenate(dsts).astype(np.int32)),
                val=jnp.asarray(np.concatenate(vals)),
                owned=jnp.asarray(owned[:nv]),
            ))

    def _merged_step(self, prog, values_dev, aux_dev, m):
        from repro.core.gab import merged_server_step

        if self._stack_fn is None:
            from functools import partial

            @partial(jax.jit, static_argnums=(0, 1))
            def fn(p, seg_impl, values, aux, src, dst, val, owned):
                return merged_server_step(p, values, aux, src, dst, val,
                                          owned, seg_impl)

            self._stack_fn = fn
        return self._stack_fn(prog, self.cfg.seg_impl, values_dev, aux_dev,
                              m["src"], m["dst"], m["val"], m["owned"])

    def _stack_step(self, prog, values_dev, aux_dev, stack):
        from repro.core.gab import stacked_tiles_step

        if self._stack_fn is None:
            from functools import partial

            row_cap = self.plan.row_cap

            @partial(jax.jit, static_argnums=(0, 3))
            def fn(p, values, aux, seg_impl, stk):
                return stacked_tiles_step(p, values, aux, stk, row_cap, seg_impl)

            self._stack_fn = fn
        return self._stack_fn(prog, values_dev, aux_dev, self.cfg.seg_impl, stack)

    # ------------------------------------------------------------------
    def _make_filter(self, tile, nv):
        srcs = tile.source_ids()
        if self.cfg.skip_filter == "bitmap":
            f = SourceBlockBitmap(nv, self.cfg.block_shift)
        else:
            f = BloomFilter(num_bits=self.cfg.bloom_bits)
        f.add(srcs)
        return f

    def _agg_cache_stats(self) -> dict:
        hits = sum(c.stats.hits for c in self.caches)
        misses = sum(c.stats.misses for c in self.caches)
        return dict(
            hit_ratio=hits / max(hits + misses, 1),
            disk_bytes_read=sum(c.stats.disk_bytes_read for c in self.caches),
        )


def _densify(vals: np.ndarray, idx: np.ndarray, nv: int, dtype) -> np.ndarray:
    out = np.zeros(nv, dtype=dtype)
    out[idx] = vals
    return out
