"""Out-of-core GAB engine — the paper's MPE (§III-C, Algorithm 5).

Emulates N servers x T workers in one process with *real* out-of-core
behaviour: tiles live in the TileStore (disk tier), each server owns a
round-robin tile subset and an EdgeCache over "idle" memory, vertex state
is fully replicated (All-in-All), and the per-superstep Broadcast payloads
are measured (and actually compressed) through core.comm.

This is the measurable CPU reference implementation; distributed.py maps
the identical superstep onto a device mesh with shard_map.
"""
from __future__ import annotations

import dataclasses
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm
from repro.core.bloom import SourceBlockBitmap, BloomFilter
from repro.core.cache import EdgeCache, auto_select_mode, DEFAULT_GAMMAS
from repro.core.checkpoint import GraphCheckpointer
from repro.core.gab import VertexProgram, run_tile, run_tile_sharded
from repro.core.partition import (assign_tiles, assign_tiles_balanced,
                                  plan_intervals)
from repro.core.tiles import compute_source_footprint, tile_edge_values
from repro.core.vstate import VertexStateStore
from repro.graphio.formats import TileStore
from repro.runtime.elastic import remap_assignment
from repro.runtime.faults import FaultPlan
from repro.runtime.ft import Preempted, PreemptionGuard


@dataclasses.dataclass
class EngineConfig:
    """All engine knobs (one dataclass so cluster server processes can ship
    it through multiprocessing spawn).  Field groups are commented below;
    see docs/OPERATIONS.md for tuning guidance."""
    num_servers: int = 1
    num_workers: int = 1                    # paper's T (accounting only here)
    cache_capacity_bytes: int = 1 << 30     # per server
    cache_mode: int | str = "auto"          # 1..4 or "auto" (lru policy)
    # --- adaptive multi-tier cache (DESIGN.md §8) ---
    # "lru": paper-faithful whole-cache single mode + LRU eviction
    # "tiered": per-tile hot/warm/cold ladder, demote-before-evict
    # "cost-aware": tiered with decompress-seconds-saved/byte victims
    cache_policy: str = "lru"
    cache_promote_hits: int = 2             # hits between tier promotions
    # cache-hit-first tile ordering: resident tiles run while the prefetcher
    # pulls the misses (order is irrelevant to results — disjoint rows)
    cache_aware_order: bool = True
    comm_mode: str = "hybrid"               # dense | sparse | hybrid
    comm_compressor: str = "zstd-1"         # paper default: snappy
    comm_threshold: float = comm.DENSITY_THRESHOLD
    tile_skipping: bool = True
    skip_filter: str = "bitmap"             # "bitmap" (exact) | "bloom" (paper)
    skip_density_threshold: float = 0.05    # paper: only when few updates
    seg_impl: str = "jnp"
    # --- fused-kernel block autotuning (DESIGN.md §14) ---
    # pick (BE, BR, stack_size) for the Pallas kernel paths from the
    # roofline cost model (roofline/kernel_tune.py) per (app monoid, Q,
    # tile shape) instead of the static (512, 256) defaults.  Also
    # promotes seg_impl="jnp" to "pallas_fused" — autotuning targets the
    # fused gather→combine→apply kernel.
    kernel_autotune: bool = False
    # explicit (BE, BR) override for the Pallas kernel paths; None = the
    # kernel's static defaults (or the autotuner's pick when
    # kernel_autotune is on).  Takes precedence over the autotuner.
    kernel_blocks: Optional[tuple] = None
    max_supersteps: int = 200
    balanced_assignment: bool = False       # beyond-paper LPT stage-2
    bloom_bits: int = 1 << 16
    block_shift: int = 8
    # --- beyond-paper performance features (EXPERIMENTS.md §Perf) ---
    # "tiled": paper-faithful one-tile-at-a-time processing
    # "stacked": device-resident stacked tiles, one scan per server (the
    #            HBM tier of the cache hierarchy; falls back to tiled for
    #            tiles beyond device_budget_bytes or when skipping is on)
    engine_mode: str = "tiled"
    device_budget_bytes: int = 1 << 30      # per server, for "stacked"
    # wire accounting: "full" compresses every payload (measured bytes);
    # "sampled" compresses every 4th superstep and reuses the last ratio
    comm_accounting: str = "full"
    # --- pipelined superstep (DESIGN.md §7): overlap tile N+1 load with
    # tile N compute and server s-1 broadcast-compression.  pipeline=False
    # keeps the paper-faithful serial loop as the baseline.
    pipeline: bool = False
    prefetch_depth: int = 4                 # tiles read+decompressed ahead
    prefetch_workers: int = 2               # parallel read/decompress threads
    stack_size: int = 4                     # tiles per jitted batch dispatch
    # record every tile-skip decision (superstep, active ids, run/skipped
    # tile lists) into engine.skip_log — test/debug aid for the skip-filter
    # safety property; off by default (the active-id snapshot costs memory)
    debug_skip_log: bool = False
    # --- out-of-core vertex state (DESIGN.md §10) ---
    # byte budget for the interval-sharded VertexStateStore's in-memory
    # tiers (hot ndarrays + warm compressed blobs); beyond it, interval
    # blocks spill to a disk tier.  None keeps the paper's fully-resident
    # [V, Q] vertex arrays.  Forces engine_mode="tiled" (stacked/merged
    # need the full value array on device).
    vertex_memory_budget: Optional[int] = None
    # source intervals K; 0 = auto (sized so ~4 value blocks fit the
    # budget, or the store's preprocessed interval plan when present)
    num_intervals: int = 0
    # co-order tiles to maximize *joint* residency of edge tiles (edge
    # cache) and source intervals (vertex cache); only active in ooc-vstate
    # mode — superstep 0 falls back to cache-hit-first ordering while
    # footprints are still unknown
    interval_aware_order: bool = True
    # --- multi-process cluster runtime (DESIGN.md §11) ---
    # when set, this engine instance is ONE server of an N-server cluster:
    # it executes only rank ``server_rank`` of the stage-2 assignment and
    # merges the other servers' per-superstep updates through the
    # ClusterExchange passed to the constructor.  None = the classic
    # single-process engine emulating all N servers itself.
    server_rank: Optional[int] = None
    # --- superstep checkpointing + fault tolerance (DESIGN.md §12) ---
    # directory for superstep-boundary checkpoints (core.checkpoint); None
    # disables checkpointing entirely
    checkpoint_dir: Optional[str] = None
    # save every K superstep boundaries (rank 0 / classic engine only);
    # 0 = no periodic saves (still saves on preemption + run completion)
    checkpoint_every: int = 0
    checkpoint_keep: int = 2
    # resume from the latest checkpoint in checkpoint_dir: adopt its tile
    # assignment (remapped via elastic.remap_assignment when num_servers
    # changed — the mid-run N->M resize path) and continue from the saved
    # superstep boundary; bit-identical to the uninterrupted run
    resume: bool = False
    # latch SIGTERM/SIGINT at the BSP barrier: save a checkpoint and raise
    # runtime.ft.Preempted instead of dying mid-superstep (spot reclaim);
    # requires checkpoint_dir
    preemptible: bool = False
    # deterministic fault injection (runtime.faults.FaultPlan) — test-only;
    # arms engine sites "superstep"/"barrier", the ckpt.* save sites, and
    # (in cluster launches) "transport.send"
    fault_plan: Optional[FaultPlan] = None
    # --- step-driven sessions + mid-run query admission (DESIGN.md §13) ---
    # scripted admissions for batch runs: tuple of (after_superstep, seeds)
    # entries — each seeds tuple is spliced into the [V, Q] state as fresh
    # query columns at the END of superstep ``after_superstep`` (their
    # first compute superstep is after_superstep + 1), in every execution
    # mode.  Cluster launches replicate the plan to every rank through
    # this config so peers know the run is not done while entries pend,
    # but the admission records themselves always originate at rank 0 and
    # ride its update frame.  Ignored for 1-D (single-query) programs.
    admit_plan: Optional[tuple] = None


@dataclasses.dataclass
class SuperstepStats:
    """Per-superstep measurements (bytes are real payload/compressed sizes,
    seconds wall-clock).  Cluster runs report cluster-total wire bytes,
    rank-local cache/io counters."""
    superstep: int
    seconds: float
    load_seconds: float
    compute_seconds: float
    updated_vertices: int
    density: float
    tiles_processed: int
    tiles_skipped: int
    raw_bytes: int            # sum over servers of broadcast payload
    wire_bytes: int           # after compression
    network_bytes: int        # wire * (N-1): each server ships to N-1 peers
    cache_hit_ratio: float
    disk_bytes_read: int      # bytes read from the disk tier THIS superstep
    # time the compute loop spent *blocked* waiting for tile data.  Serial
    # engine: equals the full load time.  Pipelined engine: only the residual
    # wait after prefetch overlap — the disk-stall the pipeline couldn't hide.
    stall_seconds: float = 0.0
    # disk read + (de)compress busy time this superstep, wherever it ran
    # (inline for the serial engine, prefetch threads for the pipelined one)
    io_busy_seconds: float = 0.0
    # tiered-cache activity this superstep (zeros for policy="lru")
    cache_promotions: int = 0
    cache_demotions: int = 0
    # per-tier residency at the barrier: {tier: {tiles, bytes, hits}}
    cache_tiers: dict = dataclasses.field(default_factory=dict)
    # --- multi-query accounting (DESIGN.md §9; all trivial for 1-D runs) ---
    # query columns still live when this superstep started
    active_queries: int = 1
    # updated (vertex, query) cells; == updated_vertices for 1-D runs
    updated_pairs: int = 0
    # {global query id: updated-cell count} for active queries
    updated_per_query: dict = dataclasses.field(default_factory=dict)
    # global query ids whose columns converged (and were compacted out)
    # at the end of this superstep
    retired_queries: tuple = ()
    # global query ids spliced in (admitted) at the end of this superstep —
    # their first compute superstep is the next one (DESIGN.md §13)
    admitted_queries: tuple = ()
    # global query ids force-retired mid-flight (session drain) at the end
    # of this superstep; their per-query supersteps stay -1
    drained_queries: tuple = ()
    # --- out-of-core vertex state (DESIGN.md §10; zeros when in-memory) ---
    vstate_faults: int = 0          # interval blocks decoded (warm + cold)
    vstate_load_bytes: int = 0      # compressed bytes faulted back in
    vstate_spill_bytes: int = 0     # compressed bytes written to the disk tier
    vstate_dirty_intervals: int = 0 # intervals written back (and broadcast)

    @property
    def stall_fraction(self) -> float:
        """Fraction of this superstep's wall time blocked on tile I/O."""
        return self.stall_seconds / self.seconds if self.seconds > 0 else 0.0

    @property
    def io_hidden_seconds(self) -> float:
        """I/O busy time overlapped behind compute instead of stalling it.
        ~0 for the serial engine by construction."""
        return max(self.io_busy_seconds - self.stall_seconds, 0.0)


@dataclasses.dataclass
class RunResult:
    """Final vertex values [V(, Q)] + aux arrays + per-superstep history of
    one engine run."""
    values: np.ndarray
    aux: dict
    history: list[SuperstepStats]
    supersteps: int
    converged: bool
    # multi-query runs: supersteps each query column took to converge
    # (index = global query id; -1 if it hit max_supersteps); None for 1-D
    per_query_supersteps: Optional[np.ndarray] = None

    def total_seconds(self) -> float:
        """Wall-clock sum over all supersteps."""
        return sum(h.seconds for h in self.history)

    def _steady_state(self, skip_first: bool) -> list[SuperstepStats]:
        """History minus the warm-up superstep — unless that would leave
        nothing to average (single-superstep runs fall back to the full
        history, an empty history to the empty list, never an empty slice
        fed to a mean/division)."""
        hs = self.history[1:] if skip_first else self.history
        return hs if hs else self.history

    def mean_superstep_seconds(self, skip_first: bool = True) -> float:
        """Steady-state mean seconds per superstep (see ``_steady_state``)."""
        hs = self._steady_state(skip_first)
        return float(np.mean([h.seconds for h in hs])) if hs else 0.0

    def disk_stall_fraction(self, skip_first: bool = True) -> float:
        """Fraction of wall time the compute loop was blocked on tile I/O."""
        hs = self._steady_state(skip_first)
        tot = sum(h.seconds for h in hs)
        return sum(h.stall_seconds for h in hs) / tot if tot > 0 else 0.0


class OutOfCoreEngine:
    """The out-of-core superstep engine (see module docstring).

    One instance either emulates all ``cfg.num_servers`` servers in-process
    (the classic mode) or — with ``cfg.server_rank`` set and a
    ``distributed.ClusterExchange`` passed as ``exchange`` — acts as one
    real server of a multi-process cluster, merging peer updates at the
    BSP barrier through the exchange (DESIGN.md §11).  Results are
    bit-identical either way: tiles own disjoint dst rows, the per-tile
    math is the same jitted gather/apply, and update value bytes
    round-trip the wire exactly."""

    def __init__(self, store: TileStore, config: EngineConfig = EngineConfig(),
                 exchange=None):
        self.store = store
        self.cfg = config
        self.exchange = exchange
        self.plan = store.load_plan()
        self.in_degree, self.out_degree = store.load_degrees()
        P, N = self.plan.num_tiles, config.num_servers
        if config.balanced_assignment:
            self.assignment = assign_tiles_balanced(self.plan.edges_per_tile, N)
        else:
            self.assignment = assign_tiles(P, N)
        # cluster mode: this process executes exactly one server's share
        if config.server_rank is not None:
            if not 0 <= config.server_rank < N:
                raise ValueError(
                    f"server_rank {config.server_rank} outside 0..{N - 1}")
            self.exec_servers = [config.server_rank]
        else:
            self.exec_servers = list(range(N))
        if exchange is not None and len(self.exec_servers) != 1:
            raise ValueError(
                "a ClusterExchange needs exactly one executed server per "
                "process — set cfg.server_rank (or num_servers=1)")

        # --- checkpointing + fault injection (DESIGN.md §12) ---
        #: per-process arm of cfg.fault_plan (None = no injection)
        self.fault = (config.fault_plan.injector(rank=config.server_rank)
                      if config.fault_plan is not None else None)
        #: the run's GraphCheckpointer (None = checkpointing disabled)
        self.ckpt: Optional[GraphCheckpointer] = None
        self._guard: Optional[PreemptionGuard] = None
        self.configure_checkpoint(config.checkpoint_dir)

        # Per-server edge caches (paper: idle memory on each server);
        # only the servers this process executes get one.
        if config.cache_mode == "auto":
            # Working set per server ~ share of total on-disk tile bytes.
            total = sum(store.tile_disk_bytes(t) for t in range(P))
            mode = auto_select_mode(total // max(N, 1), config.cache_capacity_bytes)
        else:
            mode = int(config.cache_mode)
        self.cache_mode = mode
        self.caches = {
            s: EdgeCache(store, config.cache_capacity_bytes, mode,
                         policy=config.cache_policy,
                         promote_hits=config.cache_promote_hits)
            for s in self.exec_servers
        }
        self._filters: Optional[list] = None  # built during first superstep
        self._stacks: Optional[dict] = None   # per-server device-resident tiles
        self._stack_fn = None
        # fused-kernel autotuning (DESIGN.md §14): memoized KernelChoice per
        # (combine, Q); ``kernel_choice`` holds the last resolved pick for
        # stats/CLI reporting
        self._kernel_choices: dict = {}
        self.kernel_choice = None
        self._streamed: dict[int, list[int]] = {s: [] for s in self.exec_servers}
        #: populated when cfg.debug_skip_log: one dict per (superstep, server)
        #: with the active source ids and the run/skipped tile partition
        self.skip_log: list[dict] = []
        self._wire_ratio: Optional[float] = None
        # Per-superstep deltas are computed against these cumulative-counter
        # baselines; run() re-baselines them at its start (a stale baseline
        # from a previous run / external cache activity would corrupt the
        # first superstep's deltas).
        self._io_busy_cum = 0.0   # cache io_seconds at end of last superstep
        self._promo_cum = 0       # cache promotions at end of last superstep

    # ------------------------------------------------------------------
    def kernel_plan(self, prog) -> tuple[str, Optional[tuple], int]:
        """Resolve ``(seg_impl, blocks, stack_size)`` for this program.

        With ``cfg.kernel_autotune`` the roofline cost model
        (roofline/kernel_tune.py) picks the Pallas ``(BE, BR)`` blocks and
        the pipelined stack size per ``(combine, Q, tile shape)`` —
        memoized, so the dry-run model runs once per program family — and
        ``seg_impl="jnp"`` is promoted to the fused kernel path.  An
        explicit ``cfg.kernel_blocks`` wins over the autotuner; without
        either, the kernels' static defaults apply (blocks=None).
        """
        cfg = self.cfg
        seg_impl = cfg.seg_impl
        if cfg.kernel_autotune and seg_impl == "jnp":
            seg_impl = "pallas_fused"
        stack_k = max(1, cfg.stack_size)
        if cfg.kernel_blocks is not None:
            return seg_impl, tuple(cfg.kernel_blocks), stack_k
        if not cfg.kernel_autotune:
            return seg_impl, None, stack_k
        q = int(getattr(prog, "num_queries", 1) or 1)
        key = (prog.combine, q)
        if key not in self._kernel_choices:
            from repro.roofline import kernel_tune

            self._kernel_choices[key] = kernel_tune.pick_blocks(
                prog.combine, q, self.plan.edge_cap, self.plan.row_cap)
        choice = self._kernel_choices[key]
        self.kernel_choice = choice
        return seg_impl, choice.blocks, choice.stack_size
        self._demo_cum = 0
        self._disk_cum = 0        # cache disk_bytes_read at last superstep
        # --- out-of-core vertex state (DESIGN.md §10) ---
        self._ooc = False
        #: the run's interval-sharded VertexStateStore (ooc mode only)
        self.vstate: Optional[VertexStateStore] = None
        self._iv_splitter: Optional[np.ndarray] = None
        self._iv_t2i: Optional[np.ndarray] = None
        self._use_meta_fp = False
        self._tile_iv_ids: dict[int, frozenset] = {}
        self._vs_faults_cum = 0
        self._vs_load_cum = 0
        self._vs_spill_cum = 0

    # ------------------------------------------------------------------
    # superstep checkpointing + crash-consistent resume (DESIGN.md §12)
    # ------------------------------------------------------------------
    def configure_checkpoint(self, directory: Optional[str]) -> None:
        """(Re)point the engine at a checkpoint directory — called from
        ``__init__`` and per program by the cluster server (multi-program
        launches use per-program subdirectories).

        With ``cfg.resume`` and an existing checkpoint, adopts the saved
        per-server tile assignment *now* (engine construction order needs
        the assignment before the ClusterExchange exists): verbatim when
        the saved server count matches ``cfg.num_servers``, else remapped
        through ``elastic.remap_assignment`` — the mid-run N->M elastic
        resize.  All ranks derive the identical assignment from the same
        replicated manifest."""
        if directory is None:
            self.ckpt = None
            return
        self.ckpt = GraphCheckpointer(directory, keep=self.cfg.checkpoint_keep,
                                      fault=self.fault)
        if not self.cfg.resume:
            return
        peek = self.ckpt.peek_manifest()
        if peek is None:
            return
        saved = peek[1].get("assignment")
        if not saved:
            return
        n = self.cfg.num_servers
        if len(saved) == n:
            self.assignment = [list(map(int, a)) for a in saved]
        else:
            self.assignment = remap_assignment(
                [list(map(int, a)) for a in saved], n,
                self.plan.edges_per_tile)

    def _save_final(self, values, aux_np, per_query_ss, converged,
                    supersteps: int) -> None:
        """Publish the run's result as a ``final`` checkpoint (step =
        supersteps + 1, strictly after every boundary save, so LATEST
        lands on it): a supervised restart then skips this program
        entirely instead of recomputing it."""
        manifest = dict(
            superstep=int(supersteps),
            final=True,
            converged=bool(converged),
            supersteps=int(supersteps),
            multi_q=per_query_ss is not None,
            num_servers=int(self.cfg.num_servers),
            assignment=[[int(t) for t in a] for a in self.assignment],
        )
        state: dict = {"values": values, "aux": aux_np}
        if per_query_ss is not None:
            state["per_query_ss"] = per_query_ss
        self.ckpt.save_graph(int(supersteps) + 1, state, manifest)

    @staticmethod
    def _result_from_final(loaded) -> RunResult:
        """RunResult reconstructed from a ``final`` checkpoint (resumed
        after the run already completed; history is gone — only the
        answers and convergence metadata persist)."""
        m, st = loaded.manifest, loaded.state
        pq = (np.asarray(st["per_query_ss"]) if "per_query_ss" in st
              else None)
        return RunResult(
            values=np.asarray(st["values"]),
            aux={k: np.asarray(v) for k, v in st.get("aux", {}).items()},
            history=[], supersteps=int(m.get("supersteps", m["superstep"])),
            converged=bool(m.get("converged", False)),
            per_query_supersteps=pq)

    # ------------------------------------------------------------------
    @staticmethod
    def _split_updates(rows, new, upd):
        """Per-tile (or per-server) update extraction, shape-polymorphic.

        rows [R] global vertex ids; new/upd [R] or [R, Qa].  Returns
        (vertex ids with any update, their value rows, per-query mask rows
        or None for 1-D runs)."""
        if upd.ndim == 2:
            vmask = upd.any(axis=1)
            return rows[vmask], new[vmask], upd[vmask]
        return rows[upd], new[upd], None

    def open_session(self, prog: VertexProgram, *,
                     q_slots: Optional[int] = None,
                     max_supersteps: Optional[int] = None) -> "EngineSession":
        """Open a step-driven session over ``prog`` (DESIGN.md §13).

        The session owns all per-run state; one ``session.step()`` call
        executes exactly one superstep, and between barriers the caller
        may ``admit()`` fresh queries into retired ``[V, Q]`` slots or
        ``drain()`` live ones.  ``q_slots`` caps the live query columns
        (default: the program's initial batch width); admissions beyond
        it queue until retirement frees a slot.  At most one ooc-vstate
        session may be live per engine at a time (sessions share the
        engine's edge caches, skip filters and interval bookkeeping)."""
        return EngineSession(self, prog, q_slots=q_slots,
                             max_supersteps=max_supersteps)

    def run(self, prog: VertexProgram,
            max_supersteps: Optional[int] = None) -> RunResult:
        """Run ``prog`` to convergence (no updated cells cluster-wide) or
        ``max_supersteps``.  Bit-identical across engine modes, cache
        policies, pipelining, ooc vertex state, cluster execution, and
        crash/resume (DESIGN.md §12: resuming a checkpoint replays the
        remaining supersteps to byte-identical values).

        A thin wrapper over ``open_session``: steps one EngineSession to
        completion (honoring ``cfg.admit_plan`` scripted admissions along
        the way) and returns its result — so batch callers and the online
        serving path (serve/graph_service.py) share one superstep loop.

        With ``cfg.preemptible`` + checkpointing, SIGTERM/SIGINT during
        the run latch a flag; at the next BSP barrier the engine saves a
        checkpoint and raises ``runtime.ft.Preempted``.  The prior signal
        handlers are always restored, even on exceptions."""
        guard = None
        if self.cfg.preemptible and self.ckpt is not None:
            guard = PreemptionGuard().install()
        self._guard = guard
        session = None
        try:
            session = self.open_session(prog, max_supersteps=max_supersteps)
            while not session.finished:
                session.step()
            return session.result()
        finally:
            if session is not None:
                session.close()
            if guard is not None:
                guard.restore()
            self._guard = None

    # ------------------------------------------------------------------
    def _measure_broadcast(self, si, sv, sm, nv, qa, dtype, background=False):
        """Build one server's broadcast payload and measure its wire size —
        inline (returns a BroadcastRecord) or on the comm executor
        (returns a Future resolving to one).  ``sm`` is the per-query
        updated mask for multi-query runs ([len(si), qa]) or None; the 2-D
        payload then covers only the ``qa`` still-active query columns.

        Ooc-vstate mode ships per-dirty-interval sections instead of one
        whole-V payload (DESIGN.md §10) — built straight from the sparse
        update lists, so no [V, Q]-sized buffer is ever densified."""
        cfg = self.cfg
        if self._ooc:
            plan = (comm.plan_broadcast_intervals_async if background
                    else comm.plan_broadcast_intervals)
            return plan(si, sv, sm, self._iv_splitter,
                        threshold=cfg.comm_threshold,
                        compressor=cfg.comm_compressor,
                        mode=cfg.comm_mode)
        if sm is not None:
            upd_mask = np.zeros((nv, qa), dtype=bool)
            upd_mask[si] = sm
        else:
            upd_mask = np.zeros(nv, dtype=bool)
            upd_mask[si] = True
        plan = comm.plan_broadcast_async if background else comm.plan_broadcast
        return plan(
            _densify(sv, si, nv, qa if sm is not None else None, dtype),
            upd_mask,
            threshold=cfg.comm_threshold,
            compressor=cfg.comm_compressor,
            mode=cfg.comm_mode,
        )

    # ------------------------------------------------------------------
    # pipelined path (cfg.pipeline): prefetch thread + batched dispatch
    # ------------------------------------------------------------------
    def _run_tiles_pipelined(self, s, tids, prog, values_dev, aux_dev,
                             filters, nv):
        """Overlapped tile processing for one server (DESIGN.md §7).

        A background thread reads + decompresses up to ``prefetch_depth``
        tiles ahead through the server's EdgeCache while the consumer
        stacks ``stack_size`` tiles and dispatches them as one jitted
        ``run_tile_stack`` call.  The consumer's queue-wait is the disk
        stall the pipeline failed to hide — reported per superstep.

        Returns ([indices], [values], [query masks], load_s, compute_s,
        stall_s) with results identical to the serial per-tile loop: tiles
        own disjoint row ranges and the per-tile math is the same jitted
        gather/apply.  The query-mask list is empty for 1-D runs.
        """
        from repro.core.distributed import pad_stack_to
        from repro.core.gab import run_tile_stack
        from repro.core.tiles import stack_tiles

        cfg = self.cfg
        if not tids:
            return [], [], [], 0.0, 0.0, 0.0
        if self._ooc:
            # ooc-vstate: the prefetcher still overlaps edge-tile reads with
            # compute, but tiles dispatch one at a time through the sharded
            # step (stacking would need the full [V] array on device)
            return self._run_tiles_pipelined_ooc(s, tids, prog, filters, nv)
        row_cap = self.plan.row_cap
        seg_impl, kblocks, stack_k = self.kernel_plan(prog)
        load_s = comp_s = stall_s = 0.0
        masked_acc = upd_acc = None
        batch: list = []

        def flush():
            nonlocal comp_s, masked_acc, upd_acc, batch
            stk = stack_tiles(batch, row_cap)
            if len(batch) < stack_k:
                stk = pad_stack_to(stk, stack_k)  # keep one compiled shape
            t0 = time.perf_counter()
            new_masked, upd = run_tile_stack(
                prog, values_dev, aux_dev, stk, row_cap, seg_impl, kblocks)
            if masked_acc is None:
                masked_acc, upd_acc = new_masked, upd
            else:  # disjoint row ranges: set-where-updated merge is exact
                masked_acc = jnp.where(upd, new_masked, masked_acc)
                upd_acc = jnp.logical_or(upd_acc, upd)
            comp_s += time.perf_counter() - t0
            batch = []

        it = self.store.prefetch_iter(tids, depth=cfg.prefetch_depth,
                                      cache=self.caches[s],
                                      workers=cfg.prefetch_workers)
        try:
            while True:
                t0 = time.perf_counter()
                try:
                    tid, tile = next(it)
                except StopIteration:
                    break
                wait = time.perf_counter() - t0
                load_s += wait
                stall_s += wait
                if filters is not None and filters[tid] is None:
                    filters[tid] = self._make_filter(tile, nv)
                batch.append(tile)
                if len(batch) == stack_k:
                    flush()
            if batch:
                flush()
        finally:
            it.close()

        si, sv, sm = self._split_updates(
            np.arange(values_dev.shape[0]), np.asarray(masked_acc),
            np.asarray(upd_acc))
        return [si], [sv], [] if sm is None else [sm], load_s, comp_s, stall_s

    # ------------------------------------------------------------------
    # stacked fast path (engine_mode="stacked"): device-resident tiles
    # ------------------------------------------------------------------
    def _build_stacks(self, nv: int) -> None:
        """Build the per-server device-resident tile stacks for
        ``engine_mode="stacked"`` — up to ``device_budget_bytes`` of tiles
        per server live on device; the rest stream per superstep."""
        from repro.core.tiles import stack_tiles

        budget = self.cfg.device_budget_bytes
        per_tile = self.plan.edge_cap * 12  # src+dst+val
        self._stacks = {}
        for s in self.exec_servers:
            fit = max(1, budget // per_tile)
            resident = self.assignment[s][:fit]
            self._streamed[s] = self.assignment[s][fit:]
            tiles = [self.caches[s].get(t) for t in resident]
            stk = stack_tiles(tiles, self.plan.row_cap)
            self._stacks[s] = {
                k: jnp.asarray(stk[k])
                for k in ("src", "dst_local", "val", "row_start", "num_rows")
            }

    def _build_merged(self, nv: int) -> None:
        """engine_mode="merged" (§Perf It5): per-server fused edge lists."""
        self._stacks = {}
        for s in self.exec_servers:
            self._streamed[s] = []
            srcs, dsts, vals = [], [], []
            owned = np.zeros(nv + 1, dtype=bool)
            for tid in self.assignment[s]:
                t = self.caches[s].get(tid)
                n = t.meta.num_edges
                srcs.append(t.src[:n])
                dsts.append(t.dst_local[:n].astype(np.int64) + t.meta.row_start)
                from repro.core.tiles import tile_edge_values
                vals.append(tile_edge_values(t)[:n])
                owned[t.meta.row_start: t.meta.row_end] = True
            self._stacks[s] = dict(
                src=jnp.asarray(np.concatenate(srcs).astype(np.int32)),
                dst=jnp.asarray(np.concatenate(dsts).astype(np.int32)),
                val=jnp.asarray(np.concatenate(vals)),
                owned=jnp.asarray(owned[:nv]),
            )

    def _merged_step(self, prog, values_dev, aux_dev, m):
        from repro.core.gab import merged_server_step

        if self._stack_fn is None:
            from functools import partial

            @partial(jax.jit, static_argnums=(0, 1, 2))
            def fn(p, seg_impl, blocks, values, aux, src, dst, val, owned):
                return merged_server_step(p, values, aux, src, dst, val,
                                          owned, seg_impl, blocks)

            self._stack_fn = fn
        seg_impl, kblocks, _ = self.kernel_plan(prog)
        return self._stack_fn(prog, seg_impl, kblocks, values_dev, aux_dev,
                              m["src"], m["dst"], m["val"], m["owned"])

    def _stack_step(self, prog, values_dev, aux_dev, stack):
        from repro.core.gab import stacked_tiles_step

        if self._stack_fn is None:
            from functools import partial

            row_cap = self.plan.row_cap

            @partial(jax.jit, static_argnums=(0, 3, 4))
            def fn(p, values, aux, seg_impl, blocks, stk):
                return stacked_tiles_step(p, values, aux, stk, row_cap,
                                          seg_impl, blocks)

            self._stack_fn = fn
        seg_impl, kblocks, _ = self.kernel_plan(prog)
        return self._stack_fn(prog, values_dev, aux_dev, seg_impl, kblocks,
                              stack)

    # ------------------------------------------------------------------
    def _make_filter(self, tile, nv):
        srcs = tile.source_ids()
        if self.cfg.skip_filter == "bitmap":
            f = SourceBlockBitmap(nv, self.cfg.block_shift)
        else:
            f = BloomFilter(num_bits=self.cfg.bloom_bits)
        f.add(srcs)
        return f

    def _order_cache_first(self, s: int, tids: list[int]) -> list[int]:
        """Cache-hit-first scheduling: resident tiles run immediately while
        the prefetcher pulls the misses from disk.  Stable within each
        class, and order never changes results (tiles own disjoint rows)."""
        cache = self.caches[s]
        resident = {t for t in tids if cache.contains(t)}
        if not resident or len(resident) == len(tids):
            return list(tids)
        return ([t for t in tids if t in resident]
                + [t for t in tids if t not in resident])

    # ------------------------------------------------------------------
    # out-of-core vertex state (DESIGN.md §10)
    # ------------------------------------------------------------------
    def _build_vstate(self, values: np.ndarray,
                      aux_np: dict) -> VertexStateStore:
        """Shard the freshly initialized [V(, Q)] arrays into an
        interval-sharded store under ``cfg.vertex_memory_budget``."""
        cfg = self.cfg
        stored = self.store.load_interval_plan()
        if cfg.num_intervals:
            k = cfg.num_intervals
        else:
            # auto: size intervals so ~4 blocks of the full per-vertex
            # state fit the budget — gather always has headroom to hold
            # the dst block plus several source blocks hot
            total = values.nbytes + sum(a.nbytes for a in aux_np.values())
            k = max(2, int(np.ceil(total / max(cfg.vertex_memory_budget / 4,
                                               1))))
        if stored is not None and (cfg.num_intervals == 0
                                   or stored.num_intervals == cfg.num_intervals):
            iv = stored   # honor the preprocessed plan: footprint metadata
        else:             # in the tile store refers to *its* boundaries
            iv = plan_intervals(self.plan.splitter, k)
        self._use_meta_fp = (stored is not None
                             and np.array_equal(iv.splitter, stored.splitter))
        self._iv_splitter = iv.splitter
        self._iv_t2i = iv.tile_to_interval
        self._tile_iv_ids = {}
        spill_dir = tempfile.mkdtemp(prefix="_vstate_", dir=self.store.root)
        vstore = VertexStateStore(iv.splitter, cfg.vertex_memory_budget,
                                  spill_dir)
        self.vstate = vstore
        vstore.add_array("value", values)
        for name, arr in aux_np.items():
            vstore.add_array(name, arr)
        return vstore

    def _tile_footprint(self, tile):
        """(interval ids, cumulative edge ptr, bucket-sort permutation) for
        one tile — from the tile's recorded metadata when the store was
        preprocessed with this interval plan, else computed on the fly."""
        m = tile.meta
        if (self._use_meta_fp and m.src_intervals is not None
                and tile.iv_perm is not None):
            ids, ptr, perm = m.src_intervals, m.src_interval_ptr, tile.iv_perm
        else:
            ids, ptr, perm = compute_source_footprint(
                tile.src, m.num_edges, self._iv_splitter)
        # remember the joint footprint (src intervals + dst interval) for
        # the co-scheduler; tiny (a frozenset of ints per tile)
        self._tile_iv_ids[m.tile_id] = (
            frozenset(ids) | {int(self._iv_t2i[m.tile_id])})
        return ids, ptr, perm

    def _ooc_tile_step(self, prog, tile, nv):
        """One tile's Gather+Apply against the interval-sharded vertex
        state: materialize per-edge source inputs interval by interval,
        slice the dst rows from the tile's own interval block, dispatch the
        jitted sharded step.  Returns the same (ids, values, query-mask)
        update triple as the in-memory path — bit-identical (see
        gab.tile_gather_apply_sharded)."""
        vstore = self.vstate
        m = tile.meta
        row_cap = self.plan.row_cap
        ids, ptr, perm = self._tile_footprint(tile)
        names = ("value",) + tuple(prog.src_aux)
        bufs = {}
        for name in names:
            dt, tail = vstore.spec(name)
            bufs[name] = np.zeros((m.edge_cap,) + tail, dt)
        src = tile.src
        for j, iv in enumerate(ids):
            sl = perm[ptr[j]: ptr[j + 1]]
            lo, _hi = vstore.interval_range(int(iv))
            local = src[sl] - lo
            for name in names:
                bufs[name][sl] = vstore.get_block(name, int(iv))[local]
        ivd = int(self._iv_t2i[m.tile_id])
        lo_d, _hi_d = vstore.interval_range(ivd)
        r0, r1 = m.row_start - lo_d, m.row_end - lo_d
        vdt, vtail = vstore.spec("value")
        old = np.zeros((row_cap,) + vtail, vdt)
        old[: m.num_rows] = vstore.get_block("value", ivd)[r0:r1]
        dst_aux = {}
        for name in prog.dst_aux:
            dt, tail = vstore.spec(name)
            buf = np.zeros((row_cap,) + tail, dt)
            buf[: m.num_rows] = vstore.get_block(name, ivd)[r0:r1]
            dst_aux[name] = buf
        seg_impl, kblocks, _ = self.kernel_plan(prog)
        new, upd = run_tile_sharded(
            prog, bufs["value"], {k: bufs[k] for k in prog.src_aux},
            tile_edge_values(tile), tile.dst_local, old, dst_aux,
            m.num_rows, row_cap, seg_impl, kblocks)
        rows = np.minimum(m.row_start + np.arange(row_cap), nv - 1)
        return self._split_updates(rows, np.asarray(new), np.asarray(upd))

    def _ooc_column(self, vstore: VertexStateStore, c: int) -> np.ndarray:
        """Assemble one query column of the sharded value array."""
        return np.concatenate(
            [vstore.get_block("value", k)[:, c]
             for k in range(vstore.num_intervals)])

    def _run_tiles_pipelined_ooc(self, s, tids, prog, filters, nv):
        cfg = self.cfg
        load_s = comp_s = stall_s = 0.0
        s_idx: list = []
        s_val: list = []
        s_msk: list = []
        it = self.store.prefetch_iter(tids, depth=cfg.prefetch_depth,
                                      cache=self.caches[s],
                                      workers=cfg.prefetch_workers)
        try:
            while True:
                t0 = time.perf_counter()
                try:
                    tid, tile = next(it)
                except StopIteration:
                    break
                wait = time.perf_counter() - t0
                load_s += wait
                stall_s += wait
                if filters is not None and filters[tid] is None:
                    filters[tid] = self._make_filter(tile, nv)
                t0 = time.perf_counter()
                ri, rv, rm = self._ooc_tile_step(prog, tile, nv)
                comp_s += time.perf_counter() - t0
                s_idx.append(ri)
                s_val.append(rv)
                if rm is not None:
                    s_msk.append(rm)
        finally:
            it.close()
        return s_idx, s_val, s_msk, load_s, comp_s, stall_s

    def _order_joint_residency(self, s: int, tids: list[int]) -> list[int]:
        """Interval-aware co-scheduling (DESIGN.md §10): greedily pick the
        tile whose joint footprint (source intervals + dst interval)
        overlaps most with a simulated LRU set of hot vertex intervals,
        breaking ties toward edge-cache-resident tiles — maximizing joint
        residency of the edge cache and the vertex-state hot tier.  Order
        never changes results (disjoint rows, BSP barrier).  Falls back to
        cache-hit-first while footprints are unknown (superstep 0)."""
        fps = self._tile_iv_ids
        if any(t not in fps for t in tids):
            return self._order_cache_first(s, tids)
        if len(tids) > 256:
            # the greedy below is O(T^2); past a few hundred tiles its
            # Python cost rivals the tile compute, and its behaviour on
            # locality-structured inputs is a contiguous sweep starting
            # from the hot end anyway — compute that sweep directly
            return self._order_interval_sweep(tids)
        cache = self.caches[s]
        cap = max(1, self.vstate.hot_block_capacity("value"))
        sim: OrderedDict[int, None] = OrderedDict(
            (k, None) for k in sorted(self.vstate.hot_intervals("value")))
        edge_res = {t for t in tids if cache.contains(t)}
        ivd = {t: int(self._iv_t2i[t]) for t in tids}
        last: Optional[int] = None
        remaining = list(tids)
        order: list[int] = []
        while remaining:
            best, best_score = None, None
            for t in remaining:
                # hot-source-interval overlap first; then stay near the
                # previous pick's dst interval so the walk sweeps
                # contiguously instead of thrashing on overlap ties (a
                # contiguous sweep is what keeps the fault count at
                # ~K - cap per pass); edge-cache residency breaks what
                # remains — ranked below the sweep because letting
                # scattered resident edge tiles pull the walk around
                # costs more vertex faults than it saves edge decodes
                score = (len(fps[t] & sim.keys()),
                         -abs(ivd[t] - last) if last is not None else 0,
                         t in edge_res)
                if best_score is None or score > best_score:
                    best, best_score = t, score
            order.append(best)
            remaining.remove(best)
            last = ivd[best]
            for ivk in sorted(fps[best]):
                sim.pop(ivk, None)
                sim[ivk] = None
            while len(sim) > cap:
                sim.popitem(last=False)
        return order

    def _order_interval_sweep(self, tids: list[int]) -> list[int]:
        """O(T log T) large-fleet fallback for the co-scheduler: sort tiles
        by dst interval and run the sweep toward the end *away* from the
        currently-hot intervals, so the walk starts where residency is and
        alternating supersteps sweep boustrophedon instead of rewinding to
        vertex 0 against the LRU."""
        hot = self.vstate.hot_intervals("value")
        order = sorted(tids, key=lambda t: int(self._iv_t2i[t]))
        if not hot:
            return order
        mid = (self._iv_t2i[order[0]] + self._iv_t2i[order[-1]]) / 2.0
        if np.mean(sorted(hot)) > mid:   # hot mass sits at the high end
            order.reverse()              # -> start there, sweep downward
        return order

    def _agg_cache_stats(self) -> dict:
        """Aggregate hit/miss/tier/io counters over the edge caches this
        process executes (all servers classically; one in cluster mode)."""
        caches = list(self.caches.values())
        hits = sum(c.stats.hits for c in caches)
        misses = sum(c.stats.misses for c in caches)
        tiers: dict[str, dict] = {}
        for c in caches:
            for name, d in c.tier_snapshot().items():
                agg = tiers.setdefault(name, dict(tiles=0, bytes=0, hits=0))
                agg["tiles"] += d.get("tiles", 0)
                agg["bytes"] += d.get("bytes", 0)
                agg["hits"] += d.get("hits", 0)
        return dict(
            hit_ratio=hits / max(hits + misses, 1),
            disk_bytes_read=sum(c.stats.disk_bytes_read for c in caches),
            io_seconds=sum(c.stats.disk_seconds + c.stats.decompress_seconds
                           + c.stats.retier_seconds for c in caches),
            promotions=sum(c.stats.promotions for c in caches),
            demotions=sum(c.stats.demotions for c in caches),
            tiers=tiers,
        )


def _densify(vals: np.ndarray, idx: np.ndarray, nv: int,
             nq: Optional[int], dtype) -> np.ndarray:
    out = np.zeros((nv, nq) if nq is not None else nv, dtype=dtype)
    out[idx] = vals
    return out


class EngineSession:
    """Step-driven run state over one :class:`OutOfCoreEngine` (DESIGN.md
    §13).

    One ``step()`` call executes exactly one superstep — compute, BSP
    barrier, update apply, query retirement — and between barriers the
    session accepts **mid-run query admission**: ``admit(seeds)`` queues
    fresh queries that are spliced into retired ``[V, Q]`` columns at the
    next barrier (the inverse of retirement's column compaction), and
    ``drain(qids)`` force-retires live columns.  ``run()`` is a thin loop
    over a session, so batch runs and the online serving path
    (serve/graph_service.py) share this superstep implementation.

    State machine: OPEN --step()*--> FINISHED --result()--> closed.  A
    session is FINISHED when it converged with no admission backlog, or
    hit ``max_supersteps``.  ``result()`` finalizes (flushes live columns,
    closes the ooc spill tier, publishes the final checkpoint) and returns
    the same :class:`RunResult` the monolithic loop used to.

    Admission protocol (all execution modes apply it at the same point in
    the barrier, so results stay bit-identical across them):

    1. natural retirement — columns with zero updated cells freeze into
       the result buffer and compact out;
    2. drains — force-frozen columns (``per_query_supersteps`` stays -1);
    3. admissions — fresh columns splice into ``values``/per-query aux/
       ``active_q`` with state built by ``prog.with_queries(seeds).init``,
       and the next superstep runs **all** tiles (``_force_full``) so skip
       filters and interval dirty tracking see the new column as all-dirty
       for one superstep (filters have no false negatives, so forcing a
       full pass is always safe).

    Cluster mode: rank 0 collects the admission/drain record *before* the
    exchange and ships it in its update frame (``transport.encode_frame``
    ``control=``); every rank — including rank 0 — then applies the record
    it reads back from ``ExchangeResult.control``, so all ranks splice
    identically.  Peers follow deterministically and must not ``admit()``
    themselves.

    Thread-safety: ``admit()``/``drain()`` may be called from any thread
    (the service's submit path); ``step()``/``result()`` must be called
    from one driver thread.
    """

    #: lock discipline, enforced by tools/analyze.py --check locks
    #: (admission/drain queues are filled by the serving thread while the
    #: driver thread splices them at the barrier)
    _guarded_by = {"_admit_queue": "_lock", "_drain_queue": "_lock",
                   "next_qid": "_lock"}

    def __init__(self, engine: OutOfCoreEngine, prog: VertexProgram, *,
                 q_slots: Optional[int] = None,
                 max_supersteps: Optional[int] = None):
        self.eng = engine
        self.prog = prog
        cfg = engine.cfg
        nv = self.nv = engine.plan.num_vertices
        self._lock = threading.Lock()
        self._admit_queue: list[tuple[int, int]] = []
        self._drain_queue: list[int] = []
        self._force_full = False
        self._final_result: Optional[RunResult] = None
        self._closed = False
        self.history: list[SuperstepStats] = []
        self.converged = False
        self.finished = False
        self.vstore: Optional[VertexStateStore] = None
        self._ooc = False

        # Re-baseline the engine's cumulative-counter deltas: a second
        # session on the same engine — or cache activity between sessions
        # (warm()/maintain()/direct get()s) — must not leak into this
        # session's first superstep.
        cs = engine._agg_cache_stats()
        engine._io_busy_cum = cs["io_seconds"]
        engine._promo_cum = cs["promotions"]
        engine._demo_cum = cs["demotions"]
        engine._disk_cum = cs["disk_bytes_read"]

        state = prog.init(nv, engine.out_degree.astype(np.float64),
                          engine.in_degree.astype(np.float64))
        self.values = np.asarray(state.pop("value"))
        self.aux_np = {k: np.asarray(v) for k, v in state.items()}
        self.vdtype = self.values.dtype

        # --- multi-query bookkeeping (DESIGN.md §9) ---
        # values [V, Q]: Q program instances share every tile visit.  A
        # query column that produces zero updates in a superstep has
        # reached its fixpoint; it is *retired* — its column is written to
        # the result buffer and compacted out so later supersteps no
        # longer pay for it.  The freed slot is what admission refills.
        self.multi_q = self.values.ndim == 2
        self.nq_total = self.values.shape[1] if self.multi_q else 1
        self.active_q = np.arange(self.nq_total)  # global ids, live columns
        self.final_values = self.values.copy() if self.multi_q else None
        self.per_query_ss = (np.full(self.nq_total, -1, dtype=np.int64)
                             if self.multi_q else None)
        #: superstep each column's compute began at (0 for initial
        #: queries) — per_query_ss is convergence superstep RELATIVE to
        #: this, so an admitted query reports the same count as a fresh run
        self.admitted_at = (np.zeros(self.nq_total, dtype=np.int64)
                            if self.multi_q else None)
        #: {global qid: seed vertex} lineage for every column ever admitted
        self.query_seeds: dict[int, int] = {
            int(i): int(s)
            for i, s in enumerate(getattr(prog, "queries", ()))}
        self.next_qid = self.nq_total if self.multi_q else 1
        self.q_slots = (max(1, int(q_slots)) if q_slots is not None
                        else max(1, self.nq_total))
        self._plan_pending: list[tuple[int, tuple]] = (
            [(int(after), tuple(int(s) for s in seeds))
             for after, seeds in (cfg.admit_plan or ())]
            if self.multi_q else [])

        # --- crash-consistent resume (DESIGN.md §12): overwrite the fresh
        # init with the latest checkpoint's state and continue from its
        # superstep boundary.  A "final" checkpoint short-circuits: the
        # run already completed — the session opens FINISHED with its
        # stored result (supervised restarts skip finished programs).
        self.start_ss = 0
        loaded = None
        if engine.ckpt is not None and cfg.resume:
            loaded = engine.ckpt.load_graph()
        if loaded is not None and loaded.manifest.get("final"):
            self._final_result = engine._result_from_final(loaded)
            self.converged = self._final_result.converged
            self.finished = True
            return
        if loaded is not None:
            m, st = loaded.manifest, loaded.state
            self.start_ss = int(m["superstep"])
            if loaded.vstate:
                self.values = np.asarray(loaded.vstate["value"])
                self.aux_np = {k: np.asarray(v)
                               for k, v in loaded.vstate.items()
                               if k != "value"}
            else:
                self.values = np.asarray(st["values"])
                self.aux_np = {k: np.asarray(v)
                               for k, v in st.get("aux", {}).items()}
            if self.multi_q:
                self.active_q = np.asarray(m["active_q"], dtype=np.int64)
                self.final_values = np.asarray(st["final_values"])
                self.per_query_ss = np.asarray(st["per_query_ss"], np.int64)
                self.nq_total = len(self.per_query_ss)
                self.admitted_at = (
                    np.asarray(st["admitted_at"], np.int64)
                    if "admitted_at" in st
                    else np.zeros(self.nq_total, dtype=np.int64))
                self.next_qid = int(m.get("next_qid", self.nq_total))
                saved_seeds = {int(g): int(s)
                               for g, s in m.get("queries", {}).items()}
                if saved_seeds:
                    self.query_seeds = saved_seeds
                # plan entries that fired before the boundary are already
                # in the restored state — replay only the future ones
                self._plan_pending = [e for e in self._plan_pending
                                      if e[0] >= self.start_ss]

        # --- out-of-core vertex state (DESIGN.md §10): with a vertex
        # memory budget the [V(, Q)] arrays move into an interval-sharded
        # VertexStateStore and the full arrays are dropped.  stacked/
        # merged need the full value array on device, so ooc forces tiled.
        self._ooc = engine._ooc = cfg.vertex_memory_budget is not None
        self.engine_mode = "tiled" if self._ooc else cfg.engine_mode
        if self._ooc:
            self.vstore = engine._build_vstate(self.values, self.aux_np)
            engine._vs_faults_cum = self.vstore.stats.faults
            engine._vs_load_cum = self.vstore.stats.load_bytes
            engine._vs_spill_cum = self.vstore.stats.spill_bytes
            self.values = None
            self.aux_np = {}
            self.aux_dev = None
        else:
            self.aux_dev = {k: jnp.asarray(v) for k, v in self.aux_np.items()}

        self.max_ss = max_supersteps or cfg.max_supersteps
        self.updated_ids = np.arange(nv)  # everything "updated" pre step 0
        if loaded is not None:
            # the skip pre-pass keys off the last superstep's update set —
            # part of the boundary state (filters are rebuilt lazily; they
            # have no false negatives, so a missing filter only costs work)
            self.updated_ids = np.asarray(loaded.state["updated_ids"],
                                          np.int64)
        self.building_filters = cfg.tile_skipping
        self.filters: list = ([None] * engine.plan.num_tiles
                              if self.building_filters else [])

    # -- public session surface --------------------------------------------
    @property
    def superstep(self) -> int:
        """Index of the next superstep ``step()`` will execute."""
        return self._ss if hasattr(self, "_ss") else self.start_ss

    @property
    def active_queries(self) -> tuple[int, ...]:
        """Global qids of the currently live query columns."""
        return tuple(int(g) for g in self.active_q) if self.multi_q else ()

    @property
    def free_slots(self) -> int:
        """Query slots available for admission right now."""
        if not self.multi_q:
            return 0
        with self._lock:
            queued = len(self._admit_queue)
        return max(0, self.q_slots - len(self.active_q) - queued)

    def admit(self, seeds) -> list[int]:
        """Queue fresh queries (seed vertices) for admission at the next
        barrier; returns their global qids.  Thread-safe.  Queries beyond
        the free ``q_slots`` stay queued until retirement frees slots.
        Cluster mode: rank 0 only (peers follow the control record)."""
        if not self.multi_q:
            raise RuntimeError("admission needs a batched [V, Q] program")
        if (self.eng.exchange is not None
                and getattr(self.eng.exchange, "rank", 0) != 0):
            raise RuntimeError("cluster admissions originate at rank 0 — "
                               "peers splice from the control record")
        if self.finished:
            raise RuntimeError("session is finished")
        with self._lock:
            gqs = []
            for s in seeds:
                g = self.next_qid
                self.next_qid += 1
                self._admit_queue.append((g, int(s)))
                gqs.append(g)
        return gqs

    def drain(self, qids) -> None:
        """Force-retire live columns at the next barrier: their partial
        values freeze into the result buffer and ``per_query_supersteps``
        stays -1 (deadline misses in the serving path).  Thread-safe."""
        with self._lock:
            self._drain_queue.extend(int(g) for g in qids)

    def query_result(self, gq: int) -> np.ndarray:
        """The frozen [V] column of query ``gq`` — valid once it retired
        (or drained); before that it holds the admission-time state."""
        return np.asarray(self.final_values[:, int(gq)]).copy()

    def query_supersteps(self, gq: int) -> int:
        """Supersteps query ``gq`` took to converge, counted from its own
        admission (== a fresh single-query run's count); -1 while live or
        if it was drained."""
        return int(self.per_query_ss[int(gq)])

    def checkpoint(self) -> None:
        """Save a resumable boundary checkpoint of the session right now
        (manifest carries the per-slot query lineage, so a serving session
        resumes with renumbering and accounting intact)."""
        if self.eng.ckpt is None:
            raise RuntimeError("engine has no checkpoint directory")
        self._save_boundary(self.superstep - 1)

    def close(self) -> None:
        """Release per-run scratch (the ooc spill tier).  Idempotent;
        ``result()`` already closed the store on the normal path."""
        if self._closed:
            return
        self._closed = True
        if self.vstore is not None and self._final_result is None:
            self.vstore.close()

    # -- the superstep ------------------------------------------------------
    def step(self) -> SuperstepStats:
        """Execute exactly one superstep (compute → barrier → apply →
        retirement → drains → admissions) and return its stats.  Raises
        ``runtime.ft.Preempted`` after a preemption checkpoint when the
        engine's guard latched a signal."""
        if self.finished:
            raise RuntimeError("session is finished — open a new one")
        eng = self.eng
        cfg = eng.cfg
        prog = self.prog
        nv = self.nv
        ooc = self._ooc
        multi_q = self.multi_q
        vstore = self.vstore
        vdtype = self.vdtype
        row_cap = eng.plan.row_cap
        filters = self.filters
        building_filters = self.building_filters
        ss = self._ss = getattr(self, "_ss", self.start_ss)

        if eng.fault is not None:
            eng.fault.check("superstep", ss)
        t_start = time.perf_counter()
        qa = len(self.active_q) if multi_q else 1  # live columns this step
        # a batched session with zero live columns still steps (waiting on
        # scheduled/queued admissions): no compute, but the barrier — and
        # in cluster mode the exchange carrying the control record — runs
        run_compute = not (multi_q and qa == 0)
        values_dev = (None if (ooc or not run_compute)
                      else jnp.asarray(self.values))
        load_s = 0.0
        comp_s = 0.0
        stall_s = 0.0
        tiles_done = 0
        tiles_skipped = 0
        upd_idx_parts: list[np.ndarray] = []
        upd_val_parts: list[np.ndarray] = []
        upd_msk_parts: list[np.ndarray] = []
        per_server_updates: list[tuple] = []
        bcast_futures: dict[int, object] = {}
        # ooc-vstate always measures: the sampled estimator models a
        # whole-V payload (global density switch, no interval headers),
        # which would mix incompatible models with the per-interval
        # records the sampled supersteps learn their ratio from
        sample = ooc or not (cfg.comm_accounting == "sampled"
                             and ss % 4 != 0
                             and eng._wire_ratio is not None)

        # a column admitted at the previous barrier must be treated as
        # all-dirty for one superstep: run every tile once (filters have
        # no false negatives, so a full pass can only do extra work,
        # never change results), then fall back to skip filters
        force_full = self._force_full
        self._force_full = False
        skip_on = (
            cfg.tile_skipping
            and ss > 0
            and not force_full
            and len(self.updated_ids) < cfg.skip_density_threshold * nv
            and eng._filters is not None
        )
        active_words = None
        if skip_on and cfg.skip_filter == "bitmap":
            active_words = SourceBlockBitmap.active_words_from_ids(
                self.updated_ids, nv, cfg.block_shift
            )

        for s in (eng.exec_servers if run_compute else ()):
            s_idx: list[np.ndarray] = []
            s_val: list[np.ndarray] = []
            s_msk: list[np.ndarray] = []
            server_tiles = eng.assignment[s]
            if self.engine_mode in ("stacked", "merged") and not skip_on:
                if eng._stacks is None:
                    t0 = time.perf_counter()
                    if self.engine_mode == "merged":
                        eng._build_merged(nv)
                    else:
                        eng._build_stacks(nv)
                    if building_filters:
                        for st in eng.exec_servers:
                            n_res = (len(eng.assignment[st])
                                     - len(eng._streamed[st]))
                            for tid in eng.assignment[st][:n_res]:
                                if filters[tid] is None:
                                    filters[tid] = eng._make_filter(
                                        eng.caches[st].get(tid), nv)
                    load_s += time.perf_counter() - t0
                t0 = time.perf_counter()
                step_fn = (eng._merged_step if self.engine_mode == "merged"
                           else eng._stack_step)
                new_masked, upd = step_fn(prog, values_dev, self.aux_dev,
                                          eng._stacks[s])
                si, sv, sm = eng._split_updates(
                    np.arange(nv), np.asarray(new_masked), np.asarray(upd))
                comp_s += time.perf_counter() - t0
                s_idx.append(si)
                s_val.append(sv.astype(vdtype))
                if sm is not None:
                    s_msk.append(sm)
                tiles_done += len(eng.assignment[s]) - len(eng._streamed[s])
                server_tiles = eng._streamed[s]

            # Tile-skipping pre-pass: the filter set is fixed for the
            # whole superstep, so the survivor list can be computed up
            # front (and handed to the prefetcher in pipelined mode).
            if skip_on:
                run_list = []
                for tid in server_tiles:
                    f = eng._filters[tid]
                    # a stolen tile may not have a filter yet on this
                    # server (cluster mode) — run it, never skip blind
                    hit = f is None or (
                        f.intersects(active_words)
                        if cfg.skip_filter == "bitmap"
                        else f.might_contain_any(self.updated_ids)
                    )
                    if hit:
                        run_list.append(tid)
                    else:
                        tiles_skipped += 1
                if cfg.debug_skip_log:
                    eng.skip_log.append(dict(
                        superstep=ss, server=s,
                        active=np.asarray(self.updated_ids).copy(),
                        run=list(run_list),
                        skipped=[t for t in server_tiles
                                 if t not in run_list]))
            else:
                run_list = list(server_tiles)

            if ooc and cfg.interval_aware_order and len(run_list) > 1:
                run_list = eng._order_joint_residency(s, run_list)
            elif cfg.cache_aware_order and len(run_list) > 1:
                run_list = eng._order_cache_first(s, run_list)

            if cfg.pipeline:
                p_idx, p_val, p_msk, ld, cp, stl = eng._run_tiles_pipelined(
                    s, run_list, prog, values_dev, self.aux_dev,
                    filters if building_filters else None, nv)
                s_idx += p_idx
                s_val += p_val
                s_msk += p_msk
                load_s += ld
                comp_s += cp
                stall_s += stl
                tiles_done += len(run_list)
            else:
                for tid in run_list:
                    t0 = time.perf_counter()
                    tile = eng.caches[s].get(tid)
                    dt = time.perf_counter() - t0
                    load_s += dt
                    stall_s += dt   # serial: every load blocks compute

                    if building_filters and filters[tid] is None:
                        filters[tid] = eng._make_filter(tile, nv)

                    t0 = time.perf_counter()
                    if ooc:
                        ri, rv, rm = eng._ooc_tile_step(prog, tile, nv)
                    else:
                        seg_impl, kblocks, _ = eng.kernel_plan(prog)
                        rows, new, upd = run_tile(
                            prog, values_dev, self.aux_dev,
                            (tile.src, tile.dst_local,
                             tile_edge_values(tile)),
                            tile.meta.row_start, tile.meta.num_rows,
                            row_cap, seg_impl, kblocks,
                        )
                        ri, rv, rm = eng._split_updates(
                            np.asarray(rows), np.asarray(new),
                            np.asarray(upd))
                    comp_s += time.perf_counter() - t0
                    s_idx.append(ri)
                    s_val.append(rv)
                    if rm is not None:
                        s_msk.append(rm)
                    tiles_done += 1
            si = np.concatenate(s_idx) if s_idx else np.zeros(0, np.int64)
            val_shape = (0, qa) if multi_q else (0,)
            sv = (np.concatenate(s_val) if s_val
                  else np.zeros(val_shape, vdtype))
            sm = None
            if multi_q:
                sm = (np.concatenate(s_msk) if s_msk
                      else np.zeros(val_shape, dtype=bool))
            per_server_updates.append((si, sv, sm))
            upd_idx_parts.append(si)
            upd_val_parts.append(sv)
            if multi_q:
                upd_msk_parts.append(sm)
            if cfg.pipeline and sample and eng.exchange is None:
                # overlap this server's payload compression with the next
                # server's compute; records collected at the barrier below
                # (cluster mode measures from the real transport instead)
                bcast_futures[s] = eng._measure_broadcast(
                    si, sv, sm, nv, qa, vdtype, background=True)
        if not run_compute:
            for _ in eng.exec_servers:
                per_server_updates.append((np.zeros(0, np.int64),
                                           np.zeros((0, qa), vdtype),
                                           np.zeros((0, qa), dtype=bool)))

        own_tiles = [t for s in eng.exec_servers
                     for t in eng.assignment[s]]
        if building_filters and all(filters[t] is not None
                                    for t in own_tiles):
            eng._filters = filters
            self.building_filters = False

        # --- Broadcast (BSP barrier): measure payloads, apply updates ---
        if eng.fault is not None:
            eng.fault.check("barrier", ss)
        raw_b = wire_b = 0
        control = None
        if eng.exchange is not None:
            # cluster mode (DESIGN.md §11): ship this server's updates
            # through the real transport, merge every peer's frame — the
            # exchange IS the global barrier, and the byte counts are
            # measured from the frames that actually travelled.  Rank 0
            # collects the admission/drain record pre-exchange (it must
            # ride its frame); every rank applies the record it reads
            # back below, after natural retirement.
            if eng.exchange.rank == 0:
                control = self._collect_control(
                    ss, qa, set(self.active_queries), set())
            si, sv, sm = per_server_updates[0]
            xr = eng.exchange.exchange(
                idx=si, vals=sv, mask=sm, nv=nv,
                splitter=eng._iv_splitter if ooc else None,
                compute_seconds=comp_s, control=control)
            control = xr.control
            all_idx, all_val, all_msk = xr.idx, xr.vals, xr.mask
            raw_b, wire_b = xr.raw_bytes, xr.wire_bytes
            if xr.assignment is not None:
                # cross-server tile stealing: every server derived the
                # same new ownership from the same replicated timings
                eng.assignment = [list(a) for a in xr.assignment]
        else:
            for k, s in enumerate(eng.exec_servers):
                if not run_compute:
                    break
                si, sv, sm = per_server_updates[k]
                if sample:
                    if s in bcast_futures:
                        rec = bcast_futures[s].result()
                    else:
                        rec = eng._measure_broadcast(si, sv, sm, nv, qa,
                                                     vdtype)
                    raw_b += rec.raw_bytes
                    wire_b += rec.wire_bytes
                else:
                    pairs = int(sm.sum()) if sm is not None else len(si)
                    n_eff = nv * qa
                    est = comm.wire_bytes_estimate(
                        n_eff, pairs / max(n_eff, 1),
                        # 2-D sparse payloads pack (vertex, query) u32 pairs
                        index_bytes=8 if sm is not None else 4)
                    raw_b += est
                    wire_b += int(est * eng._wire_ratio)
            if sample and raw_b:
                eng._wire_ratio = wire_b / raw_b
            all_idx = (np.concatenate(upd_idx_parts) if upd_idx_parts
                       else np.zeros(0, np.int64))
            all_val = (np.concatenate(upd_val_parts) if upd_val_parts
                       else np.zeros((0, qa) if multi_q else (0,), vdtype))
            all_msk = None
            if multi_q:
                all_msk = (np.concatenate(upd_msk_parts) if upd_msk_parts
                           else np.zeros((0, qa), dtype=bool))
        if multi_q:
            upd_per_q = all_msk.sum(axis=0)
            updated_pairs = int(all_msk.sum())
        else:
            upd_per_q = None
            updated_pairs = int(len(all_idx))
        dirty_ivs = 0
        if ooc:
            # dirty-interval writeback (DESIGN.md §10): load only the
            # interval blocks that received updates, apply in place,
            # write back dirty — clean intervals are never touched.
            if len(all_idx):
                ivs = vstore.interval_of(all_idx)
                for iv in np.unique(ivs):
                    ksel = ivs == iv
                    lo, _hi = vstore.interval_range(int(iv))
                    blk = vstore.get_block("value", int(iv)).copy()
                    loc = all_idx[ksel] - lo
                    if multi_q:
                        # per-cell application: a row touched by query A
                        # must not clobber query B's untouched column
                        cur = blk[loc]
                        msk = all_msk[ksel]
                        cur[msk] = all_val[ksel][msk]
                        blk[loc] = cur
                    else:
                        blk[loc] = all_val[ksel]
                    vstore.write_block("value", int(iv), blk)
                    dirty_ivs += 1
        elif multi_q:
            # per-cell application: a row touched by query A must not
            # clobber query B's column with a masked zero / sub-tol value
            cur = self.values[all_idx]
            cur[all_msk] = all_val[all_msk]
            self.values[all_idx] = cur
        else:
            self.values[all_idx] = all_val
        self.updated_ids = all_idx

        # Re-tier at the barrier: off the tile hot path, after this
        # superstep's access pattern has updated the per-tile counters.
        if cfg.cache_policy != "lru":
            for c in eng.caches.values():
                c.maintain()

        cache_stats = eng._agg_cache_stats()
        io_busy = cache_stats["io_seconds"] - eng._io_busy_cum
        eng._io_busy_cum = cache_stats["io_seconds"]
        promo = cache_stats["promotions"] - eng._promo_cum
        demo = cache_stats["demotions"] - eng._demo_cum
        eng._promo_cum = cache_stats["promotions"]
        eng._demo_cum = cache_stats["demotions"]
        # the cache counter is cumulative over the run; the stat is the
        # per-superstep delta (like io_busy/promotions above)
        disk_b = cache_stats["disk_bytes_read"] - eng._disk_cum
        eng._disk_cum = cache_stats["disk_bytes_read"]
        vs_faults = vs_load = vs_spill = 0
        if ooc:
            vst = vstore.stats
            vs_faults = vst.faults - eng._vs_faults_cum
            vs_load = vst.load_bytes - eng._vs_load_cum
            vs_spill = vst.spill_bytes - eng._vs_spill_cum
            eng._vs_faults_cum = vst.faults
            eng._vs_load_cum = vst.load_bytes
            eng._vs_spill_cum = vst.spill_bytes

        # --- barrier bookkeeping: natural retirement → drains → admissions
        # (the same order in every execution mode — see class docstring).
        retired: tuple = ()
        drained: tuple = ()
        admitted: tuple = ()
        upd_map: dict = {}
        ctl_pending = 0
        if multi_q:
            upd_map = {int(g): int(n)
                       for g, n in zip(self.active_q, upd_per_q)}
            done = np.nonzero(upd_per_q == 0)[0]
            retired = tuple(int(self.active_q[c]) for c in done)
            if eng.exchange is None:
                # classic mode collects post-retirement: a slot freed at
                # this barrier refills at this same barrier
                control = self._collect_control(
                    ss, qa - len(done), set(self.active_queries),
                    set(retired))
            ctl_admit, ctl_drain, ctl_pending = comm.unpack_admissions(
                control)
            drained = tuple(g for g in ctl_drain
                            if g in set(self.active_queries)
                            and g not in set(retired))
            freeze = sorted(set(int(c) for c in done)
                            | {int(np.nonzero(self.active_q == g)[0][0])
                               for g in drained})
            if freeze:
                keep = np.ones(qa, dtype=bool)
                keep[freeze] = False
                done_set = set(int(c) for c in done)
                if ooc:
                    for c in freeze:
                        gq = int(self.active_q[c])
                        self.final_values[:, gq] = eng._ooc_column(vstore, c)
                        if c in done_set:
                            self.per_query_ss[gq] = (
                                ss + 1 - int(self.admitted_at[gq]))
                    q_names = [n for n in vstore.names()
                               if vstore.spec(n)[1] == (qa,)]
                    vstore.compact_columns(q_names, keep)
                else:
                    for c in freeze:
                        gq = int(self.active_q[c])
                        self.final_values[:, gq] = self.values[:, c]
                        if c in done_set:
                            self.per_query_ss[gq] = (
                                ss + 1 - int(self.admitted_at[gq]))
                    self.values = np.ascontiguousarray(
                        self.values[:, keep])
                    for k in list(self.aux_np):
                        a = self.aux_np[k]
                        if a.ndim == 2 and a.shape[1] == qa:  # per-query
                            self.aux_np[k] = np.ascontiguousarray(
                                a[:, keep])
                            self.aux_dev[k] = jnp.asarray(self.aux_np[k])
                self.active_q = self.active_q[keep]
            if ctl_admit:
                self._apply_admissions(ctl_admit, ss)
                admitted = tuple(int(g) for g, _ in ctl_admit)
                self._force_full = True
        # every rank drops the plan entries that fired at this barrier
        # (peers never fire them, but must agree the backlog shrank)
        self._plan_pending = [e for e in self._plan_pending if e[0] > ss]

        stats = SuperstepStats(
            superstep=ss,
            seconds=time.perf_counter() - t_start,
            load_seconds=load_s,
            compute_seconds=comp_s,
            updated_vertices=int(len(all_idx)),
            density=float(len(all_idx)) / max(nv, 1),
            tiles_processed=tiles_done,
            tiles_skipped=tiles_skipped,
            raw_bytes=raw_b,
            wire_bytes=wire_b,
            network_bytes=wire_b * max(cfg.num_servers - 1, 0),
            cache_hit_ratio=cache_stats["hit_ratio"],
            disk_bytes_read=disk_b,
            stall_seconds=stall_s,
            io_busy_seconds=io_busy,
            cache_promotions=promo,
            cache_demotions=demo,
            cache_tiers=cache_stats["tiers"],
            active_queries=qa,
            updated_pairs=updated_pairs,
            updated_per_query=upd_map,
            retired_queries=retired,
            admitted_queries=admitted,
            drained_queries=drained,
            vstate_faults=vs_faults,
            vstate_load_bytes=vs_load,
            vstate_spill_bytes=vs_spill,
            vstate_dirty_intervals=dirty_ivs,
        )
        self.history.append(stats)
        self.converged = (len(self.active_q) == 0 if multi_q
                          else len(all_idx) == 0)
        self._ss = ss + 1
        with self._lock:
            backlog = (bool(self._plan_pending) or ctl_pending > 0
                       or bool(self._admit_queue))
        self.finished = ((self.converged and not backlog)
                         or self._ss >= self.max_ss)

        # --- superstep-boundary checkpoint + preemption (DESIGN.md §12)
        # Written AFTER update apply + retirement + admission — this
        # boundary's state is exactly what superstep ss+1 starts from.
        # State is fully replicated, so rank 0 is the single periodic
        # writer; a preempted rank may also save (collision-safe publish).
        if eng.ckpt is not None and not self.finished:
            due = (cfg.checkpoint_every > 0
                   and (ss + 1) % cfg.checkpoint_every == 0
                   and cfg.server_rank in (None, 0))
            preempt = eng._guard is not None and eng._guard.triggered
            if due or preempt:
                self._save_boundary(ss)
            if preempt:
                if ooc:
                    vstore.close()
                raise Preempted(ss + 1)
        return stats

    # -- result / epilogue ---------------------------------------------------
    def result(self) -> RunResult:
        """Finalize the session and return its RunResult (same contract as
        the pre-session monolithic ``run()``): flush still-live columns,
        materialize + close the ooc store, publish the final checkpoint."""
        if self._final_result is not None:
            return self._final_result
        if not self.finished:
            raise RuntimeError(
                "session still live — step() to completion or drain first")
        eng = self.eng
        ooc, vstore = self._ooc, self.vstore
        values, aux_np = self.values, self.aux_np
        if self.multi_q:
            # flush columns still live at max_supersteps into the result
            for c, gq in enumerate(self.active_q):
                self.final_values[:, int(gq)] = (
                    eng._ooc_column(vstore, c) if ooc else values[:, c])
            values = self.final_values
        elif ooc:
            values = vstore.materialize("value")
        if ooc:
            # the result materializes the final arrays; the working state
            # and its disk spill tier are per-run scratch
            aux_np = {n: vstore.materialize(n) for n in vstore.names()
                      if n != "value"}
            vstore.close()
        # supersteps counts GLOBALLY (resume continues the numbering, so a
        # resumed run reports the same count as the uninterrupted one even
        # though its history holds only the post-resume entries)
        supersteps = self.start_ss + len(self.history)
        if eng.ckpt is not None and eng.cfg.server_rank in (None, 0):
            eng._save_final(values, aux_np, self.per_query_ss,
                            self.converged, supersteps)
        self._final_result = RunResult(
            values=values, aux=aux_np, history=self.history,
            supersteps=supersteps, converged=self.converged,
            per_query_supersteps=self.per_query_ss)
        return self._final_result

    # -- admission internals -------------------------------------------------
    def _collect_control(self, ss: int, live_base: int, active_set: set,
                         retired_set: set) -> Optional[dict]:
        """Assemble this barrier's admission/drain control record (rank 0
        / classic engine only).  ``live_base`` is the column count that
        survives this barrier's natural retirement (cluster mode passes
        the conservative pre-retirement count — a slot freed at the same
        barrier refills one barrier later there); scheduled ``admit_plan``
        entries fire first and bypass the slot cap, then queued live
        admissions fill the remaining free slots."""
        if not self.multi_q:
            return None
        with self._lock:
            drains: list[int] = []
            for g in self._drain_queue:
                if g not in drains:
                    drains.append(g)
            self._drain_queue.clear()
            live_drains = [g for g in drains
                           if g in active_set and g not in retired_set]
            admit: list[tuple[int, int]] = []
            for after, seeds in self._plan_pending:
                if after == ss:
                    for s in seeds:
                        admit.append((self.next_qid, int(s)))
                        self.next_qid += 1
            free = self.q_slots - (live_base - len(live_drains))
            while self._admit_queue and free > 0:
                admit.append(self._admit_queue.pop(0))
                free -= 1
            return comm.pack_admissions(admit, drains,
                                        len(self._admit_queue))

    def _apply_admissions(self, admit: list, ss: int) -> None:
        """Splice freshly admitted query columns into the live state — the
        inverse of retirement's compaction.  Initial column state comes
        from ``prog.with_queries(seeds).init`` (column math is independent
        of batch context, so the spliced column is bit-identical to a
        fresh single-query run); per-query aux arrays ([V, q_new]) splice
        alongside, shared aux is untouched.  Deterministic given the
        control record, so every cluster rank converges to identical
        state."""
        eng = self.eng
        nv = self.nv
        gqs = [int(g) for g, _ in admit]
        seeds = [int(s) for _, s in admit]
        sub = self.prog.with_queries(seeds)
        state = sub.init(nv, eng.out_degree.astype(np.float64),
                         eng.in_degree.astype(np.float64))
        new_vals = np.asarray(state.pop("value")).astype(self.vdtype)
        qn = len(gqs)
        per_q_aux = {k: np.asarray(v) for k, v in state.items()
                     if np.asarray(v).ndim == 2
                     and np.asarray(v).shape[1] == qn}
        hi = max(gqs) + 1
        if hi > len(self.per_query_ss):
            grow = hi - len(self.per_query_ss)
            self.per_query_ss = np.concatenate(
                [self.per_query_ss, np.full(grow, -1, np.int64)])
            self.admitted_at = np.concatenate(
                [self.admitted_at, np.zeros(grow, np.int64)])
            self.final_values = np.ascontiguousarray(np.concatenate(
                [self.final_values,
                 np.zeros((nv, grow), self.final_values.dtype)], axis=1))
        for g, s in zip(gqs, seeds):
            self.admitted_at[g] = ss + 1
            self.query_seeds[g] = s
        self.final_values[:, gqs] = new_vals
        self.nq_total = len(self.per_query_ss)
        # peers renumber from the control record (rank 0 assigned at
        # collect time); max() keeps both sides monotonic — under the lock,
        # since the serving thread's admit() bumps the counter concurrently
        with self._lock:
            self.next_qid = max(self.next_qid, hi)
        if self._ooc:
            self.vstore.append_columns({"value": new_vals, **per_q_aux})
        else:
            self.values = np.ascontiguousarray(
                np.concatenate([self.values, new_vals], axis=1))
            for k, arr in per_q_aux.items():
                self.aux_np[k] = np.ascontiguousarray(
                    np.concatenate([self.aux_np[k], arr], axis=1))
                self.aux_dev[k] = jnp.asarray(self.aux_np[k])
        self.active_q = np.concatenate(
            [self.active_q, np.asarray(gqs, dtype=self.active_q.dtype)])

    # -- checkpoint ----------------------------------------------------------
    def _save_boundary(self, ss: int) -> None:
        """Write the superstep-``ss+1`` boundary checkpoint: manifest
        (resume point, live queries + per-slot lineage, replicated
        assignment) + state leaves; ooc runs flush vertex state as
        interval blocks instead of leaves (dirty blocks only — clean ones
        hardlink, see core.checkpoint)."""
        eng, cfg = self.eng, self.eng.cfg
        with self._lock:
            next_qid = int(self.next_qid)
        manifest = dict(
            superstep=ss + 1,
            final=False,
            converged=False,
            multi_q=bool(self.multi_q),
            nq_total=int(self.nq_total),
            num_servers=int(cfg.num_servers),
            assignment=[[int(t) for t in a] for a in eng.assignment],
            active_q=([int(g) for g in self.active_q]
                      if self.multi_q else None),
            next_qid=next_qid,
            queries={str(g): int(s) for g, s in self.query_seeds.items()},
        )
        state: dict = {"updated_ids": np.asarray(self.updated_ids,
                                                 np.int64)}
        if self.multi_q:
            state["final_values"] = self.final_values
            state["per_query_ss"] = self.per_query_ss
            state["admitted_at"] = self.admitted_at
        if self.vstore is None:
            state["values"] = self.values
            state["aux"] = self.aux_np
        eng.ckpt.save_graph(ss + 1, state, manifest, vstore=self.vstore)
