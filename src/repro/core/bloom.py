"""Tile-skipping filters (paper §III-C-4).

The paper leaves a bloom filter per tile recording its source-vertex set;
a tile whose sources contain no updated vertex is skipped.  We provide:

  * ``BloomFilter``       — the paper-faithful probabilistic filter
  * ``SourceBlockBitmap`` — beyond-paper *exact* filter at block granularity
                            (1 bit per 2^k-vertex block), vectorizable with
                            a single AND over uint64 words.

Both are host-side scheduling structures; the engine enables skipping only
when the updated-vertex count is small (paper: "only actives this strategy
when having a small number of updated vertices").
"""
from __future__ import annotations

import numpy as np

_MIX1 = np.uint64(0xFF51AFD7ED558CCD)
_MIX2 = np.uint64(0xC4CEB9FE1A85EC53)


def _mix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(33)
        x *= _MIX1
        x ^= x >> np.uint64(33)
        x *= _MIX2
        x ^= x >> np.uint64(33)
    return x


class BloomFilter:
    """Vectorized k-hash bloom filter over vertex ids."""

    def __init__(self, num_bits: int = 1 << 16, num_hashes: int = 4):
        assert num_bits & (num_bits - 1) == 0, "num_bits must be a power of 2"
        self.num_bits = num_bits
        self.num_hashes = num_hashes
        self.bits = np.zeros(num_bits // 64, dtype=np.uint64)

    def _positions(self, ids: np.ndarray) -> np.ndarray:
        h1 = _mix64(np.asarray(ids, dtype=np.uint64))
        h2 = _mix64(h1 ^ _MIX2)
        ks = np.arange(self.num_hashes, dtype=np.uint64)[:, None]
        with np.errstate(over="ignore"):
            pos = (h1[None, :] + ks * h2[None, :]) & np.uint64(self.num_bits - 1)
        return pos  # [k, n]

    def add(self, ids: np.ndarray) -> None:
        """Set the k hash bits for every vertex id in ids ``[U]``
        (vectorized)."""
        pos = self._positions(ids).ravel()
        np.bitwise_or.at(self.bits, pos >> np.uint64(6),
                         np.uint64(1) << (pos & np.uint64(63)))

    def might_contain_any(self, ids: np.ndarray) -> bool:
        """True if ANY id in ids ``[U]`` may be present (no false negatives;
        false positives at the configured bits/hashes rate)."""
        if len(ids) == 0:
            return False
        pos = self._positions(ids)
        word = self.bits[(pos >> np.uint64(6)).astype(np.int64)]
        bit = (word >> (pos & np.uint64(63))) & np.uint64(1)
        return bool(np.any(bit.all(axis=0)))

    def nbytes(self) -> int:
        """Filter size in bytes (the per-tile scheduling-memory cost)."""
        return self.bits.nbytes


class SourceBlockBitmap:
    """Exact per-tile bitmap over vertex-id blocks of size 2^block_shift."""

    def __init__(self, num_vertices: int, block_shift: int = 8):
        self.block_shift = block_shift
        self.num_blocks = (num_vertices + (1 << block_shift) - 1) >> block_shift
        nwords = (self.num_blocks + 63) // 64
        self.words = np.zeros(nwords, dtype=np.uint64)

    def add(self, ids: np.ndarray) -> None:
        """Mark the 2^block_shift-vertex blocks covering ids ``[U]``."""
        blocks = np.unique(np.asarray(ids, dtype=np.int64) >> self.block_shift)
        np.bitwise_or.at(self.words, blocks >> 6,
                         np.uint64(1) << (blocks & 63).astype(np.uint64))

    def intersects(self, active_words: np.ndarray) -> bool:
        """Exact block-granular test: any common block with uint64 words
        active_words ``[B]`` (one AND; no false negatives)."""
        return bool(np.any(self.words & active_words))

    @staticmethod
    def active_words_from_ids(ids: np.ndarray, num_vertices: int,
                              block_shift: int = 8) -> np.ndarray:
        """Bitmap words ``[B]`` (B = ceil(blocks/64)) for an updated-vertex
        id set ids ``[U]`` — built once per superstep and tested against
        every tile filter."""
        bm = SourceBlockBitmap(num_vertices, block_shift)
        bm.add(ids)
        return bm.words

    def nbytes(self) -> int:
        """Bitmap size in bytes (the per-tile scheduling-memory cost)."""
        return self.words.nbytes


def build_tile_filters(tiles, num_vertices: int, kind: str = "bitmap",
                       block_shift: int = 8, bloom_bits: int = 1 << 16):
    """Build one filter per tile from its real source ids."""
    out = []
    for t in tiles:
        srcs = t.source_ids()
        if kind == "bitmap":
            f = SourceBlockBitmap(num_vertices, block_shift)
            f.add(srcs)
        else:
            f = BloomFilter(num_bits=bloom_bits)
            f.add(srcs)
        out.append(f)
    return out
