"""Streaming synthetic graph generators.

R-MAT reproduces the power-law degree skew of the paper's web graphs
(Twitter-2010 / UK-2007 / ...), uniform graphs match the random-graph
assumption behind the paper's Eq. 4/5 memory model.  Generators yield
chunks so the SPE preprocessing path stays out-of-core end to end.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

EdgeChunk = tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]


def uniform_edges(
    num_vertices: int,
    num_edges: int,
    seed: int = 0,
    weighted: bool = False,
    chunk: int = 1 << 20,
) -> Iterator[EdgeChunk]:
    """Uniform random directed edges in chunks of ``chunk`` (matches the
    random-graph assumption behind the paper's Eq. 4/5 memory model)."""
    rng = np.random.default_rng(seed)
    left = num_edges
    while left > 0:
        n = min(chunk, left)
        src = rng.integers(0, num_vertices, n, dtype=np.int64)
        dst = rng.integers(0, num_vertices, n, dtype=np.int64)
        val = rng.uniform(0.1, 10.0, n).astype(np.float32) if weighted else None
        yield src, dst, val
        left -= n


def rmat_edges(
    num_vertices: int,
    num_edges: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    weighted: bool = False,
    chunk: int = 1 << 20,
) -> Iterator[EdgeChunk]:
    """R-MAT (Graph500 parameters by default): recursive quadrant sampling,
    vectorized over a chunk of edges at a time."""
    scale = int(np.ceil(np.log2(max(num_vertices, 2))))
    rng = np.random.default_rng(seed)
    d = 1.0 - a - b - c
    assert d >= -1e-9
    left = num_edges
    while left > 0:
        n = min(chunk, left)
        src = np.zeros(n, dtype=np.int64)
        dst = np.zeros(n, dtype=np.int64)
        for bit in range(scale):
            r = rng.random(n)
            # quadrant probabilities: [a b; c d] over (src_bit, dst_bit)
            src_bit = r >= (a + b)
            r2 = rng.random(n)
            dst_bit = np.where(
                src_bit,
                r2 >= (c / max(c + d, 1e-12)),
                r2 >= (a / max(a + b, 1e-12)),
            )
            src = (src << 1) | src_bit.astype(np.int64)
            dst = (dst << 1) | dst_bit.astype(np.int64)
        src %= num_vertices
        dst %= num_vertices
        val = rng.uniform(0.1, 10.0, n).astype(np.float32) if weighted else None
        yield src, dst, val
        left -= n


def banded_edges(
    num_vertices: int,
    num_edges: int,
    bandwidth: int = 0,
    seed: int = 0,
    weighted: bool = False,
    chunk: int = 1 << 20,
) -> Iterator[EdgeChunk]:
    """Locality-structured graph: src falls within ``bandwidth`` of dst
    (wrapping), like meshes / road networks / time-ordered interaction
    graphs.  Tiles of such graphs touch only a few *source intervals*, so
    this is the workload where interval-aware co-scheduling of the
    out-of-core vertex state shows up (DESIGN.md §10); R-MAT/uniform src
    sets span all of V and every tile's footprint is everything."""
    w = bandwidth or max(1, num_vertices // 16)
    rng = np.random.default_rng(seed)
    left = num_edges
    while left > 0:
        n = min(chunk, left)
        dst = rng.integers(0, num_vertices, n, dtype=np.int64)
        off = rng.integers(-w, w + 1, n, dtype=np.int64)
        src = (dst + off) % num_vertices
        val = rng.uniform(0.1, 10.0, n).astype(np.float32) if weighted else None
        yield src, dst, val
        left -= n


def from_arrays(
    src: np.ndarray, dst: np.ndarray, val: Optional[np.ndarray] = None,
    chunk: int = 1 << 20,
) -> Iterator[EdgeChunk]:
    """Wrap in-memory edge arrays as a chunked stream (test/benchmark aid)."""
    for i in range(0, len(src), chunk):
        s = slice(i, i + chunk)
        yield (
            np.asarray(src[s], dtype=np.int64),
            np.asarray(dst[s], dtype=np.int64),
            None if val is None else np.asarray(val[s], dtype=np.float32),
        )


def symmetrized(stream: Iterator[EdgeChunk]) -> Iterator[EdgeChunk]:
    """Emit each edge in both directions (for WCC on directed inputs)."""
    for src, dst, val in stream:
        yield np.concatenate([src, dst]), np.concatenate([dst, src]), (
            None if val is None else np.concatenate([val, val])
        )
