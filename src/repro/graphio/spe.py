"""SPE — graph pre-processing engine (paper §III-B, Algorithm 4).

The paper runs three Spark map-reduce jobs; here the same three passes run
as chunked out-of-core host passes (this is a data-plane component — Spark
itself contributes nothing algorithmic):

  pass 1+2: per-chunk bincount map -> added reduce  => out-degree, in-degree
  splitter: walk the in-degree array, cut a tile every S edges
  pass 3  : shuffle edges into per-tile spill buckets (group-by tile id),
            then build each tile's CSR block and write it to the store.

The edge stream can be replayed (callable returning a fresh iterator), so
nothing is ever fully materialized in memory.
"""
from __future__ import annotations

import os
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.partition import (IntervalPlan, PartitionPlan, plan_intervals,
                                  plan_partition)
from repro.core.tiles import build_tile
from repro.graphio.formats import TileStore
from repro.graphio.synth import EdgeChunk

StreamFactory = Callable[[], Iterator[EdgeChunk]]


def degree_pass(stream: Iterator[EdgeChunk], num_vertices: int) -> tuple[np.ndarray, np.ndarray]:
    """Map-reduce jobs 1+2: out-degree and in-degree in one pass."""
    out_deg = np.zeros(num_vertices, dtype=np.int64)
    in_deg = np.zeros(num_vertices, dtype=np.int64)
    for src, dst, _ in stream:
        out_deg += np.bincount(src, minlength=num_vertices)
        in_deg += np.bincount(dst, minlength=num_vertices)
    return in_deg, out_deg


class _SpillBuckets:
    """Append-only per-tile spill files for the shuffle pass."""

    def __init__(self, root: str, num_tiles: int, weighted: bool):
        self.root = root
        self.weighted = weighted
        os.makedirs(root, exist_ok=True)
        self.paths = [os.path.join(root, f"spill{t:06d}.bin") for t in range(num_tiles)]
        self.files = [open(p, "wb") for p in self.paths]
        self.rec = np.dtype(
            [("src", "<i8"), ("dst", "<i8")] + ([("val", "<f4")] if weighted else [])
        )

    def append(self, tile_ids: np.ndarray, src: np.ndarray, dst: np.ndarray,
               val: Optional[np.ndarray]) -> None:
        order = np.argsort(tile_ids, kind="stable")
        tile_ids = tile_ids[order]
        src, dst = src[order], dst[order]
        if val is not None:
            val = val[order]
        bounds = np.searchsorted(tile_ids, np.arange(len(self.files) + 1))
        for t in np.unique(tile_ids):
            lo, hi = bounds[t], bounds[t + 1]
            rec = np.empty(hi - lo, dtype=self.rec)
            rec["src"] = src[lo:hi]
            rec["dst"] = dst[lo:hi]
            if val is not None:
                rec["val"] = val[lo:hi]
            self.files[t].write(rec.tobytes())

    def read(self, t: int) -> tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        self.files[t].flush()
        rec = np.fromfile(self.paths[t], dtype=self.rec)
        return (
            rec["src"].astype(np.int64),
            rec["dst"].astype(np.int64),
            rec["val"].astype(np.float32) if self.weighted else None,
        )

    def close(self, remove: bool = True) -> None:
        for f in self.files:
            f.close()
        if remove:
            for p in self.paths:
                if os.path.exists(p):
                    os.remove(p)


def preprocess(
    stream_factory: StreamFactory,
    num_vertices: int,
    store: TileStore,
    tile_size: int,
    weighted: bool = False,
    dedup: bool = False,
    pad_edges_to: int = 128,
    pad_rows_to: int = 8,
    num_intervals: int = 0,
) -> PartitionPlan:
    """Run the full SPE pipeline into ``store``.  Returns the partition plan.

    ``num_intervals > 0`` additionally derives a source-interval plan
    (DESIGN.md §10), records each tile's source-interval footprint in its
    metadata (versioned GHT2 tile format), and persists the interval plan
    in the store's meta.json for the out-of-core vertex-state engine."""
    in_deg, out_deg = degree_pass(stream_factory(), num_vertices)
    plan = plan_partition(in_deg, tile_size, pad_edges_to, pad_rows_to)
    iv_plan: Optional[IntervalPlan] = (
        plan_intervals(plan.splitter, num_intervals) if num_intervals else None)

    spill_root = os.path.join(store.root, "_spill")
    buckets = _SpillBuckets(spill_root, plan.num_tiles, weighted)
    try:
        for src, dst, val in stream_factory():
            tids = (np.searchsorted(plan.splitter, dst, side="right") - 1).astype(np.int64)
            buckets.append(tids, src, dst, val)

        store.initialize(plan, weighted, in_deg, out_deg,
                         interval_plan=iv_plan)
        dd_in = np.zeros_like(in_deg) if dedup else None
        dd_out = np.zeros_like(out_deg) if dedup else None
        for t in range(plan.num_tiles):
            src, dst, val = buckets.read(t)
            lo, hi = plan.tile_range(t)
            if dedup and len(src):
                key = src * (plan.num_vertices + 1) + dst
                _, idx = np.unique(key, return_index=True)
                src, dst = src[idx], dst[idx]
                val = val[idx] if val is not None else None
            if dedup:
                dd_in += np.bincount(dst, minlength=len(in_deg))
                dd_out += np.bincount(src, minlength=len(out_deg))
            tile = build_tile(
                t, lo, hi, src, dst, val if weighted else None,
                plan.edge_cap, plan.row_cap,
                interval_splitter=None if iv_plan is None else iv_plan.splitter,
            )
            store.write_tile(tile)
        if dedup:   # degrees must reflect the deduped edge set
            store.initialize(plan, weighted, dd_in, dd_out,
                             interval_plan=iv_plan)
    finally:
        buckets.close()
        if os.path.isdir(spill_root) and not os.listdir(spill_root):
            os.rmdir(spill_root)
    return plan


def preprocess_arrays(
    src: np.ndarray, dst: np.ndarray, val: Optional[np.ndarray],
    num_vertices: int, store: TileStore, tile_size: int, **kw,
) -> PartitionPlan:
    """In-memory convenience wrapper over ``preprocess`` for edge arrays
    (src/dst int64 [E], optional float32 val [E])."""
    from repro.graphio.synth import from_arrays

    return preprocess(
        lambda: from_arrays(src, dst, val),
        num_vertices, store, tile_size,
        weighted=val is not None, **kw,
    )
