# SPE-equivalent preprocessing + tile storage ("DFS") + synthetic graphs.
