"""Graph I/O: SPE-equivalent preprocessing (``spe``), the tile store +
wire/disk formats (``formats``), and synthetic graph generators
(``synth``).  Submodules are imported explicitly by users.
"""
# SPE-equivalent preprocessing + tile storage ("DFS") + synthetic graphs.
