"""Tile store — GraphH's "DFS" tier (paper §III-A).

Tiles are serialized to one binary blob each (header + raw little-endian
array bytes), optionally zstd-compressed, and written to a directory:

    store/
      meta.json            partition plan + graph metadata
      degrees.npz          in_degree / out_degree arrays (paper: SPE output)
      tiles/t<id>.bin      serialized tiles

The same serializer feeds the edge-cache tier (core/cache.py) so the cache
can hold compressed blobs at any of the paper's four modes.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import struct
import threading
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.compat import zstd_compress, zstd_decompress
from repro.core.partition import IntervalPlan, PartitionPlan
from repro.core.tiles import Tile, TileMeta

# Versioned tile format: GHT1 is the original layout; GHT2 appends the
# source-interval bucket-sort permutation (``Tile.iv_perm``, DESIGN.md §10)
# after the value array.  Readers accept both; writers emit GHT2 only when a
# footprint is attached, so stores built without an interval plan stay
# byte-identical to the v1 format.
MAGIC = b"GHT1"
MAGIC_V2 = b"GHT2"

# The paper's cache modes: 1=raw, 2=snappy, 3=zlib-1, 4=zlib-3.  snappy/zlib
# are not shipped in this environment; zstd levels are the stand-ins with the
# same fast/slow compression trade-off shape (DESIGN.md §3).  When zstandard
# itself is unavailable, repro.compat transparently substitutes stdlib zlib
# at the same levels.
MODE_CODECS = {
    1: ("raw", None),
    2: ("zstd-1", 1),     # snappy analogue: fast, modest ratio
    3: ("zstd-3", 3),     # zlib-1 analogue
    4: ("zstd-9", 9),     # zlib-3 analogue: slow, best ratio
}


def compress_blob(blob: bytes, mode: int) -> bytes:
    """Compress ``blob`` at one of the paper's four modes (1 = raw
    passthrough); see MODE_CODECS for the ladder."""
    name, level = MODE_CODECS[mode]
    if level is None:
        return blob
    return zstd_compress(blob, level)


def decompress_blob(blob: bytes, mode: int) -> bytes:
    """Inverse of ``compress_blob`` for the same mode."""
    name, level = MODE_CODECS[mode]
    if level is None:
        return blob
    return zstd_decompress(blob)


def serialize_tile(tile: Tile) -> bytes:
    """Tile -> one binary blob: magic + JSON header + raw little-endian
    arrays (GHT2 appends iv_perm when a footprint is attached)."""
    v2 = tile.iv_perm is not None
    header = dict(
        meta=tile.meta.to_dict(),
        weighted=tile.val is not None,
        row_ptr_len=int(tile.row_ptr.shape[0]),
    )
    if v2:
        header["iv_perm_len"] = int(tile.iv_perm.shape[0])
    hb = json.dumps(header).encode()
    out = io.BytesIO()
    out.write(MAGIC_V2 if v2 else MAGIC)
    out.write(struct.pack("<I", len(hb)))
    out.write(hb)
    out.write(tile.src.astype("<i4").tobytes())
    out.write(tile.dst_local.astype("<i4").tobytes())
    out.write(tile.row_ptr.astype("<i4").tobytes())
    if tile.val is not None:
        out.write(tile.val.astype("<f4").tobytes())
    if v2:
        out.write(tile.iv_perm.astype("<i4").tobytes())
    return out.getvalue()


def deserialize_tile(blob: bytes) -> Tile:
    """Inverse of ``serialize_tile`` (accepts GHT1 and GHT2)."""
    magic = blob[:4]
    assert magic in (MAGIC, MAGIC_V2), "bad tile magic"
    (hlen,) = struct.unpack("<I", blob[4:8])
    header = json.loads(blob[8 : 8 + hlen].decode())
    meta = TileMeta.from_dict(header["meta"])
    off = 8 + hlen
    ecap = meta.edge_cap

    def take(n, dtype):
        nonlocal off
        a = np.frombuffer(blob, dtype=dtype, count=n, offset=off).copy()
        off += n * np.dtype(dtype).itemsize
        return a

    src = take(ecap, "<i4")
    dst_local = take(ecap, "<i4")
    row_ptr = take(header["row_ptr_len"], "<i4")
    val = take(ecap, "<f4") if header["weighted"] else None
    iv_perm = (take(header["iv_perm_len"], "<i4")
               if magic == MAGIC_V2 else None)
    return Tile(meta=meta, src=src, dst_local=dst_local, val=val,
                row_ptr=row_ptr, iv_perm=iv_perm)


class TileStore:
    """Directory-backed tile store with optional at-rest compression."""

    #: lock discipline, enforced by tools/analyze.py --check locks
    _guarded_by = {"bytes_read": "_stats_lock",
                   "bytes_written": "_stats_lock"}

    def __init__(self, root: str, disk_mode: int = 1):
        self.root = root
        self.disk_mode = disk_mode
        self.tile_dir = os.path.join(root, "tiles")
        self.bytes_read = 0
        self.bytes_written = 0
        self._stats_lock = threading.Lock()  # prefetch workers share counters

    # -- write side (SPE) --------------------------------------------------
    def initialize(self, plan: PartitionPlan, weighted: bool,
                   in_degree: np.ndarray, out_degree: np.ndarray,
                   interval_plan: Optional[IntervalPlan] = None) -> None:
        """Write meta.json (partition plan + optional interval plan) and the
        degree arrays; creates the tiles/ directory."""
        os.makedirs(self.tile_dir, exist_ok=True)
        meta = dict(
            plan=plan.to_dict(),
            weighted=weighted,
            disk_mode=self.disk_mode,
        )
        if interval_plan is not None:
            meta["interval_plan"] = interval_plan.to_dict()
        tmp = os.path.join(self.root, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.root, "meta.json"))
        # stage through a file object: np.savez would append ".npz" to a
        # bare "degrees.npz.tmp" path and the publish would miss it
        dtmp = os.path.join(self.root, "degrees.npz.tmp")
        with open(dtmp, "wb") as f:
            np.savez(f, in_degree=in_degree, out_degree=out_degree)
            f.flush()
            os.fsync(f.fileno())
        os.replace(dtmp, os.path.join(self.root, "degrees.npz"))

    def write_tile(self, tile: Tile) -> int:
        """Serialize + disk-mode-compress + atomically write one tile; returns
        the on-disk byte count."""
        blob = compress_blob(serialize_tile(tile), self.disk_mode)
        path = self._tile_path(tile.meta.tile_id)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic: a reader never sees a torn tile
        with self._stats_lock:
            self.bytes_written += len(blob)
        return len(blob)

    # -- read side (MPE) ---------------------------------------------------
    def load_meta(self) -> dict:
        """Read meta.json (also refreshes ``self.disk_mode``)."""
        with open(os.path.join(self.root, "meta.json")) as f:
            meta = json.load(f)
        self.disk_mode = meta["disk_mode"]
        return meta

    def load_plan(self) -> PartitionPlan:
        """The stage-1 PartitionPlan recorded at preprocessing time."""
        return PartitionPlan.from_dict(self.load_meta()["plan"])

    def fingerprint(self) -> str:
        """Stable identity of the preprocessed graph, used as a result-cache
        key component (serve.graph_service).  Hashes meta.json, the degree
        archive bytes, and the sorted (name, size) tile listing — cheap (tile
        payloads are not read) and **conservative**: two different graphs
        never collide (their degree bytes differ), while a byte-level rebuild
        of the same graph may re-key the cache (npz zip timestamps) — a
        spurious miss, never a wrong hit."""
        h = hashlib.sha256()
        with open(os.path.join(self.root, "meta.json"), "rb") as f:
            h.update(f.read())
        deg = os.path.join(self.root, "degrees.npz")
        if os.path.exists(deg):
            with open(deg, "rb") as f:
                h.update(f.read())
        if os.path.isdir(self.tile_dir):
            for name in sorted(os.listdir(self.tile_dir)):
                size = os.stat(os.path.join(self.tile_dir, name)).st_size
                h.update(f"{name}:{size};".encode())
        return h.hexdigest()[:16]

    def load_interval_plan(self) -> Optional[IntervalPlan]:
        """Interval plan recorded at preprocessing time (DESIGN.md §10), or
        None for stores built without one — the engine then derives a plan
        from the tile splitter and computes footprints lazily."""
        d = self.load_meta().get("interval_plan")
        return IntervalPlan.from_dict(d) if d is not None else None

    def load_degrees(self) -> tuple[np.ndarray, np.ndarray]:
        """(in_degree [V], out_degree [V]) int64 arrays from degrees.npz."""
        z = np.load(os.path.join(self.root, "degrees.npz"))
        return z["in_degree"], z["out_degree"]

    def read_tile_blob(self, tile_id: int) -> bytes:
        """Raw (possibly disk-compressed) blob — what the cache stores."""
        with open(self._tile_path(tile_id), "rb") as f:
            blob = f.read()
        with self._stats_lock:
            self.bytes_read += len(blob)
        return blob

    def read_tile(self, tile_id: int) -> Tile:
        """Read + decompress + deserialize one tile from disk."""
        return deserialize_tile(
            decompress_blob(self.read_tile_blob(tile_id), self.disk_mode)
        )

    def tile_disk_bytes(self, tile_id: int) -> int:
        """On-disk (post disk-mode compression) size of one tile, in bytes."""
        return os.path.getsize(self._tile_path(tile_id))

    def iter_tiles(self, tile_ids: Iterator[int]) -> Iterator[Tile]:
        """Yield tiles in the given id order (serial reads; see
        ``prefetch_iter`` for the overlapped path)."""
        for t in tile_ids:
            yield self.read_tile(t)

    def prefetch_iter(self, tile_ids: Iterable[int], depth: int = 4,
                      cache=None, workers: int = 2) -> Iterator[tuple[int, Tile]]:
        """Yield ``(tile_id, Tile)`` in order, reading + decompressing up to
        ``depth`` tiles ahead on ``workers`` background threads (the
        pipelined engine's I/O stage — paper §IV: keep the disk busy while
        workers compute).  Multiple workers matter because decompression is
        the dominant per-tile cost and zlib/zstd release the GIL.

        When an :class:`~repro.core.cache.EdgeCache` is passed, lookups go
        through it on the prefetch threads: the cache is consulted
        (``get_if_resident``) before any disk read is issued, so hits decode
        straight from idle memory without touching the disk; misses are read
        once and admitted to the cache, and hit/miss/disk stats accrue
        exactly as on the serial path.  EdgeCache does its codec work
        outside its lock, so workers genuinely overlap.  The engine feeds
        this iterator a cache-hit-first tile order (``cache_aware_order``),
        so resident tiles flow to the consumer immediately while the
        workers' lookahead pulls the misses off disk behind them.

        ``depth`` bounds memory: at most ``depth`` tiles are decoded-but-
        unconsumed (completed or in flight) at any moment, regardless of
        worker count.  Delivery order always matches ``tile_ids`` order.

        In-flight reads are deduplicated: when two workers want the same
        tile id concurrently (duplicate ids in ``tile_ids``), the second
        waits for the first's read to land in the cache instead of issuing
        a second disk read for the same bytes.
        """
        ids = list(tile_ids)
        if not ids:
            return
        depth = max(1, depth)
        nworkers = max(1, min(workers, depth, len(ids)))
        budget = threading.Semaphore(depth)
        cond = threading.Condition()
        results: dict[int, tuple[int, Optional[Tile], Optional[BaseException]]] = {}
        cursor = [0]          # next id index to claim (under cond)
        stop = threading.Event()
        # tile id -> (event, [tile, exc]) for reads currently in flight: the
        # leader loads and publishes; followers wait on the event and reuse
        # the leader's result (which also sits in the cache by then) rather
        # than reading the same tile from disk a second time
        inflight: dict[int, tuple[threading.Event, list]] = {}
        iflock = threading.Lock()

        def _load(tid: int) -> Tile:
            # cache.get consults residency (get_if_resident) before
            # issuing any disk read: resident tiles decode straight
            # from idle memory, only misses touch the disk tier
            return cache.get(tid) if cache is not None else self.read_tile(tid)

        def produce() -> None:
            while not stop.is_set():
                if not budget.acquire(timeout=0.1):
                    continue  # re-check stop
                with cond:
                    i = cursor[0]
                    if i >= len(ids):
                        budget.release()
                        return
                    cursor[0] += 1
                tid = ids[i]
                with iflock:
                    entry = inflight.get(tid)
                    leader = entry is None
                    if leader:
                        entry = (threading.Event(), [None, None])
                        inflight[tid] = entry
                ev, slot = entry
                if leader:
                    try:
                        slot[0] = _load(tid)
                    except BaseException as exc:  # surfaced on the consumer
                        slot[1] = exc
                    finally:
                        with iflock:
                            inflight.pop(tid, None)
                        ev.set()
                else:
                    while not ev.wait(timeout=0.1):
                        if stop.is_set():
                            budget.release()
                            return
                    if slot[1] is not None:
                        # leader failed; retry independently so a transient
                        # error doesn't poison every duplicate
                        try:
                            slot = [_load(tid), None]
                        except BaseException as exc:
                            slot = [None, exc]
                item = (tid, slot[0], slot[1])
                with cond:
                    results[i] = item
                    cond.notify_all()

        threads = [threading.Thread(target=produce, daemon=True,
                                    name=f"graphh-prefetch-{w}")
                   for w in range(nworkers)]
        for t in threads:
            t.start()
        try:
            for i in range(len(ids)):
                with cond:
                    while i not in results:
                        if not any(t.is_alive() for t in threads):
                            raise RuntimeError(
                                f"prefetch workers died before tile index {i}")
                        cond.wait(timeout=0.1)
                    tid, tile, exc = results.pop(i)
                budget.release()
                if exc is not None:
                    raise exc
                yield tid, tile
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=5.0)

    def _tile_path(self, tile_id: int) -> str:
        return os.path.join(self.tile_dir, f"t{tile_id:06d}.bin")
