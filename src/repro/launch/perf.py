import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# §Perf hillclimbing runner: re-lower + re-analyze a single (arch, cell)
# with RunConfig overrides; results land in results/perf/<label>.json for
# the EXPERIMENTS.md iteration log.
#
#   PYTHONPATH=src python -m repro.launch.perf --arch qwen3-14b \
#       --cell train_4k --label it1_flat --set attn_shard=flat
import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPE_CELLS
from repro.launch import mesh as meshlib
from repro.roofline import analysis as ra, hlo_cost

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results", "perf")


def run(arch: str, cell_name: str, overrides: dict, label: str,
        mesh_kind: str = "single", attribute: bool = False) -> dict:
    from repro.models.model_zoo import build_model, param_count, active_param_count
    from repro.serve import serve_step
    from repro.train import train_step as ts

    cell = SHAPE_CELLS[cell_name]
    cfg = registry.get_config(arch)
    mesh = meshlib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    run_cfg = registry.default_run_config(arch, cell, n_chips)
    typed = {}
    for k, v in overrides.items():
        cur = getattr(run_cfg, k)
        typed[k] = type(cur)(v) if cur is not None and not isinstance(cur, bool) \
            else (v in ("1", "true", "True") if isinstance(cur, bool) else v)
    run_cfg = dataclasses.replace(run_cfg, **typed)

    model = build_model(cfg, run_cfg)
    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    n_active = active_param_count(cfg, pshapes)
    embed_p = cfg.vocab_size * cfg.d_model

    t0 = time.time()
    if cell.kind == "train":
        step, init_state, sh = ts.build_train_step(cfg, run_cfg, mesh=mesh)
        state_shapes = jax.eval_shape(init_state, jax.random.key(0))
        lowered = step.lower(state_shapes, registry.input_specs(cfg, cell))
        mflops = ra.model_flops("train", n_active,
                                cell.global_batch * cell.seq_len, embed_p)
    elif cell.kind == "prefill":
        fns = serve_step.build_serve_fns(cfg, run_cfg, mesh=mesh,
                                         max_len=cell.seq_len,
                                         batch=cell.global_batch)
        cshapes = jax.eval_shape(fns["init_cache"])
        lowered = fns["prefill"].lower(pshapes, cshapes,
                                       registry.input_specs(cfg, cell))
        mflops = ra.model_flops("prefill", n_active,
                                cell.global_batch * cell.seq_len, embed_p)
    else:
        fns = serve_step.build_serve_fns(cfg, run_cfg, mesh=mesh,
                                         max_len=cell.seq_len,
                                         batch=cell.global_batch)
        cshapes = jax.eval_shape(fns["init_cache"])
        tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
        lowered = fns["decode"].lower(pshapes, cshapes, tok,
                                      jax.ShapeDtypeStruct((), jnp.int32))
        mflops = ra.model_flops("decode", n_active, cell.global_batch, embed_p)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    from repro.compat import zstd_compress
    os.makedirs(PERF_DIR, exist_ok=True)
    with open(os.path.join(PERF_DIR, f"{arch}__{cell_name}__{label}.hlo.zst"),
              "wb") as f:
        f.write(zstd_compress(hlo.encode(), level=3))
    cost = hlo_cost.analyze(hlo)
    terms = ra.roofline(cost.flops, cost.bytes, cost.coll_bytes, n_chips,
                        mflops, hbm_bytes_fused=cost.bytes_fused)
    out = {
        "arch": arch, "cell": cell_name, "label": label,
        "overrides": typed, "compile_s": round(time.time() - t0, 1),
        "roofline": terms.as_dict(),
        "collectives": {k: int(v) for k, v in cost.coll_by_kind.items()},
    }
    if attribute:
        out["attribution"] = [
            (t, round(f, 0), round(b, 0))
            for t, f, b in hlo_cost.attribute(hlo, depth=6, top_k=12)]
    os.makedirs(PERF_DIR, exist_ok=True)
    with open(os.path.join(PERF_DIR, f"{arch}__{cell_name}__{label}.json"),
              "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--label", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override key=value")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--attribute", action="store_true")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)
    out = run(args.arch, args.cell, overrides, args.label, args.mesh,
              args.attribute)
    rf = out["roofline"]
    print(json.dumps({
        "label": args.label,
        "compute_s": round(rf["compute_s"], 4),
        "memory_s": round(rf["memory_s"], 4),
        "collective_s": round(rf["collective_s"], 4),
        "bottleneck": rf["bottleneck"],
        "useful": round(rf["useful_flops_ratio"], 3),
        "mfu_bound": round(rf["mfu_bound"], 4),
    }))


if __name__ == "__main__":
    main()
