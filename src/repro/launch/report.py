"""Render results/dryrun/*.json into the EXPERIMENTS.md §Dry-run and
§Roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["whisper-base", "qwen3-14b", "qwen3-1.7b", "gemma2-2b",
              "deepseek-7b", "internvl2-76b", "recurrentgemma-9b",
              "dbrx-132b", "granite-moe-1b-a400m", "rwkv6-1.6b"]


def load(dirname):
    out = {}
    for p in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(p))
        out[(r["arch"], r["cell"], r["mesh"])] = r
    return out


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.1f}us"


def dryrun_table(res):
    lines = [
        "| arch | cell | mesh | status | compile | bytes/dev (args+out+temp) | collectives (ops) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for cell in CELL_ORDER:
            for mesh in ("single", "multi"):
                r = res.get((arch, cell, mesh))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    lines.append(f"| {arch} | {cell} | {mesh} | SKIP (assignment) | | | |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {cell} | {mesh} | **ERROR** | | | |")
                    continue
                m = r["memory"]
                mem = (f"{fmt_bytes(m.get('argument_bytes',0))}+"
                       f"{fmt_bytes(m.get('output_bytes',0))}+"
                       f"{fmt_bytes(m.get('temp_bytes',0))}")
                ops = ", ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in
                                sorted(r.get("collective_ops", {}).items()))
                lines.append(
                    f"| {arch} | {cell} | {mesh} | ok | {r['compile_s']:.0f}s "
                    f"| {mem} | {ops} |")
    return "\n".join(lines)


def roofline_table(res, mesh="single"):
    lines = [
        "| arch | cell | compute | mem (fused/cons) | collective | bound | model TFLOP | useful | MFU-bound | next move |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for cell in CELL_ORDER:
            r = res.get((arch, cell, mesh))
            if r is None or r["status"] == "skipped":
                if r is not None:
                    lines.append(f"| {arch} | {cell} | — | — | — | skip | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {cell} | ERROR | | | | | | | |")
                continue
            rf = r["roofline"]
            move = suggest_move(r)
            fused = rf.get("memory_fused_s", rf["memory_s"])
            lines.append(
                f"| {arch} | {cell} | {fmt_s(rf['compute_s'])} "
                f"| {fmt_s(fused)} / {fmt_s(rf['memory_s'])} "
                f"| {fmt_s(rf['collective_s'])} "
                f"| **{rf['bottleneck'][:4]}** "
                f"| {rf['model_flops_total']/1e12:.1f} "
                f"| {min(rf['useful_flops_ratio'],99):.2f} "
                f"| {rf['mfu_bound']:.3f} | {move} |")
    return "\n".join(lines)


def suggest_move(r) -> str:
    rf = r["roofline"]
    b = rf["bottleneck"]
    if b == "collective":
        top = max(r["collectives"], key=r["collectives"].get) if r["collectives"] else "?"
        return f"cut {top} traffic (sharding/overlap)"
    if b == "memory":
        if rf["useful_flops_ratio"] < 0.3:
            return "reduce replicated compute (shard heads/seq)"
        return "fuse/remat tuning; bigger per-step compute"
    return "increase arithmetic intensity or accept (compute-bound)"


def summary(res):
    ok = sum(1 for r in res.values() if r["status"] == "ok")
    skip = sum(1 for r in res.values() if r["status"] == "skipped")
    err = sum(1 for r in res.values() if r["status"] not in ("ok", "skipped"))
    return f"{ok} compiled ok, {skip} skipped (assignment rules), {err} errors"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    res = load(args.dir)
    print(f"<!-- {summary(res)} -->\n")
    if args.section in ("all", "dryrun"):
        print("## Dry-run (both meshes)\n")
        print(dryrun_table(res))
        print()
    if args.section in ("all", "roofline"):
        print("## Roofline (single-pod 16x16, per-device terms)\n")
        print(roofline_table(res))


if __name__ == "__main__":
    main()
