"""Baseline vs optimized sweep comparison for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.compare
"""
import glob
import json
import os

from repro.launch.report import ARCH_ORDER, CELL_ORDER, fmt_s, load


def main():
    base = load("results/dryrun")
    opt = load("results/dryrun_opt")
    print("| arch | cell | MFU-bound base -> opt | x | bottleneck base -> opt | useful base -> opt |")
    print("|---|---|---|---|---|---|")
    gains = []
    for arch in ARCH_ORDER:
        for cell in CELL_ORDER:
            b = base.get((arch, cell, "single"))
            o = opt.get((arch, cell, "single"))
            if not b or not o or b["status"] != "ok" or o["status"] != "ok":
                continue
            rb, ro = b["roofline"], o["roofline"]
            x = ro["mfu_bound"] / max(rb["mfu_bound"], 1e-9)
            gains.append(x)
            print(f"| {arch} | {cell} | {rb['mfu_bound']:.4f} -> {ro['mfu_bound']:.4f} "
                  f"| {x:.1f}x | {rb['bottleneck'][:4]} -> {ro['bottleneck'][:4]} "
                  f"| {rb['useful_flops_ratio']:.2f} -> {min(ro['useful_flops_ratio'],99):.2f} |")
    if gains:
        import statistics
        print(f"\ngeometric-mean MFU-bound improvement over "
              f"{len(gains)} cells: "
              f"{statistics.geometric_mean(gains):.2f}x")


if __name__ == "__main__":
    main()
