"""Graph analytics driver — run GraphH apps out-of-core or distributed.

    PYTHONPATH=src python -m repro.launch.graph --app pagerank \
        --vertices 100000 --edges 1000000 --servers 4 --supersteps 20

``--servers N`` emulates the paper's N servers inside one process (the
measurable reference).  ``--cluster`` upgrades the same run to N *real*
server processes exchanging updates over a shared-memory ring or TCP
(``--transport``, DESIGN.md §11) via ``repro.launch.cluster`` — results
are bit-identical either way.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import numpy as np

from repro.core.apps import APPS
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.graphio import spe, synth
from repro.graphio.formats import TileStore


def build_store(args) -> TileStore:
    """SPE-preprocess the synthetic graph selected by the CLI namespace
    into a (new or ``--store``-named) TileStore; weighted edges are
    generated iff the app consumes them (sssp/landmarks)."""
    store = TileStore(args.store or tempfile.mkdtemp(prefix="graphh_"),
                      disk_mode=args.disk_mode)
    gen = {"rmat": synth.rmat_edges, "uniform": synth.uniform_edges,
           "banded": synth.banded_edges}[args.graph]
    weighted = args.app in ("sssp", "landmarks")
    t0 = time.time()
    spe.preprocess(
        lambda: gen(args.vertices, args.edges, seed=args.seed,
                    weighted=weighted),
        args.vertices, store, tile_size=args.tile_size,
        weighted=weighted,
    )
    print(f"SPE preprocessing: {time.time()-t0:.1f}s -> {store.root}")
    return store


def _serve_main(args):
    """``--serve`` / ``--serve-http``: long-lived graph-query service
    over the tile store (DESIGN.md §13/§16).  A scripted workload of
    ``--serve-requests`` mixed queries (seeded from ``--seed``) is
    offered at ``--serve-qps`` (0 = all upfront) from a feeder thread;
    the serve loop runs in the main thread so SIGTERM drains gracefully
    (exit 0).  With ``--serve-requests 0`` — always in HTTP mode — the
    service idles until SIGTERM.  ``--serve-http`` additionally binds the
    JSON-over-HTTP frontend (serve/http.py) on ``--host``/``--port`` and
    keeps it answering ``GET /v1/query/<rid>`` for ``--drain-linger-ms``
    after the drain so clients can collect in-flight results."""
    import threading

    from repro.serve.graph_service import (SERVABLE, GraphService,
                                           parse_tenants)

    apps = [a.strip() for a in args.serve_apps.split(",") if a.strip()]
    bad = [a for a in apps if a not in SERVABLE]
    if bad:
        raise SystemExit(f"--serve-apps: {bad} not servable "
                         f"(batched apps only: {', '.join(SERVABLE)})")
    if args.reuse and args.store:
        store = TileStore(args.store)
        store.load_meta()
    else:
        store = build_store(args)
    cfg = EngineConfig(
        num_servers=args.servers,
        cache_capacity_bytes=int(args.cache_mb * 1e6),
        cache_mode=args.cache_mode if args.cache_mode == "auto"
        else int(args.cache_mode),
        comm_mode=args.comm_mode,
        cache_policy=args.cache_policy,
        pipeline=args.pipeline,
        vertex_memory_budget=(None if args.vertex_memory_budget is None
                              else int(args.vertex_memory_budget * 1e6)),
        num_intervals=args.num_intervals,
        checkpoint_dir=args.checkpoint_dir,
    )
    svc = GraphService(
        store, cfg, q_slots=args.q_slots, min_fill=args.min_fill,
        max_wait_s=args.max_wait_ms / 1e3,
        default_deadline_s=(None if args.deadline_ms is None
                            else args.deadline_ms / 1e3),
        max_supersteps=args.supersteps,
        drain_mode=args.drain_mode, resume=args.resume,
        tenants=parse_tenants(args.tenants) if args.tenants else None,
        result_cache=args.result_cache)

    frontend = None
    if args.serve_http:
        from repro.serve.http import HttpFrontend

        fault = None
        if args.inject:
            from repro.runtime import faults

            fault = faults.parse_plan(args.inject).injector()
        frontend = HttpFrontend(svc, host=args.host, port=args.port,
                                fault=fault).start()
        print(f"serving http on {frontend.host}:{frontend.port}",
              flush=True)

    def feeder():
        rng = np.random.default_rng(args.seed)
        tickets = []
        for i in range(args.serve_requests):
            if args.serve_qps > 0 and i:
                time.sleep(1.0 / args.serve_qps)
            try:
                tickets.append(svc.submit(apps[i % len(apps)],
                                          int(rng.integers(args.vertices))))
            except RuntimeError:
                break               # service started draining under us
        for t in tickets:
            t.wait()
        svc.request_drain()

    if args.serve_requests and not args.serve_http:
        threading.Thread(target=feeder, daemon=True).start()
    print(f"serving {','.join(apps)} on {store.root} "
          f"(q_slots={args.q_slots}, min_fill={args.min_fill}, "
          f"max_wait={args.max_wait_ms:g} ms, drain={args.drain_mode})",
          flush=True)
    t0 = time.time()
    svc.serve()
    dt = time.time() - t0
    if frontend is not None:
        # linger: finished tickets stay pollable while clients collect
        time.sleep(max(0.0, args.drain_linger_ms) / 1e3)
        frontend.close()
    s = svc.latency_summary()
    print(f"drained: {svc.stats['done']} done, {svc.stats['timeout']} "
          f"timeout, {svc.stats['failed']} failed, "
          f"{svc.stats['refused']} refused in {dt:.1f}s "
          f"({svc.stats['done'] / max(dt, 1e-9):.2f} queries/s, "
          f"{svc.stats['supersteps']} supersteps, "
          f"{svc.stats['sessions_opened']} sessions)")
    if s.get("count"):
        print(f"  latency p50 {s['p50_ms']:.0f} ms, p99 {s['p99_ms']:.0f} "
              f"ms (queue {s['mean_queue_ms']:.0f} ms + service "
              f"{s['mean_service_ms']:.0f} ms mean); "
              f"{s['mean_supersteps']:.1f} supersteps/query mean")
    if svc.cache is not None:
        c = svc.cache.snapshot()
        print(f"  result cache: {c['hits']} hits / {c['misses']} misses "
              f"({c['entries']}/{c['capacity']} entries)")
    if svc.tenant_stats:
        parts = ", ".join(
            f"{t}: {d['admitted']} admitted/{d['submitted']} submitted"
            for t, d in sorted(svc.tenant_stats.items()))
        print(f"  tenants: {parts}")
    return svc


def main(argv=None):
    """Parse CLI flags, build/reuse a tile store, and run the selected app
    through the out-of-core engine (or hand off to the multi-process
    cluster driver when ``--cluster`` is set)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="pagerank", choices=sorted(APPS))
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "uniform", "banded"])
    ap.add_argument("--vertices", type=int, default=100_000)
    ap.add_argument("--edges", type=int, default=1_000_000)
    ap.add_argument("--tile-size", type=int, default=65536)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--supersteps", type=int, default=30)
    ap.add_argument("--cache-mb", type=float, default=1024)
    ap.add_argument("--cache-mode", default="auto")
    ap.add_argument("--cache-policy", default="lru",
                    choices=["lru", "tiered", "cost-aware"],
                    help="lru = paper's whole-cache single mode; tiered / "
                         "cost-aware = per-tile hot/warm/cold ladder with "
                         "demote-before-evict (DESIGN.md §8)")
    ap.add_argument("--cache-promote-hits", type=int, default=2,
                    help="hits between tier promotions (tiered policies)")
    ap.add_argument("--static-order", action="store_true",
                    help="disable cache-hit-first tile ordering")
    ap.add_argument("--comm-mode", default="hybrid",
                    choices=["dense", "sparse", "hybrid"])
    ap.add_argument("--disk-mode", type=int, default=1)
    ap.add_argument("--store", default=None,
                    help="reuse an existing tile store directory")
    ap.add_argument("--reuse", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline", action="store_true",
                    help="overlap tile I/O, compute, and broadcast "
                         "compression (DESIGN.md §7)")
    ap.add_argument("--prefetch-depth", type=int, default=4)
    ap.add_argument("--prefetch-workers", type=int, default=2)
    ap.add_argument("--seg-impl", default="jnp",
                    choices=["jnp", "pallas_onehot", "pallas_fused"],
                    help="segment-reduce backend: XLA scatter, the unfused "
                         "one-hot Pallas kernel, or the fused "
                         "gather→combine→apply kernel (DESIGN.md §14)")
    ap.add_argument("--kernel-autotune", action="store_true",
                    help="pick Pallas (BE, BR) blocks + stack size from the "
                         "roofline cost model per (app, Q, tile shape) "
                         "instead of the static (512, 256); implies the "
                         "fused kernel path")
    ap.add_argument("--stack-size", type=int, default=4,
                    help="tiles per jitted batch dispatch (pipelined mode)")
    ap.add_argument("--queries", type=int, default=None,
                    help="batched apps (ppr/msbfs/landmarks): number of "
                         "query instances to run in one edge pass; seeds "
                         "are drawn deterministically from --seed unless "
                         "--seeds is given (DESIGN.md §9)")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seed/source/landmark vertex ids "
                         "for the batched apps, e.g. --seeds 0,17,42")
    ap.add_argument("--vertex-memory-budget", type=float, default=None,
                    metavar="MB",
                    help="byte budget (in MB) for the interval-sharded "
                         "out-of-core vertex state (DESIGN.md §10); vertex "
                         "[V,Q] arrays beyond it spill to a disk tier.  "
                         "Default: fully resident (the paper's All-in-All)")
    ap.add_argument("--num-intervals", type=int, default=0,
                    help="source intervals K for the out-of-core vertex "
                         "state (0 = auto from the budget / stored plan)")
    ap.add_argument("--no-interval-order", action="store_true",
                    help="disable interval-aware tile co-scheduling in "
                         "ooc-vstate mode (falls back to cache-hit-first)")
    ap.add_argument("--cluster", action="store_true",
                    help="run --servers as N real server processes "
                         "exchanging updates over --transport instead of "
                         "emulating them in-process (DESIGN.md §11)")
    ap.add_argument("--transport", default="shm", choices=["shm", "tcp"],
                    help="cluster transport: shared-memory ring (one "
                         "host) or TCP sockets (rendezvous via a shared "
                         "filesystem)")
    ap.add_argument("--steal", action="store_true",
                    help="cluster mode: cross-server tile stealing "
                         "between supersteps (runtime.scheduler)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="superstep-boundary checkpoints here "
                         "(DESIGN.md §12); enables --resume")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every K superstep boundaries "
                         "(0 = final checkpoint only)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint "
                         "(bit-identical; --servers may differ from the "
                         "saved run)")
    ap.add_argument("--preemptible", action="store_true",
                    help="SIGTERM => save at the next superstep boundary "
                         "and exit for later --resume")
    ap.add_argument("--on-failure", default="fail",
                    choices=["fail", "restart", "shrink"],
                    help="cluster mode: rank-death policy (restart/shrink "
                         "resume from --checkpoint-dir)")
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--inject", action="append", default=None,
                    metavar="SPEC",
                    help="fault-injection spec (runtime.faults), "
                         "repeatable — fault drills only")
    ap.add_argument("--verify-clean", action="store_true",
                    help="cluster mode: diff the run against an "
                         "uninterrupted in-process rerun")
    ap.add_argument("--admit", action="append", default=None,
                    metavar="SS:SEEDS",
                    help="scripted mid-run admission for batched apps "
                         "(DESIGN.md §13), repeatable: '4:17,42' splices "
                         "those query seeds into retired [V,Q] slots at "
                         "the end of superstep 4")
    ap.add_argument("--serve", action="store_true",
                    help="run as a long-lived graph-query service "
                         "(DESIGN.md §13): queries admit into retired "
                         "[V,Q] slots mid-run; SIGTERM drains gracefully")
    ap.add_argument("--q-slots", type=int, default=8,
                    help="serve mode: live query columns per session")
    ap.add_argument("--min-fill", type=int, default=1,
                    help="serve mode: batch admissions until this many "
                         "queries are queued (amortizes the all-dirty "
                         "superstep an admission forces) ...")
    ap.add_argument("--max-wait-ms", type=float, default=50.0,
                    help="... but admit anyway after this long")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="serve mode: per-query deadline; overdue "
                         "queries drain with partial results")
    ap.add_argument("--serve-requests", type=int, default=32,
                    help="serve mode: scripted workload size "
                         "(0 = serve idle until SIGTERM)")
    ap.add_argument("--serve-qps", type=float, default=0.0,
                    help="serve mode: offered arrival rate for the "
                         "scripted workload (0 = submit all upfront)")
    ap.add_argument("--serve-apps", default="ppr,msbfs",
                    help="serve mode: comma list of batched apps the "
                         "scripted workload mixes")
    ap.add_argument("--drain-mode", default="finish",
                    choices=["finish", "checkpoint"],
                    help="serve mode: on SIGTERM, run in-flight queries "
                         "to convergence or checkpoint them for a "
                         "--resume'd service restart")
    ap.add_argument("--serve-http", action="store_true",
                    help="serve mode with the JSON-over-HTTP frontend "
                         "(serve/http.py, DESIGN.md §16): POST /v1/query, "
                         "GET /v1/query/<rid>, /v1/stats, /healthz; "
                         "implies --serve and idles until SIGTERM")
    ap.add_argument("--host", default="127.0.0.1",
                    help="HTTP frontend bind address")
    ap.add_argument("--port", type=int, default=8080,
                    help="HTTP frontend port (0 = ephemeral; the bound "
                         "port is printed as 'serving http on ...')")
    ap.add_argument("--tenants", default=None, metavar="NAME:W,...",
                    help="serve mode: tenant weights for deficit-round-"
                         "robin fair admission, e.g. 'alice:3,bob:1' "
                         "(unknown tenants serve at weight 1)")
    ap.add_argument("--result-cache", type=int, default=0,
                    metavar="ENTRIES",
                    help="serve mode: exact result-cache capacity keyed "
                         "by (app, seed, graph fingerprint); repeated "
                         "seeds return without consuming a [V,Q] slot "
                         "(0 = off)")
    ap.add_argument("--drain-linger-ms", type=float, default=500.0,
                    help="HTTP serve mode: keep GET /v1/query/<rid> "
                         "answering this long after the drain so "
                         "clients can collect in-flight results")
    args = ap.parse_args(argv)

    if args.serve or args.serve_http:
        return _serve_main(args)

    if args.cluster:
        from repro.launch import cluster as cluster_mod

        cl_argv = ["--app", args.app, "--graph", args.graph,
                   "--vertices", str(args.vertices),
                   "--edges", str(args.edges),
                   "--tile-size", str(args.tile_size),
                   "--servers", str(args.servers),
                   "--transport", args.transport,
                   "--supersteps", str(args.supersteps),
                   "--comm-mode", args.comm_mode,
                   "--cache-mb", str(args.cache_mb),
                   "--cache-mode", str(args.cache_mode),
                   "--cache-policy", args.cache_policy,
                   "--cache-promote-hits", str(args.cache_promote_hits),
                   "--prefetch-depth", str(args.prefetch_depth),
                   "--prefetch-workers", str(args.prefetch_workers),
                   "--stack-size", str(args.stack_size),
                   "--num-intervals", str(args.num_intervals),
                   "--disk-mode", str(args.disk_mode),
                   "--seed", str(args.seed),
                   "--checkpoint-every", str(args.checkpoint_every),
                   "--on-failure", args.on_failure,
                   "--max-restarts", str(args.max_restarts)]
        for flag, on in (("--steal", args.steal),
                         ("--pipeline", args.pipeline),
                         ("--static-order", args.static_order),
                         ("--no-interval-order", args.no_interval_order),
                         ("--reuse", args.reuse),
                         ("--resume", args.resume),
                         ("--preemptible", args.preemptible),
                         ("--verify-clean", args.verify_clean)):
            if on:
                cl_argv.append(flag)
        if args.checkpoint_dir:
            cl_argv += ["--checkpoint-dir", args.checkpoint_dir]
        for spec in args.inject or ():
            cl_argv += ["--inject", spec]
        for spec in args.admit or ():
            cl_argv += ["--admit", spec]
        if args.store:
            cl_argv += ["--store", args.store]
        if args.queries:
            cl_argv += ["--queries", str(args.queries)]
        if args.seeds:
            cl_argv += ["--seeds", args.seeds]
        if args.vertex_memory_budget is not None:
            cl_argv += ["--vertex-memory-budget",
                        str(args.vertex_memory_budget)]
        return cluster_mod.main(cl_argv)

    if args.reuse and args.store:
        store = TileStore(args.store)
        store.load_meta()
    else:
        store = build_store(args)

    cfg = EngineConfig(
        num_servers=args.servers,
        cache_capacity_bytes=int(args.cache_mb * 1e6),
        cache_mode=args.cache_mode if args.cache_mode == "auto"
        else int(args.cache_mode),
        comm_mode=args.comm_mode,
        cache_policy=args.cache_policy,
        cache_promote_hits=args.cache_promote_hits,
        cache_aware_order=not args.static_order,
        seg_impl=args.seg_impl,
        kernel_autotune=args.kernel_autotune,
        max_supersteps=args.supersteps,
        pipeline=args.pipeline,
        prefetch_depth=args.prefetch_depth,
        prefetch_workers=args.prefetch_workers,
        stack_size=args.stack_size,
        vertex_memory_budget=(None if args.vertex_memory_budget is None
                              else int(args.vertex_memory_budget * 1e6)),
        num_intervals=args.num_intervals,
        interval_aware_order=not args.no_interval_order,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=args.resume,
        preemptible=args.preemptible,
    )
    if args.inject:
        from repro.runtime import faults

        cfg = dataclasses.replace(cfg, fault_plan=faults.parse_plan(
            args.inject))
    if args.admit:
        from repro.launch.cluster import parse_admit_plan

        cfg = dataclasses.replace(cfg,
                                  admit_plan=parse_admit_plan(args.admit))
    eng = OutOfCoreEngine(store, cfg)
    batched = args.app in ("ppr", "msbfs", "landmarks")
    if batched:
        if args.seeds:
            seeds = tuple(int(s) for s in args.seeds.split(","))
        else:
            q = args.queries or 8
            rng = np.random.default_rng(args.seed)
            seeds = tuple(int(v) for v in
                          rng.choice(args.vertices, size=q, replace=False))
        key = {"ppr": "seeds", "msbfs": "sources", "landmarks": "landmarks"}
        prog = APPS[args.app](**{key[args.app]: seeds})
    elif args.queries or args.seeds:
        raise SystemExit(f"--queries/--seeds only apply to batched apps "
                         f"(ppr/msbfs/landmarks), not {args.app}")
    else:
        prog = APPS[args.app]()
    t0 = time.time()
    res = eng.run(prog)
    dt = time.time() - t0
    print(f"{args.app}: {res.supersteps} supersteps in {dt:.1f}s "
          f"(mean {res.mean_superstep_seconds()*1000:.0f} ms/superstep, "
          f"converged={res.converged})")
    if args.kernel_autotune and eng.kernel_choice is not None:
        c = eng.kernel_choice
        print(f"  kernel autotune [{prog.combine}, Q="
              f"{getattr(prog, 'num_queries', 1)}]: BE={c.block_e} "
              f"BR={c.block_r} stack={c.stack_size} ({c.bound}-bound, "
              f"ceiling {c.edges_per_s:.2e} edges/s)")
    if batched:
        q = len(seeds)
        io = sum(x.disk_bytes_read for x in res.history)
        retired = [(g, int(s)) for g, s in enumerate(res.per_query_supersteps)]
        print(f"  {q} queries in one edge pass: "
              f"tile I/O {io/1e6:.1f} MB total = {io/q/1e6:.2f} MB/query, "
              f"{dt/q*1000:.0f} ms/query; per-query supersteps "
              f"{[s for _, s in retired]}")
    if not res.history:
        # --resume against a FINAL checkpoint short-circuits: the stored
        # result is returned without executing a superstep, so there are
        # no per-superstep stats to report.
        print("  resumed a finished run from its final checkpoint "
              "(no supersteps executed)")
        return res
    h = res.history[-1]
    print(f"  cache hit ratio {h.cache_hit_ratio:.2f}, "
          f"net {sum(x.network_bytes for x in res.history)/1e6:.1f} MB total, "
          f"mode={eng.cache_mode}, "
          f"disk-stall {res.disk_stall_fraction()*100:.0f}% of wall time"
          f"{' (pipelined)' if args.pipeline else ''}")
    if args.vertex_memory_budget is not None:
        vs = eng.vstate.stats
        faults = sum(x.vstate_faults for x in res.history)
        spill = sum(x.vstate_spill_bytes for x in res.history)
        load = sum(x.vstate_load_bytes for x in res.history)
        print(f"  vertex state [{eng.vstate.num_intervals} intervals, "
              f"budget {args.vertex_memory_budget:g} MB]: "
              f"{faults} interval faults, {load/1e6:.1f} MB faulted in, "
              f"{spill/1e6:.1f} MB spilled to disk, "
              f"{vs.dirty_writebacks} dirty writebacks")
    if args.cache_policy != "lru":
        promo = sum(x.cache_promotions for x in res.history)
        demo = sum(x.cache_demotions for x in res.history)
        tiers = ", ".join(
            f"{name}: {d['tiles']} tiles/{d['bytes']/1e6:.1f} MB "
            f"({d['hits']} hits)"
            for name, d in sorted(h.cache_tiers.items()))
        print(f"  cache tiers [{args.cache_policy}]: {tiers or 'empty'}; "
              f"{promo} promotions, {demo} demotions")
    return res


if __name__ == "__main__":
    main()
