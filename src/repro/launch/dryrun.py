import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# The two lines above MUST stay first: jax locks the device count at first
# init, and only this entry point may see 512 placeholder devices.
#
# Per cell this produces:
#   * compiled.memory_analysis()  — bytes/device (proves it fits)
#   * compiled.cost_analysis()    — per-device FLOPs / bytes for Roofline
#   * collective bytes parsed from the optimized HLO
#   * the three roofline terms + bottleneck + MFU bound
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen3-14b --cell train_4k --mesh single
#   python -m repro.launch.dryrun --all            # orchestrate subprocesses
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPE_CELLS
from repro.launch import mesh as meshlib
from repro.roofline import analysis as ra

RESULTS_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "results", "dryrun"))


def _mesh(kind: str):
    return meshlib.make_production_mesh(multi_pod=(kind == "multi"))


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
            "peak_bytes_estimate": int(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception as e:  # backend without memory_analysis
        return {"error": str(e)}


def run_cell(arch: str, cell_name: str, mesh_kind: str,
             collect_hlo: bool = True) -> dict:
    from repro.models.model_zoo import build_model, param_count, active_param_count
    from repro.serve import serve_step
    from repro.train import train_step as ts

    cell = SHAPE_CELLS[cell_name]
    ok, reason = registry.cell_runnable(arch, cell_name)
    if not ok:
        return {"arch": arch, "cell": cell_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    cfg = registry.get_config(arch)
    mesh = _mesh(mesh_kind)
    n_chips = mesh.devices.size
    run = registry.default_run_config(arch, cell, n_chips)
    t0 = time.time()

    # active/total param counts from shapes only (no allocation)
    model = build_model(cfg, run)
    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    n_params = param_count(pshapes)
    n_active = active_param_count(cfg, pshapes)
    embed_p = cfg.vocab_size * cfg.d_model

    if cell.kind == "train":
        step, init_state, sh = ts.build_train_step(cfg, run, mesh=mesh)
        state_shapes = jax.eval_shape(init_state, jax.random.key(0))
        batch_shapes = registry.input_specs(cfg, cell)
        lowered = step.lower(state_shapes, batch_shapes)
        tokens = cell.global_batch * cell.seq_len
        mflops = ra.model_flops("train", n_active, tokens, embed_p)
    else:
        fns = serve_step.build_serve_fns(
            cfg, run, mesh=mesh, max_len=cell.seq_len,
            batch=cell.global_batch)
        cshapes = jax.eval_shape(fns["init_cache"])
        if cell.kind == "prefill":
            batch_shapes = registry.input_specs(cfg, cell)
            lowered = fns["prefill"].lower(pshapes, cshapes, batch_shapes)
            tokens = cell.global_batch * cell.seq_len
            mflops = ra.model_flops("prefill", n_active, tokens, embed_p)
        else:  # decode: one new token against a seq_len cache
            if cfg.encoder_layers > 0:
                enc_len = cell.seq_len // 2
                bshapes = {
                    "tokens": jax.ShapeDtypeStruct(
                        (cell.global_batch, cell.seq_len - 1), jnp.int32),
                    "enc_frames": jax.ShapeDtypeStruct(
                        (cell.global_batch, enc_len, cfg.d_model), jnp.bfloat16),
                }
                cshapes = jax.eval_shape(
                    lambda p, c, b: fns["prefill"](p, c, b)[0],
                    pshapes, cshapes, bshapes)
            tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
            clen = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fns["decode"].lower(pshapes, cshapes, tok, clen)
            mflops = ra.model_flops("decode", n_active, cell.global_batch,
                                    embed_p)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # raw XLA numbers (loop bodies counted once — kept for reference)
    xla_flops, xla_bytes = ra.cost_analysis_terms(compiled)
    hlo = compiled.as_text()
    _save_hlo(arch, cell_name, mesh_kind, hlo)
    naive_coll = ra.collective_bytes(hlo)
    # trip-count-aware re-analysis (the numbers the roofline uses)
    from repro.roofline import hlo_cost
    cost = hlo_cost.analyze(hlo)
    terms = ra.roofline(cost.flops, cost.bytes, cost.coll_bytes,
                        n_chips, mflops,
                        hbm_bytes_fused=cost.bytes_fused)
    mem = _mem_analysis_dict(compiled)

    return {
        "arch": arch, "cell": cell_name, "mesh": mesh_kind,
        "status": "ok",
        "n_chips": n_chips,
        "params": n_params, "active_params": n_active,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "collectives": {k: int(v) for k, v in cost.coll_by_kind.items()},
        "collective_ops": naive_coll.get("op_counts", {}),
        "unknown_trip_loops": cost.unknown_trip_loops,
        "xla_cost": {"flops_per_dev_loopbody_once": xla_flops,
                     "bytes_per_dev_loopbody_once": xla_bytes},
        "roofline": terms.as_dict(),
        "run_config": {"sharding_mode": run.sharding_mode,
                       "microbatch": run.microbatch, "remat": run.remat},
    }


def _result_path(arch, cell, mesh_kind):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{cell}__{mesh_kind}.json")


def _hlo_path(arch, cell, mesh_kind):
    d = os.path.join(RESULTS_DIR, "hlo")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{cell}__{mesh_kind}.hlo.zst")


def _save_hlo(arch, cell, mesh_kind, text: str) -> None:
    from repro.compat import zstd_compress

    with open(_hlo_path(arch, cell, mesh_kind), "wb") as f:
        f.write(zstd_compress(text.encode(), level=3))


def load_hlo(arch, cell, mesh_kind) -> str:
    from repro.compat import zstd_decompress

    with open(_hlo_path(arch, cell, mesh_kind), "rb") as f:
        return zstd_decompress(f.read()).decode()


def reanalyze(arch, cell, mesh_kind) -> dict:
    """Recompute the roofline terms from saved HLO (no recompilation) —
    used when the cost model improves."""
    from repro.roofline import hlo_cost

    path = _result_path(arch, cell, mesh_kind)
    res = json.load(open(path))
    if res.get("status") != "ok":
        return res
    hlo = load_hlo(arch, cell, mesh_kind)
    cost = hlo_cost.analyze(hlo)
    terms = ra.roofline(cost.flops, cost.bytes, cost.coll_bytes,
                        res["n_chips"], res["roofline"]["model_flops_total"],
                        hbm_bytes_fused=cost.bytes_fused)
    res["roofline"] = terms.as_dict()
    res["collectives"] = {k: int(v) for k, v in cost.coll_by_kind.items()}
    res["unknown_trip_loops"] = cost.unknown_trip_loops
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute rooflines from saved HLO, no compiles")
    args = ap.parse_args()

    if args.reanalyze:
        import glob as _glob
        n = 0
        for p in sorted(_glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
            base = os.path.basename(p)[:-5]
            arch, cell, mk = base.split("__")
            if os.path.exists(_hlo_path(arch, cell, mk)):
                reanalyze(arch, cell, mk)
                n += 1
        print(f"reanalyzed {n} cells")
        return

    if args.all:
        jobs = []
        for arch in registry.ARCH_IDS:
            for cell in SHAPE_CELLS:
                for mk in args.meshes.split(","):
                    jobs.append((arch, cell, mk))
        done = ok = 0
        for arch, cell, mk in jobs:
            path = _result_path(arch, cell, mk)
            if os.path.exists(path) and not args.force:
                done += 1
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--cell", cell, "--mesh", mk]
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env={**os.environ, "PYTHONPATH": "src",
                                    "REPRO_DRYRUN_DIR": RESULTS_DIR})
            if r.returncode == 0:
                ok += 1
            else:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "cell": cell, "mesh": mk,
                               "status": "error",
                               "error": r.stderr[-4000:]}, f, indent=1)
                print(f"FAIL {arch} {cell} {mk}", flush=True)
        print(f"all done: {ok} ran, {done} cached")
        return

    res = None
    try:
        res = run_cell(args.arch, args.cell, args.mesh)
    except Exception:
        res = {"arch": args.arch, "cell": args.cell, "mesh": args.mesh,
               "status": "error", "error": traceback.format_exc()[-4000:]}
    with open(_result_path(args.arch, args.cell, args.mesh), "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps({k: v for k, v in res.items()
                      if k in ("arch", "cell", "mesh", "status", "compile_s")}))
    if res["status"] == "error":
        print(res["error"][-2000:], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
