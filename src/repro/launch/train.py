"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised: registry configs, synthetic deterministic data
pipeline with prefetch, AdamW + schedule, microbatching, checkpointing
every N steps, preemption-safe resume (rerun the same command after an
interruption and it continues), optional gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import RunConfig, ShapeCell
from repro.runtime.ft import FaultTolerantLoop
from repro.train import data as datalib
from repro.train import train_step as ts
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "topk"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch, reduced=args.reduced)
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model,
                         d_ff=int(args.d_model * 8 / 3 / 128) * 128 or 128,
                         head_dim=64,
                         num_heads=max(args.d_model // 64, 1),
                         num_kv_heads=max(args.d_model // 128, 1))
    if args.layers:
        overrides["num_layers"] = args.layers
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    run = RunConfig(remat="block", microbatch=args.microbatch,
                    q_chunk=min(512, args.seq), kv_chunk=min(512, args.seq),
                    loss_chunk=min(512, args.seq),
                    grad_compression=args.grad_compression,
                    compute_dtype="float32")
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 10),
                        decay_steps=args.steps)

    step_fn, init_state, _ = ts.build_train_step(cfg, run, opt_cfg, mesh=None)
    source = datalib.SyntheticLM(cfg, args.batch, args.seq, seed=args.seed)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    ft = FaultTolerantLoop(ckpt, save_every=args.ckpt_every) if ckpt else None

    t0 = time.time()
    if ft is not None:
        start, state = ft.resume_or_init(
            lambda: init_state(jax.random.key(args.seed)))
        if start:
            print(f"resumed from checkpoint at step {start}")
    else:
        start, state = 0, init_state(jax.random.key(args.seed))
    print(f"init in {time.time()-t0:.1f}s; params = "
          f"{sum(np.prod(x.shape) for x in jax.tree.leaves(state['params'])):,}")

    prefetch = datalib.Prefetcher(source, start_step=start)
    losses = []
    t_loop = time.time()
    tokens_per_step = args.batch * args.seq
    try:
        for step in range(start, args.steps):
            _, batch = prefetch.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, stats = step_fn(state, batch)
            losses.append(float(stats["loss"]))
            if ft is not None and ft.maybe_save(step + 1, state):
                print(f"[ckpt] step {step+1}")
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t_loop
                done = step + 1 - start
                print(f"step {step+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                      f"lr {float(stats['lr']):.2e} gnorm {float(stats['grad_norm']):.2f} "
                      f"| {done*tokens_per_step/dt:,.0f} tok/s")
            if ft is not None and ft.should_stop():
                print("preempted: checkpointed and exiting")
                ft.maybe_save(step + 1, state, force=True)
                break
    finally:
        prefetch.close()
    if ft is not None:
        ft.maybe_save(args.steps, state, force=True)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
