"""Multi-process cluster driver — real N-server GraphH runs (DESIGN.md §11).

    PYTHONPATH=src python -m repro.launch.cluster --app pagerank \
        --vertices 100000 --edges 1000000 --servers 4 --transport shm

Spawns N server processes (multiprocessing ``spawn`` — safe with jax),
each running the out-of-core engine (``engine.OutOfCoreEngine`` with
``server_rank``) over its stage-2 tile share of one shared TileStore, and
exchanging per-superstep vertex updates through a real transport
(``core.transport``: shared-memory ring, or TCP sockets via ``--transport
tcp``).  Results are bit-identical to the single-process engine — the
driver verifies this across ranks on every run.

A single launch amortizes process/jit startup over many programs: pass
several vertex programs and the same N servers execute them back to back
(the exchange sequence numbers keep the BSP barriers aligned across runs).
"""
from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import os
import shutil
import tempfile
import time
import traceback
from typing import Optional

import numpy as np

from repro.core.engine import EngineConfig, OutOfCoreEngine, RunResult


@dataclasses.dataclass
class ClusterConfig:
    """Knobs for a multi-process cluster run (engine knobs ride along in
    ``engine`` — its ``num_servers``/``server_rank`` are overridden per
    spawned process).  See docs/OPERATIONS.md for tuning guidance."""

    num_servers: int = 2
    #: "shm" = mmap shared-memory ring per server pair (single host);
    #: "tcp" = sockets with file rendezvous (works across hosts sharing
    #: only a filesystem)
    transport: str = "shm"
    #: per-directed-channel ring capacity in bytes (shm transport)
    ring_capacity: int = 1 << 22
    #: cross-server tile stealing between supersteps (scheduler.
    #: rebalance_assignment); requires engine_mode="tiled"
    steal: bool = False
    straggler_factor: float = 1.5
    #: per-superstep exchange timeout inside each server (seconds)
    timeout_seconds: float = 180.0
    #: parent-side timeout for the whole launch (seconds)
    launch_timeout_seconds: float = 900.0
    #: JAX platform forced into the server processes (None = inherit)
    jax_platforms: Optional[str] = "cpu"
    #: what to do when a rank dies or is preempted mid-run (DESIGN.md §12):
    #: "fail" = raise ClusterFailure; "restart" = tear down, respawn the
    #: same N resuming from the latest checkpoint; "shrink" = respawn with
    #: N - dead servers (elastic resize at the superstep boundary)
    on_failure: str = "fail"
    #: supervised restart budget before giving up and re-raising
    max_restarts: int = 2
    #: engine template; num_servers/server_rank are overridden per rank
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)


class ClusterFailure(RuntimeError):
    """A cluster attempt died: one or more ranks failed, were killed, or
    were preempted.  Carries enough forensics for supervision (and tests):
    ``dead_ranks``, ``pids`` (of every spawned rank, dead or reaped), and
    ``preempted`` (True when the rank saved a checkpoint and exited
    cleanly on SIGTERM rather than crashing)."""

    def __init__(self, message: str, dead_ranks=(), pids=(),
                 preempted: bool = False):
        super().__init__(message)
        self.dead_ranks = list(dead_ranks)
        self.pids = list(pids)
        self.preempted = preempted


@dataclasses.dataclass
class ClusterResult:
    """Parent-side result of :func:`run_cluster`."""

    results: list            # rank 0's RunResult per program
    rank_reports: list       # one dict per rank: wire/raw bytes, steals, s
    # final values bit-identical across all ranks; always True on a
    # returned result (run_cluster RAISES on divergence), kept so callers
    # can assert the invariant explicitly
    verified: bool
    #: supervised restarts consumed before this result was produced
    restarts: int = 0
    #: server count of the attempt that finished (< num_servers after a
    #: shrink resize)
    final_servers: int = 0

    def wire_bytes_per_superstep(self, app_index: int = 0) -> list:
        """Cluster-total measured wire bytes per superstep for one app."""
        return [h.wire_bytes for h in self.results[app_index].history]


def _server_main(rank: int, store_root: str, cfg: ClusterConfig,
                 progs: list, run_dir: str, conn) -> None:
    """Entry point of one spawned server process: build transport +
    exchange + engine for ``rank``, run every program, ship results back
    through ``conn``.  Errors are reported (never silently dropped) so the
    parent can tear the cluster down."""
    from repro.core import transport as transport_mod
    from repro.core.distributed import ClusterExchange
    from repro.graphio.formats import TileStore
    from repro.runtime.ft import Preempted

    transport = None
    exchange = None
    try:
        store = TileStore(store_root)
        store.load_meta()
        # checkpoints go to per-program subdirectories (configured below,
        # after resume can remap the assignment but before the exchange
        # snapshot), so the engine ctor must not claim the shared root
        ecfg = dataclasses.replace(
            cfg.engine, num_servers=cfg.num_servers, server_rank=rank,
            checkpoint_dir=None)
        if cfg.steal and ecfg.engine_mode != "tiled":
            raise ValueError("tile stealing requires engine_mode='tiled' "
                             "(stacked/merged pin tiles to devices)")
        eng = OutOfCoreEngine(store, ecfg)
        transport = transport_mod.make_transport(
            cfg.transport, rank, cfg.num_servers, run_dir)
        if eng.fault is not None:
            # same injector instance as the engine's sites, so once-specs
            # share one claim namespace per rank
            transport = transport_mod.FaultInjectingTransport(
                transport, eng.fault)
        exchange = ClusterExchange(
            transport, comm_mode=ecfg.comm_mode,
            compressor=ecfg.comm_compressor, threshold=ecfg.comm_threshold,
            assignment=eng.assignment,
            edges_per_tile=eng.plan.edges_per_tile,
            steal=cfg.steal, straggler_factor=cfg.straggler_factor,
            timeout=cfg.timeout_seconds)
        eng.exchange = exchange
        results = []
        t0 = time.perf_counter()
        for i, prog in enumerate(progs):
            if cfg.engine.checkpoint_dir:
                eng.configure_checkpoint(
                    os.path.join(cfg.engine.checkpoint_dir, f"prog_{i:02d}"))
                # resume may have adopted a remapped assignment (elastic
                # N->M resize); refresh the exchange's snapshot
                exchange.assignment = [list(a) for a in eng.assignment]
            results.append(eng.run(prog))
        report = dict(
            rank=rank,
            seconds=time.perf_counter() - t0,
            # what THIS rank put on the wire (cluster totals live in the
            # per-superstep history of every rank's RunResult)
            wire_bytes=exchange.sent_wire_bytes,
            raw_bytes=exchange.sent_raw_bytes,
            steal_moves=exchange.steal_moves,
            final_assignment=[list(a) for a in eng.assignment],
        )
        conn.send(("ok", results, report))
    except Preempted as e:
        # state is saved (the engine checkpointed before raising): report
        # the resume boundary and exit cleanly so supervision can resume
        try:
            conn.send(("preempted", e.superstep, dict(rank=rank)))
        except (OSError, ValueError):
            pass
        raise SystemExit(0)
    except BaseException:
        try:
            conn.send(("error", traceback.format_exc(), None))
        except (OSError, ValueError):
            pass
        raise SystemExit(1)
    finally:
        if exchange is not None:
            exchange.close()
        if transport is not None:
            transport.close()
        conn.close()


def _teardown(procs) -> None:
    """Bounded-time teardown: terminate, then escalate to SIGKILL.

    A rank blocked inside a transport recv can ignore SIGTERM for the
    socket timeout; the kill escalation guarantees no child outlives the
    parent by more than ~10s and none leaks."""
    for p in procs:
        if p.is_alive():
            p.terminate()
    for p in procs:
        p.join(timeout=5.0)
    for p in procs:
        if p.is_alive():
            p.kill()
            p.join(timeout=5.0)


def _run_attempt(store_root: str, progs: list, cfg: ClusterConfig,
                 run_dir: str) -> ClusterResult:
    """One supervised attempt: spawn N ranks, collect results, raise
    ClusterFailure (after bounded teardown) when any rank dies, errors,
    or reports preemption."""
    from repro.core import transport as transport_mod

    n = cfg.num_servers
    if cfg.transport == "shm":
        transport_mod.create_ring_files(run_dir, n, cfg.ring_capacity)

    ctx = mp.get_context("spawn")
    saved_env = {k: os.environ.get(k) for k in ("JAX_PLATFORMS",)}
    if cfg.jax_platforms is not None:
        # children inherit the parent env at spawn time; restored below
        os.environ["JAX_PLATFORMS"] = cfg.jax_platforms
    procs, conns = [], []
    try:
        for rank in range(n):
            parent_conn, child_conn = ctx.Pipe(duplex=False)
            p = ctx.Process(
                target=_server_main,
                args=(rank, store_root, cfg, progs, run_dir, child_conn),
                name=f"graphh-server-{rank}", daemon=True)
            p.start()
            child_conn.close()
            procs.append(p)
            conns.append(parent_conn)
        for k, v in saved_env.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v

        pids = [p.pid for p in procs]
        deadline = time.monotonic() + cfg.launch_timeout_seconds
        payloads: list = [None] * n
        pending = set(range(n))
        while pending:
            for r in list(pending):
                if conns[r].poll(0.1):
                    try:
                        payloads[r] = conns[r].recv()
                    except EOFError:
                        raise ClusterFailure(
                            f"cluster server {r} died (exit code "
                            f"{procs[r].exitcode}) without reporting",
                            dead_ranks=[r], pids=pids)
                    pending.discard(r)
                    if payloads[r][0] == "error":
                        # fail fast: peers are now blocked on this rank's
                        # missing frames; the finally below reaps them
                        raise ClusterFailure(
                            f"cluster server {r} failed:\n{payloads[r][1]}",
                            dead_ranks=[r], pids=pids)
                    if payloads[r][0] == "preempted":
                        raise ClusterFailure(
                            f"cluster server {r} preempted; checkpoint "
                            f"saved at superstep boundary {payloads[r][1]}",
                            dead_ranks=[r], pids=pids, preempted=True)
                elif not procs[r].is_alive() and not conns[r].poll(0.1):
                    raise ClusterFailure(
                        f"cluster server {r} died (exit code "
                        f"{procs[r].exitcode}) without reporting",
                        dead_ranks=[r], pids=pids)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"cluster launch timed out; pending ranks {sorted(pending)}")
        for p in procs:
            p.join(timeout=30.0)
    finally:
        _teardown(procs)

    all_results = [payloads[r][1] for r in range(n)]
    reports = [payloads[r][2] for r in range(n)]
    diverged = [(a, r) for a in range(len(progs)) for r in range(1, n)
                if not np.array_equal(all_results[0][a].values,
                                      all_results[r][a].values)]
    if diverged:
        raise RuntimeError(
            "cluster ranks diverged — final values not bit-identical for "
            f"(app index, rank): {diverged}; this is a wrong answer, not "
            "a degraded one (transport/decode bug or broken hardware)")
    return ClusterResult(results=all_results[0], rank_reports=reports,
                         verified=True, final_servers=n)


def run_cluster(store_root: str, progs: list,
                cfg: ClusterConfig = ClusterConfig(),
                run_dir: Optional[str] = None,
                keep_run_dir: bool = False) -> ClusterResult:
    """Run ``progs`` (VertexProgram instances) on an N-server cluster over
    the tile store at ``store_root``.

    The parent creates the rendezvous directory (+ shared-memory ring
    files for the shm transport), spawns the N server processes, collects
    each rank's results, verifies the final value arrays are bit-identical
    across ranks (divergence RAISES — a divergent cluster run is a wrong
    answer, never a degraded one), and returns rank 0's results with
    per-rank wire/steal reports.

    Failure handling follows ``cfg.on_failure`` (DESIGN.md §12): with
    ``"fail"`` any rank failure tears the cluster down and raises
    ClusterFailure with that rank's traceback; ``"restart"`` respawns the
    same N (resuming from the latest checkpoint when
    ``cfg.engine.checkpoint_dir`` is set — otherwise a clean rerun, which
    is equally bit-identical, just slower); ``"shrink"`` respawns with
    ``N - dead`` servers, remapping the checkpointed assignment at the
    superstep boundary (elastic resize).  Each attempt gets a fresh
    rendezvous subdirectory — stale ring frames from a killed attempt
    must never be replayed into the next."""
    base_dir = run_dir or tempfile.mkdtemp(prefix="graphh_cluster_")
    own_dir = run_dir is None
    acfg = cfg
    restarts = 0
    try:
        while True:
            attempt_dir = os.path.join(base_dir, f"attempt_{restarts:02d}")
            os.makedirs(attempt_dir, exist_ok=True)
            try:
                res = _run_attempt(store_root, progs, acfg, attempt_dir)
                res.restarts = restarts
                return res
            except ClusterFailure as e:
                if (cfg.on_failure not in ("restart", "shrink")
                        or restarts >= cfg.max_restarts):
                    raise
                restarts += 1
                new_n = acfg.num_servers
                if cfg.on_failure == "shrink":
                    new_n = max(1, acfg.num_servers -
                                len(set(e.dead_ranks)))
                # resume only works with a checkpoint directory; without
                # one the restart is a clean rerun from superstep 0
                resume = bool(acfg.engine.checkpoint_dir)
                acfg = dataclasses.replace(
                    acfg, num_servers=new_n,
                    engine=dataclasses.replace(acfg.engine, resume=resume))
    finally:
        if own_dir and not keep_run_dir:
            shutil.rmtree(base_dir, ignore_errors=True)


def parse_admit_plan(specs) -> Optional[tuple]:
    """``--admit`` specs -> ``EngineConfig.admit_plan``: each
    ``"SS:seed1,seed2"`` entry schedules those query seeds for admission
    at the end of superstep SS (batched apps only; DESIGN.md §13)."""
    if not specs:
        return None
    plan = []
    for spec in specs:
        try:
            ss, seeds = spec.split(":", 1)
            plan.append((int(ss), tuple(int(s)
                                        for s in seeds.split(","))))
        except ValueError:
            raise SystemExit(f"--admit {spec!r}: expected 'SS:seed,seed'")
    return tuple(sorted(plan))


def _build_progs(args) -> list:
    """Vertex program list for the CLI (mirrors launch.graph seeding)."""
    from repro.core.apps import APPS

    batched = args.app in ("ppr", "msbfs", "landmarks")
    if batched:
        if args.seeds:
            seeds = tuple(int(s) for s in args.seeds.split(","))
        else:
            q = args.queries or 8
            rng = np.random.default_rng(args.seed)
            seeds = tuple(int(v) for v in
                          rng.choice(args.vertices, size=q, replace=False))
        key = {"ppr": "seeds", "msbfs": "sources", "landmarks": "landmarks"}
        return [APPS[args.app](**{key[args.app]: seeds})]
    if args.queries or args.seeds:
        raise SystemExit(f"--queries/--seeds only apply to batched apps "
                         f"(ppr/msbfs/landmarks), not {args.app}")
    return [APPS[args.app]()]


def main(argv=None) -> ClusterResult:
    """CLI: build (or reuse) a tile store, run one app on an N-server
    cluster, print per-superstep wire bytes and per-rank reports."""
    from repro.core.apps import APPS
    from repro.launch.graph import build_store
    from repro.graphio.formats import TileStore

    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="pagerank", choices=sorted(APPS))
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "uniform", "banded"])
    ap.add_argument("--vertices", type=int, default=100_000)
    ap.add_argument("--edges", type=int, default=1_000_000)
    ap.add_argument("--tile-size", type=int, default=65536)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--transport", default="shm", choices=["shm", "tcp"])
    ap.add_argument("--steal", action="store_true",
                    help="cross-server tile stealing between supersteps")
    ap.add_argument("--supersteps", type=int, default=30)
    ap.add_argument("--comm-mode", default="hybrid",
                    choices=["dense", "sparse", "hybrid"])
    ap.add_argument("--cache-mb", type=float, default=1024)
    ap.add_argument("--cache-mode", default="auto")
    ap.add_argument("--cache-policy", default="lru",
                    choices=["lru", "tiered", "cost-aware"])
    ap.add_argument("--cache-promote-hits", type=int, default=2)
    ap.add_argument("--static-order", action="store_true")
    ap.add_argument("--pipeline", action="store_true")
    ap.add_argument("--prefetch-depth", type=int, default=4)
    ap.add_argument("--prefetch-workers", type=int, default=2)
    ap.add_argument("--stack-size", type=int, default=4)
    ap.add_argument("--num-intervals", type=int, default=0)
    ap.add_argument("--no-interval-order", action="store_true")
    ap.add_argument("--disk-mode", type=int, default=1)
    ap.add_argument("--store", default=None)
    ap.add_argument("--reuse", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--seeds", default=None)
    ap.add_argument("--vertex-memory-budget", type=float, default=None,
                    metavar="MB")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for superstep checkpoints (shared by "
                         "all ranks; enables --resume and supervised "
                         "restart, DESIGN.md §12)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="write a checkpoint every K superstep boundaries "
                         "(0 = final checkpoint only)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--checkpoint-dir (bit-identical to the "
                         "uninterrupted run; N may differ from the saved "
                         "run — the assignment is remapped)")
    ap.add_argument("--preemptible", action="store_true",
                    help="SIGTERM => checkpoint at the next superstep "
                         "boundary and exit cleanly for later --resume")
    ap.add_argument("--on-failure", default="fail",
                    choices=["fail", "restart", "shrink"],
                    help="rank-death policy: fail fast, restart same N "
                         "from the latest checkpoint, or shrink to the "
                         "survivors (elastic resize)")
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--inject", action="append", default=None,
                    metavar="SPEC",
                    help="fault-injection spec, repeatable: e.g. "
                         "'rank=1,superstep=2,site=superstep,kind=kill' "
                         "(runtime.faults.parse_spec); once-markers "
                         "persist under --checkpoint-dir so a fault does "
                         "not re-fire after a supervised restart")
    ap.add_argument("--verify-clean", action="store_true",
                    help="after the (possibly faulted/restarted) cluster "
                         "run, re-run uninterrupted in-process and fail "
                         "unless the answers are byte-for-byte identical")
    ap.add_argument("--admit", action="append", default=None,
                    metavar="SS:SEEDS",
                    help="scripted mid-run admission for batched apps "
                         "(DESIGN.md §13), repeatable: '4:17,42' splices "
                         "queries seeded at vertices 17 and 42 into "
                         "retired [V,Q] slots at the end of superstep 4. "
                         "The plan replicates to every rank; rank 0 "
                         "admits (its frame header carries the record) "
                         "and peers splice deterministically from it")
    args = ap.parse_args(argv)

    if args.reuse and args.store:
        store = TileStore(args.store)
        store.load_meta()
    else:
        store = build_store(args)

    fault_plan = None
    if args.inject:
        from repro.runtime import faults

        marker_dir = None
        if args.checkpoint_dir:
            marker_dir = os.path.join(args.checkpoint_dir, "fault_markers")
            os.makedirs(marker_dir, exist_ok=True)
        fault_plan = faults.parse_plan(args.inject, marker_dir=marker_dir)

    ecfg = EngineConfig(
        comm_mode=args.comm_mode,
        cache_capacity_bytes=int(args.cache_mb * 1e6),
        cache_mode=args.cache_mode if args.cache_mode == "auto"
        else int(args.cache_mode),
        cache_policy=args.cache_policy,
        cache_promote_hits=args.cache_promote_hits,
        cache_aware_order=not args.static_order,
        max_supersteps=args.supersteps,
        pipeline=args.pipeline,
        prefetch_depth=args.prefetch_depth,
        prefetch_workers=args.prefetch_workers,
        stack_size=args.stack_size,
        vertex_memory_budget=(None if args.vertex_memory_budget is None
                              else int(args.vertex_memory_budget * 1e6)),
        num_intervals=args.num_intervals,
        interval_aware_order=not args.no_interval_order,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=args.resume,
        preemptible=args.preemptible,
        fault_plan=fault_plan,
        admit_plan=parse_admit_plan(args.admit),
    )
    cfg = ClusterConfig(num_servers=args.servers, transport=args.transport,
                        steal=args.steal, on_failure=args.on_failure,
                        max_restarts=args.max_restarts, engine=ecfg)
    progs = _build_progs(args)
    t0 = time.time()
    out = run_cluster(store.root, progs, cfg)
    dt = time.time() - t0
    res = out.results[0]
    wire = sum(h.wire_bytes for h in res.history)
    net = sum(h.network_bytes for h in res.history)
    print(f"{args.app} x{args.servers} servers [{args.transport}"
          f"{', steal' if args.steal else ''}]: {res.supersteps} supersteps "
          f"in {dt:.1f}s (converged={res.converged}, "
          f"bit-identical across ranks={out.verified}"
          + (f", {out.restarts} restarts -> {out.final_servers} servers"
             if out.restarts else "") + ")")
    if args.verify_clean:
        clean_cfg = dataclasses.replace(
            ecfg, num_servers=args.servers, server_rank=None,
            checkpoint_dir=None, checkpoint_every=0, resume=False,
            preemptible=False, fault_plan=None)
        clean_eng = OutOfCoreEngine(store, clean_cfg)
        for i, prog in enumerate(_build_progs(args)):
            clean = clean_eng.run(prog)
            if not np.array_equal(clean.values, out.results[i].values):
                raise SystemExit(
                    f"verify-clean FAILED: app index {i} differs from the "
                    "uninterrupted run")
        print("  verify-clean: byte-identical to the uninterrupted run")
    print(f"  wire {wire / 1e6:.2f} MB total ({net / 1e6:.2f} MB on the "
          f"network at N-1 peers/server); per-superstep "
          f"{[h.wire_bytes for h in res.history[:8]]}{'...' if res.supersteps > 8 else ''}")
    from repro.core.partition import server_vertex_ranges

    plan = store.load_plan()
    for rep in out.rank_reports:
        ranges = server_vertex_ranges(plan.splitter,
                                      [rep["final_assignment"][rep["rank"]]])[0]
        owned = sum(hi - lo for lo, hi in ranges)
        print(f"  rank {rep['rank']}: {rep['seconds']:.1f}s, "
              f"sent {rep['wire_bytes'] / 1e6:.2f} MB, "
              f"{len(rep['final_assignment'][rep['rank']])} tiles / "
              f"{owned} rows owned"
              + (f", {rep['steal_moves']} tiles stolen" if args.steal else ""))
    return out


if __name__ == "__main__":
    main()
