"""Production meshes (functions, never module-level constants — importing
this module must not touch jax device state)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly all-Auto
    AxisType = None


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh(axes: tuple[str, ...] = ("data",)) -> Mesh:
    """Whatever devices exist locally, flattened onto the first axis."""
    n = jax.device_count()
    shape = (n,) + (1,) * (len(axes) - 1)
    return _mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return _mesh(shape, axes)


def graph_engine_axes(mesh: Mesh) -> tuple[str, ...]:
    """GraphH tile-shard axes: servers = pod x data, workers = model —
    tiles shard over all of them (DESIGN.md §5)."""
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def make_cluster_mesh(num_servers: int) -> Mesh:
    """1-D ("server",) mesh modelling the multi-process cluster runtime
    (DESIGN.md §11) for the shard_map dry-run path: one mesh slot per
    server process, so ``distributed.build_superstep`` over this mesh
    lowers the same per-server tile shard + hybrid broadcast the real
    cluster executes.  Requires >= ``num_servers`` local (or
    ``--xla_force_host_platform_device_count``-emulated) devices."""
    if jax.device_count() < num_servers:
        raise ValueError(
            f"need {num_servers} devices for a {num_servers}-server mesh; "
            f"have {jax.device_count()} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_servers} "
            "before importing jax to emulate them)")
    return _mesh((num_servers,), ("server",))
