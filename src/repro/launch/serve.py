"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 16 --slots 4 --max-new 16 [--ckpt-dir /tmp/run1]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.models.model_zoo import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="load trained params from a checkpoint")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch, reduced=args.reduced)
    import dataclasses
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model,
                         d_ff=int(args.d_model * 8 / 3 / 128) * 128 or 128,
                         head_dim=64,
                         num_heads=max(args.d_model // 64, 1),
                         num_kv_heads=max(args.d_model // 128, 1))
    if args.layers:
        overrides["num_layers"] = args.layers
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    run = RunConfig(remat="none", q_chunk=64, kv_chunk=64,
                    compute_dtype="float32")
    model = build_model(cfg, run)
    if args.ckpt_dir:
        _, state = CheckpointManager(args.ckpt_dir).restore()
        params = state["params"]
        print("loaded params from", args.ckpt_dir)
    else:
        params = model.init(jax.random.key(args.seed))

    eng = ServeEngine(cfg, run, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.time()
    outs = eng.run_requests(reqs)
    dt = time.time() - t0
    tok = sum(len(o.tokens) for o in outs)
    print(f"{len(outs)} completions, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {eng.stats['decode_steps']} decode steps, "
          f"slots={args.slots})")
    for o in sorted(outs, key=lambda x: x.rid)[:4]:
        print(f"  req {o.rid}: {o.tokens[:12]}{'...' if len(o.tokens)>12 else ''}")
    return outs


if __name__ == "__main__":
    main()
