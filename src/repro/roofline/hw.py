"""Target hardware constants: TPU v5e (per chip)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 4   # MXU f32 rate (one bf16 pass = 4x)
VPU_OPS = 4e12                  # elementwise f32 op/s (8x128 VPU lanes)
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link (~45-50 GB/s on v5e)
HBM_BYTES = 16 * 1024**3        # 16 GiB
VMEM_BYTES = 128 * 1024**2      # ~128 MiB vector memory
MXU_ALIGN = 128
SUBLANES = 8                    # f32 tile is (8, 128)
GRID_STEP_OVERHEAD_S = 2e-6     # per kernel grid step (DMA issue + sync)
HOST_DISPATCH_S = 200e-6        # per jit dispatch from the host loop
