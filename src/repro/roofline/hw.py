"""Target hardware constants: TPU v5e (per chip)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link (~45-50 GB/s on v5e)
HBM_BYTES = 16 * 1024**3        # 16 GiB
VMEM_BYTES = 128 * 1024**2      # ~128 MiB vector memory
MXU_ALIGN = 128
