"""Three-term roofline from a compiled dry-run artifact.

  compute   = HLO_FLOPs / peak_FLOP/s            (per-chip: post-SPMD HLO
  memory    = HLO_bytes / HBM_bw                  is the per-device program)
  collective= collective_bytes / link_bw

collective_bytes is parsed from the optimized (post-partitioning) HLO text:
the summed operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops, per the assignment's definition.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shape_str_bytes(s: str) -> int:
    """Bytes of a result type string, incl. tuple types '(f32[2], f32[2])'."""
    return sum(_type_bytes(d, dims) for d, dims in _TYPE_RE.findall(s))


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from optimized HLO text.

    Optimized HLO prints operands as bare %names, so first build a symbol
    table name -> result-type bytes, then resolve each collective's operand
    list against it.
    """
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        dm = _DEF_RE.match(line)
        if dm:
            sizes[dm.group(1)] = _shape_str_bytes(dm.group(2))

    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:        # async pair: the -start carries operands
            continue
        kind = m.group(1)
        start = line.index(m.group(0)) + len(m.group(0))
        depth = 1
        i = start
        while i < len(line) and depth > 0:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = line[start: i - 1]
        # inline-typed operands (unoptimized HLO) or bare names (optimized)
        b = sum(_type_bytes(d, s) for d, s in _TYPE_RE.findall(operands))
        if b == 0:
            b = sum(sizes.get(nm, 0) for nm in _OPERAND_RE.findall(operands))
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["op_counts"] = count
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed (conservative)
    coll_bytes: float             # per-device collective operand bytes
    compute_s: float
    memory_s: float               # conservative (op-boundary) bound
    memory_fused_s: float         # optimistic (fusion-granularity) bound
    collective_s: float
    bottleneck: str
    model_flops_total: float      # 6ND (train) / 2ND (inference), global
    useful_flops_ratio: float     # model_flops_per_device / HLO flops
    step_s_bound: float           # max of the three terms
    mfu_bound: float              # model flops / (chips * peak * step_s_bound)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(flops: float, hbm_bytes: float, coll_bytes: float,
             n_chips: int, model_flops_total: float,
             links: int = 1, hbm_bytes_fused: float = None) -> RooflineTerms:
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / hw.HBM_BW
    fused = hbm_bytes if hbm_bytes_fused is None else hbm_bytes_fused
    memory_fused_s = fused / hw.HBM_BW
    collective_s = coll_bytes / (hw.ICI_BW_PER_LINK * links)
    # bottleneck / MFU use the fused (TPU-fusion-granularity) memory bound;
    # the conservative bound is reported alongside.
    terms = {"compute": compute_s, "memory": memory_fused_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(compute_s, memory_fused_s, collective_s)
    mfu = (model_flops_total / (n_chips * hw.PEAK_FLOPS_BF16 * step)
           if step > 0 else 0.0)
    per_dev_model = model_flops_total / n_chips
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm_bytes, coll_bytes=coll_bytes,
        compute_s=compute_s, memory_s=memory_s,
        memory_fused_s=memory_fused_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_flops_ratio=per_dev_model / flops if flops else 0.0,
        step_s_bound=step, mfu_bound=mfu,
    )


def model_flops(kind: str, n_params_active: int, tokens: int,
                embed_params: int = 0) -> float:
    """6ND for train, 2ND per forward token for prefill/decode.
    n_params excludes embedding table lookups (pass separately if desired)."""
    n = n_params_active - embed_params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def cost_analysis_terms(compiled) -> tuple[float, float]:
    """(flops, bytes accessed) from compiled.cost_analysis(), tolerant of
    backend differences."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, byts
