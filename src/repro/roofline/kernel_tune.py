"""Roofline-driven block-size autotuner for the fused GAB kernel.

Picks ``(BE, BR, stack_size)`` for ``kernels/gab_fused.py`` per
``(combine, Q, edge_cap, row_cap)`` from a dry-run cost model instead of
the historical hand-picked ``(512, 256)`` (DESIGN.md §14):

  * **HBM traffic** — the kernel re-streams the whole edge list once per
    row block (``src [Q,E]`` + ``dst`` + optional scale/add streams), plus
    one read/write of the row-block arrays.  Larger ``BR`` → fewer row
    blocks → fewer edge re-streams; this term drives ``BR`` toward the
    tile's full row cap.
  * **Compute** — per-monoid arithmetic intensity: the sum monoid is a
    ``2·Q·E·R`` MXU contraction, min/max a ``~3·Q·E·R`` masked VPU
    select+reduce (no MXU form), and the one-hot build costs ``E·R``
    compares either way.
  * **Overhead** — a per-grid-step cost (DMA issue + semaphore sync) that
    penalizes tiny ``BE``; this is what makes big edge blocks win once
    VMEM allows them.
  * **VMEM feasibility** — double-buffered edge slots + the resident
    accumulator + row-block I/O + the one-hot (and, for min/max, the
    ``[Q, BE, BR]`` select) must fit a VMEM budget; this is the ceiling
    that forces min/max and wide-Q configs to smaller blocks.

``predicted_s = max(hbm/bw, compute) + overhead``; the roofline ceiling
(``edges_per_s``) drops the overhead term — the gap between a measured
run and that ceiling is what ``bench_kernel_fused`` reports per app.

The bandwidth is the declared HBM figure on TPU and a measured host
``memcpy`` figure everywhere else (interpret mode streams through host
memory), so predicted times are honest on both substrates.  The pick
itself is bandwidth-independent given the candidate order, so CPU and
TPU choose the same blocks for the same shape.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.roofline import hw

#: candidate block sizes — MXU/lane-aligned multiples of 128
_BE_CANDIDATES = (128, 256, 512, 1024, 2048, 4096)
_BR_CANDIDATES = (128, 256, 512, 1024, 2048)
#: fraction of VMEM the kernel may plan for (the rest: spills, metadata)
_VMEM_FRACTION = 0.5
STATIC_BLOCKS = (512, 256)      # the historical hand-picked default


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """One tuned kernel configuration + its model terms."""

    block_e: int
    block_r: int
    stack_size: int             # tiles per pipelined dispatch
    predicted_s: float          # model seconds per tile (incl. overhead)
    roofline_s: float           # max(bytes/bw, compute) — no overhead
    edges_per_s: float          # edge_cap / roofline_s: the ceiling
    hbm_bytes: int
    flops: int                  # MXU flops (sum monoid contraction)
    vpu_ops: int                # elementwise ops (one-hot + min/max path)
    bound: str                  # "memory" | "compute"

    @property
    def blocks(self) -> tuple[int, int]:
        return (self.block_e, self.block_r)


def _roundup(x: int, m: int) -> int:
    return max(-(-x // m) * m, m)


@functools.lru_cache(maxsize=1)
def measured_bandwidth() -> float:
    """Effective stream bandwidth in bytes/s.

    On TPU: the declared HBM figure.  Elsewhere (interpret mode) a tiny
    host memcpy microbench — best of three copies of a 32 MB buffer —
    since that is the memory the interpreted kernel actually streams.
    """
    import jax

    if jax.default_backend() == "tpu":
        return float(hw.HBM_BW)
    buf = np.ones(32 * 1024 * 1024 // 8, dtype=np.float64)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        np.copyto(np.empty_like(buf), buf)
        best = min(best, time.perf_counter() - t0)
    return (2 * buf.nbytes) / max(best, 1e-9)


def _n_streams(q: int) -> int:
    # dst + src always stream; scale/add streams are app-dependent — plan
    # for the worst shipped case (one extra f32 stream) so one choice
    # serves every program at a given (combine, Q, shape).
    return 3


def vmem_plan_bytes(combine: str, q: int, block_e: int, block_r: int) -> int:
    """Planned VMEM footprint of the fused kernel at (BE, BR)."""
    qp = _roundup(q, hw.SUBLANES)
    slots = 2 * (qp * block_e + (_n_streams(q) - 1) * block_e) * 4
    acc = qp * block_r * 4
    row_io = 4 * qp * block_r * 4           # old + base + new + upd blocks
    onehot = block_e * block_r * 4
    sel = qp * block_e * block_r * 4 if combine in ("min", "max") else 0
    return slots + acc + row_io + onehot + sel


def tile_cost(combine: str, q: int, edge_cap: int, row_cap: int,
              block_e: int, block_r: int,
              bandwidth: float | None = None) -> KernelChoice:
    """Model one (BE, BR) config for one tile shape; stack_size unset (0)."""
    bw = measured_bandwidth() if bandwidth is None else bandwidth
    qp = _roundup(q, hw.SUBLANES)
    ep = _roundup(edge_cap, block_e)
    rp = _roundup(row_cap, block_r)
    n_rb = rp // block_r
    n_eb = ep // block_e

    pass_bytes = ep * (4 * qp + 4 * (_n_streams(q) - 1))
    row_bytes = rp * qp * 4 * 4             # old+base in, new+upd out
    hbm_bytes = n_rb * pass_bytes + row_bytes

    onehot_ops = ep * rp
    if combine == "sum":
        flops = 2 * qp * ep * rp
        vpu_ops = onehot_ops
    else:
        flops = 0
        vpu_ops = 3 * qp * ep * rp + onehot_ops
    compute_s = flops / hw.PEAK_FLOPS_F32 + vpu_ops / hw.VPU_OPS

    roofline_s = max(hbm_bytes / bw, compute_s)
    overhead_s = n_rb * (n_eb + 1) * hw.GRID_STEP_OVERHEAD_S
    predicted_s = roofline_s + overhead_s
    return KernelChoice(
        block_e=block_e, block_r=block_r, stack_size=0,
        predicted_s=predicted_s, roofline_s=roofline_s,
        edges_per_s=edge_cap / max(roofline_s, 1e-12),
        hbm_bytes=hbm_bytes, flops=flops, vpu_ops=vpu_ops,
        bound="memory" if hbm_bytes / bw >= compute_s else "compute",
    )


def _stack_size(predicted_s: float) -> int:
    """Tiles per pipelined dispatch: enough that the host dispatch cost
    stays under ~5% of the stack's kernel time, clamped to [1, 16]."""
    k = hw.HOST_DISPATCH_S / (0.05 * max(predicted_s, 1e-9))
    return int(min(16, max(1, np.ceil(k))))


def pick_blocks(combine: str, q: int, edge_cap: int, row_cap: int,
                bandwidth: float | None = None,
                vmem_bytes: int | None = None) -> KernelChoice:
    """The autotuned (BE, BR, stack_size) for one (app-monoid, Q, tile).

    Deterministic: candidates are the 128-aligned grid capped at the
    padded tile shape (a block bigger than the tile only pads), filtered
    by the VMEM plan, ranked by predicted time with smaller-footprint
    tie-breaking.  The static (512, 256) default is always a candidate
    when feasible, so the pick can never model-predict worse than it.
    """
    budget = int(_VMEM_FRACTION * (hw.VMEM_BYTES if vmem_bytes is None
                                   else vmem_bytes))
    be_cap = _roundup(edge_cap, 128)
    br_cap = _roundup(row_cap, 128)
    cands = []
    for be in _BE_CANDIDATES:
        if be > max(be_cap, _BE_CANDIDATES[0]):
            continue
        for br in _BR_CANDIDATES:
            if br > max(br_cap, _BR_CANDIDATES[0]):
                continue
            if vmem_plan_bytes(combine, q, be, br) > budget:
                continue
            cands.append(tile_cost(combine, q, edge_cap, row_cap, be, br,
                                   bandwidth=bandwidth))
    if not cands:  # degenerate budget: smallest legal block
        cands = [tile_cost(combine, q, edge_cap, row_cap, 128, 128,
                           bandwidth=bandwidth)]
    best = min(cands, key=lambda c: (c.predicted_s,
                                     c.block_e * c.block_r, c.block_e))
    return dataclasses.replace(best, stack_size=_stack_size(best.predicted_s))
