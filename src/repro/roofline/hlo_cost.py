"""Trip-count-aware cost model over optimized HLO text.

XLA's HloCostAnalysis (and hence compiled.cost_analysis()) counts a while
loop's body ONCE, ignoring the trip count — useless for scan-over-layers
models where >95% of work sits inside loops.  This module re-derives

    flops            (dot ops exact, elementwise 1/elem)
    hbm bytes        (fusion-boundary operands + results)
    collective bytes (operand sizes of all-gather/all-reduce/
                      reduce-scatter/all-to-all/collective-permute)

by walking the computation graph and multiplying loop bodies by their trip
counts (parsed from the loop condition's `compare(iv, constant)` or the
`known_trip_count` backend config).  Conditionals take the max of branches
(pessimistic for compute, matching the runtime of a taken branch).

Approximations (documented):
  * elementwise/transcendental ops: 1 flop per output element
  * gather/scatter bytes: 2x result + indices (random-access reads)
  * reshape/bitcast/tuple/parameter/constant: free
  * broadcast/iota/copy/transpose: result bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# lazy type capture: tuple types embed /*index=N*/ comments (contain '='),
# so match everything up to the first lowercase op token followed by '('.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\(")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)="
                           r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"?(\d+)')

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "negate", "abs", "rsqrt", "sqrt", "sign",
    "cosine", "sine", "floor", "ceil", "round-nearest-afz", "logistic",
    "select", "compare", "and", "or", "xor", "not", "clamp", "atan2",
    "exponential-minus-one", "log-plus-one", "remainder", "cbrt", "erf",
}
FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "rng-bit-generator",
    "get-dimension-size", "domain", "opt-barrier",
}
MOVE = {"broadcast", "iota", "copy", "transpose", "reverse", "pad", "slice",
        "concatenate", "convert", "reduce",
        "select-and-scatter", "sort", "rng",
        "reduce-window", "cholesky", "triangular-solve"}
COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start", "ragged-all-to-all"}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict                    # name -> Op
    order: list                  # op names in order
    root: Optional[str] = None


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # conservative: op-boundary traffic
    coll_bytes: float = 0.0
    bytes_fused: float = 0.0    # optimistic: standalone elementwise/move ops
    #                             assumed fused away (TPU fusion granularity)
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.bytes_fused += o.bytes_fused
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        self.unknown_trip_loops += o.unknown_trip_loops
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n, self.coll_bytes * n,
                    self.bytes_fused * n,
                    {k: v * n for k, v in self.coll_by_kind.items()},
                    self.unknown_trip_loops)


def parse_module(text: str) -> tuple[dict, str]:
    """Split HLO module text into computations."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$", line)
            if m and ("(" in line or "ENTRY" in line):
                cur = Computation(m.group(2), {}, [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, type_str, kind = dm.group(1), dm.group(2), dm.group(3)
        # operand segment: inside the op's parens
        try:
            pstart = line.index(kind + "(", line.index("=")) + len(kind) + 1
        except ValueError:
            continue
        depth, i = 1, pstart
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        opnds = _OPND_RE.findall(line[pstart:i - 1])
        op = Op(name, kind, type_str, line, opnds)
        cur.ops[name] = op
        cur.order.append(name)
        if stripped.startswith("ROOT"):
            cur.root = name
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_b = _shape_elems_bytes(op.type_str)
    out_e, _ = _shape_elems_bytes(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs = comp.ops.get(op.operands[0]) if op.operands else None
    if lhs is None or m is None:
        return 2.0 * out_e  # fallback
    lhs_dims = []
    sm = _SHAPE_RE.search(lhs.type_str)
    if sm:
        lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for ci in (int(c) for c in m.group(1).split(",") if c):
        if ci < len(lhs_dims):
            k *= lhs_dims[ci]
    return 2.0 * out_e * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_e, _ = _shape_elems_bytes(op.type_str)
    rhs = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
    k = 1
    if rhs is not None:
        sm = _SHAPE_RE.search(rhs.type_str)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            # kernel spatial+input-feature product (all dims except output feat)
            if dims:
                k = 1
                for d in dims[:-1]:
                    k *= d
    return 2.0 * out_e * k


def _operand_bytes(op: Op, comp: Computation) -> float:
    b = 0
    for nm in op.operands:
        src = comp.ops.get(nm)
        if src is not None:
            b += _shape_elems_bytes(src.type_str)[1]
    return b


def _trip_count(op: Op, comps: dict) -> Optional[int]:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    cm = _CALL_ATTR_RE.findall(op.line)
    cond_name = None
    for grp, single in cm:
        target = grp or single
        if "condition=" + (("{" + grp + "}") if grp else target) in op.line.replace("%", "") \
           or ("condition=" in op.line and target in op.line.split("condition=")[1][:len(target) + 2]):
            cond_name = target.strip().lstrip("%")
            break
    if cond_name is None:
        mm = re.search(r"condition=%?([\w.\-]+)", op.line)
        cond_name = mm.group(1) if mm else None
    cond = comps.get(cond_name) if cond_name else None
    if cond is None or cond.root is None:
        return None
    root = cond.ops[cond.root]
    if root.kind != "compare":
        return None
    for nm in root.operands:
        src = cond.ops.get(nm)
        if src is not None and src.kind == "constant":
            cmv = re.search(r"constant\((\d+)\)", src.line)
            if cmv:
                return int(cmv.group(1))
    return None


def comp_cost(comp: Computation, comps: dict, memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    total = Cost()
    for nm in comp.order:
        op = comp.ops[nm]
        kind = op.kind
        out_e, out_b = _shape_elems_bytes(op.type_str)
        if kind in FREE or kind.endswith("-done"):
            continue
        if kind in COLLECTIVES:
            b = _operand_bytes(op, comp)
            total.coll_bytes += b
            base = kind.replace("-start", "")
            total.coll_by_kind[base] = total.coll_by_kind.get(base, 0) + b
            total.bytes += _operand_bytes(op, comp) + out_b
            total.bytes_fused += _operand_bytes(op, comp) + out_b
            continue
        if kind == "dot":
            total.flops += _dot_flops(op, comp)
            total.bytes += _operand_bytes(op, comp) + out_b
            total.bytes_fused += _operand_bytes(op, comp) + out_b
            continue
        if kind == "convolution":
            total.flops += _conv_flops(op, comp)
            total.bytes += _operand_bytes(op, comp) + out_b
            total.bytes_fused += _operand_bytes(op, comp) + out_b
            continue
        if kind == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.line)
            inner = comps.get(m.group(1)) if m else None
            if inner is not None:
                ic = comp_cost(inner, comps, memo)
                # fusion: inner flops count, but bytes cross the boundary once
                total.flops += ic.flops
                total.coll_bytes += ic.coll_bytes
                for k, v in ic.coll_by_kind.items():
                    total.coll_by_kind[k] = total.coll_by_kind.get(k, 0) + v
            total.bytes += _operand_bytes(op, comp) + out_b
            total.bytes_fused += _operand_bytes(op, comp) + out_b
            continue
        if kind == "while":
            mb = re.search(r"body=%?([\w.\-]+)", op.line)
            body = comps.get(mb.group(1)) if mb else None
            trips = _trip_count(op, comps)
            if body is not None:
                bc = comp_cost(body, comps, memo)
                if trips is None:
                    trips = 1
                    total.unknown_trip_loops += 1
                total += bc.scaled(trips)
            continue
        if kind == "conditional":
            mb = re.search(r"branch_computations=\{([^}]*)\}", op.line)
            branches = []
            if mb:
                branches = [comps.get(x.strip().lstrip("%"))
                            for x in mb.group(1).split(",")]
            best = Cost()
            for br in branches:
                if br is None:
                    continue
                c = comp_cost(br, comps, memo)
                if c.flops + c.bytes > best.flops + best.bytes:
                    best = c
            total += best
            total.bytes += out_b
            continue
        if kind in ("call", "async-start"):
            m = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", op.line)
            inner = comps.get(m.group(1)) if m else None
            if inner is not None:
                total += comp_cost(inner, comps, memo)
            continue
        if kind == "dynamic-update-slice":
            # in-place: traffic is the updated slice, not the full buffer
            upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
            ub = _shape_elems_bytes(upd.type_str)[1] if upd is not None else 0
            total.bytes += 2 * ub
            total.bytes_fused += 2 * ub
            continue
        if kind == "dynamic-slice":
            total.bytes += 2 * out_b          # read slice region, write result
            total.bytes_fused += 2 * out_b
            continue
        if kind in ("gather", "scatter"):
            total.bytes += 2 * out_b + _operand_bytes(op, comp) * 0  # approx
            total.bytes_fused += 2 * out_b
            idx = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
            if idx is not None:
                total.bytes += _shape_elems_bytes(idx.type_str)[1]
                total.bytes_fused += _shape_elems_bytes(idx.type_str)[1]
            if kind == "scatter":
                total.flops += out_e
            continue
        if kind == "custom-call":
            total.bytes += _operand_bytes(op, comp) + out_b
            total.bytes_fused += _operand_bytes(op, comp) + out_b
            continue
        if kind in ELEMENTWISE:
            total.flops += out_e
            # fused later usually; charge boundary bytes only for large ops
            total.bytes += _operand_bytes(op, comp) + out_b
            continue
        if kind in MOVE:
            total.bytes += _operand_bytes(op, comp) + out_b
            if kind == "reduce":
                total.flops += _operand_bytes(op, comp) / 4.0
            continue
        # unknown op: move-like default
        total.bytes += _operand_bytes(op, comp) + out_b
    memo[comp.name] = total
    return total


def analyze(hlo_text: str) -> Cost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        # fall back: the largest computation
        entry = max(comps, key=lambda c: len(comps[c].order)) if comps else None
    if entry is None:
        return Cost()
    return comp_cost(comps[entry], comps, {})


# ---------------------------------------------------------------------------
# attribution: where do the flops/bytes go? (the dry-run "profile")
# ---------------------------------------------------------------------------

_META_RE = re.compile(r'op_name="([^"]*)"')


def _op_tag(line: str, depth: int = 3) -> str:
    m = _META_RE.search(line)
    if not m:
        return "(no-metadata)"
    parts = m.group(1).split("/")
    return "/".join(parts[:depth])


def attribute(hlo_text: str, depth: int = 4, top_k: int = 20) -> list:
    """Group trip-count-scaled flops/bytes by jax op_name prefix.

    Returns [(tag, flops, bytes)] sorted by flops+bytes-seconds-equivalent.
    Loop bodies inherit their own ops' metadata (jax records source scopes),
    so scan-over-layers work shows up under its model-code path.
    """
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return []
    buckets: dict[str, list] = {}

    def walk(comp: Computation, scale: float, seen: tuple):
        if comp.name in seen:       # recursion guard
            return
        for nm in comp.order:
            op = comp.ops[nm]
            kind = op.kind
            out_e, out_b = _shape_elems_bytes(op.type_str)
            if kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                body = comps.get(mb.group(1)) if mb else None
                trips = _trip_count(op, comps) or 1
                if body is not None:
                    walk(body, scale * trips, seen + (comp.name,))
                continue
            if kind == "fusion" or kind in ("call", "async-start"):
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.line)
                inner = comps.get(m.group(1)) if m else None
                if inner is not None:
                    walk(inner, scale, seen + (comp.name,))
                if kind == "fusion":
                    tag = _op_tag(op.line, depth)
                    b = buckets.setdefault(tag, [0.0, 0.0])
                    b[1] += scale * (_operand_bytes(op, comp) + out_b)
                continue
            if kind == "conditional":
                mb = re.search(r"branch_computations=\{([^}]*)\}", op.line)
                if mb:
                    brs = [comps.get(x.strip().lstrip("%"))
                           for x in mb.group(1).split(",")]
                    sizes = [(len(b.order) if b else 0) for b in brs]
                    big = brs[int(np.argmax(sizes))] if brs else None
                    if big is not None:
                        walk(big, scale, seen + (comp.name,))
                continue
            if kind in FREE or kind.endswith("-done"):
                continue
            tag = _op_tag(op.line, depth)
            b = buckets.setdefault(tag, [0.0, 0.0])
            if kind == "dot":
                b[0] += scale * _dot_flops(op, comp)
                b[1] += scale * (_operand_bytes(op, comp) + out_b)
            elif kind == "dynamic-update-slice":
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                ub = _shape_elems_bytes(upd.type_str)[1] if upd else 0
                b[1] += scale * 2 * ub
            elif kind in ELEMENTWISE:
                b[0] += scale * out_e
                b[1] += scale * (_operand_bytes(op, comp) + out_b)
            else:
                b[1] += scale * (_operand_bytes(op, comp) + out_b)

    import numpy as np  # local: keep module import-light
    walk(comps[entry], 1.0, ())
    rows = [(k, v[0], v[1]) for k, v in buckets.items()]
    rows.sort(key=lambda r: -(r[1] / 197e12 + r[2] / 819e9))
    return rows[:top_k]
