"""Mixture-of-Experts FFN with sort-based dispatch and expert parallelism.

Dispatch is the production TPU pattern (no [T, E, C] one-hot blow-up):

  1. router top-k per token -> (expert_id, gate) pairs, flattened [T*k]
  2. stable-sort assignments by expert; position-within-expert via running
     rank; drop tokens past the per-expert capacity C = k*T/E * cf
  3. scatter token indices into an [E, C] index grid, gather tokens to
     [E, C, D], run the expert FFNs batched with a single einsum chain,
     scatter-add gated outputs back to [T, D].

Expert weights are sharded over the "model" axis (EP); GSPMD turns the
gather/scatter into all_to_all exchanges between data and expert shards —
the direct analogue of GraphH's Broadcast of updated values to owning
servers (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, _act
from repro.models.sharding import cns


def moe_init(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e)),
        "wi": dense_init(ks[1], (e, d, f), in_axis_size=d),
        "wg": dense_init(ks[2], (e, d, f), in_axis_size=d),
        "wo": dense_init(ks[3], (e, f, d), in_axis_size=f),
    }


def moe_capacity(num_tokens: int, cfg) -> int:
    c = int(cfg.experts_per_token * num_tokens * cfg.moe_capacity_factor
            / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_apply(p, x, cfg):
    """x: [B, S, D] -> [B, S, D]."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = moe_capacity(T, cfg)
    cdt = x.dtype
    xt = x.reshape(T, D)

    logits = (xt @ p["router"].astype(cdt)).astype(jnp.float32)   # [T, E]
    gates, eids = jax.lax.top_k(logits, K)                        # [T, K]
    gates = jax.nn.softmax(gates, axis=-1)

    # --- sort-based dispatch -------------------------------------------
    flat_e = eids.reshape(-1)                                     # [T*K]
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    # position of each assignment within its expert
    ar = jnp.arange(T * K)
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")  # [E]
    pos = ar - seg_start[se]                                      # rank in expert
    keep = pos < C

    # scatter token ids into the [E, C] grid (capacity-dropped slots = T)
    grid_tok = jnp.full((E, C), T, jnp.int32)
    grid_gate = jnp.zeros((E, C), jnp.float32)
    lin = jnp.where(keep, se * C + pos, E * C)   # dropped -> OOB -> discarded
    grid_tok = grid_tok.reshape(-1).at[lin].set(
        st.astype(jnp.int32), mode="drop").reshape(E, C)
    grid_gate = grid_gate.reshape(-1).at[lin].set(
        sg, mode="drop").reshape(E, C)

    # gather tokens -> [E, C, D] (out-of-range id T -> zeros via clamp+mask)
    safe = jnp.minimum(grid_tok, T - 1)
    xe = xt[safe] * (grid_tok < T)[..., None].astype(cdt)
    xe = cns(xe, "model", ("pod", "data"), None)   # EP x DP: tokens shard over dp

    # expert FFN, batched over E
    hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(cdt))
    hg = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(cdt))
    h = _act(hg, cfg.act) * hi
    h = cns(h, "model", ("pod", "data"), None)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cdt))

    # combine back: scatter-add gated outputs to tokens
    yw = ye * grid_gate[..., None].astype(cdt)
    out = jnp.zeros((T + 1, D), cdt).at[grid_tok.reshape(-1)].add(
        yw.reshape(E * C, D), mode="drop")[:T]
    out = cns(out.reshape(B, S, D), ("pod", "data"), None, None)
    return out
