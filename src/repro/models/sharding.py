"""Sharding rules: logical -> mesh axis mapping for params and activations.

LM-side distribution uses pjit/GSPMD (the graph engine uses shard_map).
A contextvar carries (mesh, rules) so layer code can annotate activations
with plain helper calls; when no mesh is set (CPU smoke tests) constraints
are no-ops.

Rules:
  dp axes  = ("pod", "data") when present — batch parallel
  tp axis  = "model"          — heads / ffn / vocab / experts
  fsdp     = params (and optimizer state) additionally sharded over "data"
"""
from __future__ import annotations

import contextlib
import dataclasses
from contextvars import ContextVar
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    fsdp: bool = True                 # shard params over dp_axes[-1]
    zero1: bool = True                # shard optimizer state over dp

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def fsdp_axis(self) -> Optional[str]:
        return self.dp_axes[-1] if self.fsdp else None


_CTX: ContextVar[Optional[tuple[Mesh, Rules]]] = ContextVar("mesh_rules", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Rules):
    tok = _CTX.set((mesh, rules) if mesh is not None else None)
    try:
        yield
    finally:
        _CTX.reset(tok)


def current() -> Optional[tuple[Mesh, Rules]]:
    return _CTX.get()


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Make a spec legal for this mesh: drop axes the mesh doesn't have and
    axes whose size doesn't divide the dim (NamedSharding is strict;
    non-dividing head/vocab counts fall back to replication on that dim —
    recorded in DESIGN.md as a hardware-adaptation note)."""
    out = []
    for i, s in enumerate(spec):
        if s is None or i >= len(shape):
            out.append(None if i >= len(shape) else s)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            out.append(None)
            continue
        s2 = axes[0] if len(axes) == 1 else axes
        if shape[i] % axis_size(mesh, s2) != 0:
            out.append(None)
        else:
            out.append(s2)
    return P(*out)


def cns(x, *spec):
    """Constrain activation sharding (no-op without a mesh context;
    divisibility-sanitized against the current mesh)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    sp = sanitize_spec(P(*spec), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, sp))


def act_specs():
    """Common activation specs resolved from the current rules context."""
    ctx = _CTX.get()
    if ctx is None:
        return None
    _, r = ctx
    return r


# ---------------------------------------------------------------------------
# Parameter shardings by path-name heuristics
# ---------------------------------------------------------------------------

_COL_PARALLEL = ("wq", "wk", "wv", "wi", "wg", "w_rkvg", "wx", "wy")   # [.., D, F*]
_ROW_PARALLEL = ("wo",)                                               # [.., F*, D]
_REPLICATED = ("scale", "bias", "router", "conv", "a_param", "u", "decay_lora",
               "mix", "pos", "w_decay", "ln")


def _leaf_spec(path: str, ndim: int, rules: Rules) -> P:
    f = rules.fsdp_axis
    tp = rules.tp_axis
    last = path.split("/")[-1]
    is_expert = "/moe/" in path and last in ("wi", "wg", "wo")
    if last in ("tok", "lm_head"):                 # [V, D] / [D, V]
        if last == "tok":
            return P(tp, f)
        return P(f, tp)
    if is_expert:                                   # [E, D, F] / [E, F, D]
        if last == "wo":
            return P(tp, None, f)
        return P(tp, f, None)
    if any(last == n or last.startswith(n) for n in _REPLICATED):
        return P(*([None] * ndim))
    if last in _COL_PARALLEL:                       # [..., D, F] col-parallel
        spec = [None] * ndim
        spec[-1] = tp
        if ndim >= 2:
            spec[-2] = f
        return P(*spec)
    if last in _ROW_PARALLEL:                       # [..., F, D] row-parallel
        spec = [None] * ndim
        if ndim >= 2:
            spec[-2] = tp
        spec[-1] = f
        return P(*spec)
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_tree, rules: Rules, mesh: Optional[Mesh] = None):
    """PartitionSpec pytree matching ``params_tree`` (arrays or ShapeDtype).
    With a mesh, specs are divisibility-sanitized against leaf shapes."""
    def leaf(path, x):
        sp = _leaf_spec(_path_str(path), len(x.shape), rules)
        if mesh is not None:
            sp = sanitize_spec(sp, x.shape, mesh)
        return sp

    return jax.tree_util.tree_map_with_path(leaf, params_tree)


def param_shardings(params_tree, mesh: Mesh, rules: Rules):
    specs = param_specs(params_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_spec_from_param(spec: P, rules: Rules, shape=None,
                              mesh: Optional[Mesh] = None) -> P:
    """ZeRO-1: give optimizer-state copies an extra shard over the dp axis
    on the first unsharded dim that divides (falls back to the param spec)."""
    if not rules.zero1:
        return spec
    dp = rules.dp_axes[-1]
    used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
    if dp in used:
        return spec
    new = list(spec)
    for i, s in enumerate(new):
        if s is None:
            if shape is not None and mesh is not None and \
                    shape[i] % axis_size(mesh, dp) != 0:
                continue
            new[i] = dp
            return P(*new)
    return spec
