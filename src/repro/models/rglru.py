"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (the paper's "recurrent block"):
    x -> ln -> [branch A: linear -> gelu]                       (gate)
              [branch B: linear -> conv1d(w=4) -> RG-LRU]       (recurrence)
    out = wo(A * B)

RG-LRU per channel:
    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    a_t = exp(c * softplus(Λ) * (-r_t))            # a in (0,1), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses an associative scan over the length axis (O(log S)
depth); decode is the single-step recurrence with h carried in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import cns

_C = 8.0


def rglru_init(key, cfg):
    d = cfg.d_model
    r = cfg.rnn_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], (d, r)),          # branch B in-proj
        "wy": dense_init(ks[1], (d, r)),          # branch A (gate) in-proj
        "conv": jax.random.normal(ks[2], (cfg.conv_width, r)) * 0.02,
        "gate_a": dense_init(ks[3], (r, r)),
        "gate_x": dense_init(ks[4], (r, r)),
        "a_param": jnp.log(jnp.expm1(                     # softplus^-1
            -jnp.log(jax.random.uniform(ks[5], (r,), minval=0.9, maxval=0.999))
            / _C)),
        "wo": dense_init(key, (r, d)),
    }


def _conv1d(x, w, state=None):
    """Causal depthwise conv.  x: [B, S, R]; w: [W, R]; state: [B, W-1, R]."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return out, new_state


def _gates(p, xb):
    rf = jax.nn.sigmoid((xb @ p["gate_a"].astype(xb.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["gate_x"].astype(xb.dtype)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["a_param"]).astype(jnp.float32) * rf
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xb.astype(jnp.float32)


def rglru_scan(p, xb, h0=None):
    """xb: [B, S, R] conv output.  Returns (y [B,S,R], h_last [B,R])."""
    a, bx = _gates(p, xb)                         # [B, S, R] f32

    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h0 + bx_1
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h.astype(xb.dtype), h[:, -1]


def rglru_step(p, xb, h):
    """Single decode step.  xb: [B, 1, R]; h: [B, R] f32."""
    a, bx = _gates(p, xb)
    h_new = a[:, 0] * h.astype(jnp.float32) + bx[:, 0]
    return h_new.astype(xb.dtype)[:, None], h_new


def rnn_block_init(key, cfg):
    return rglru_init(key, cfg)


def rnn_block_apply(p, x, cfg, cache=None):
    """Full recurrent block.  cache: None (train) or dict with
    {"conv": [B, W-1, R], "h": [B, R]} for decode/prefill continuation."""
    cdt = x.dtype
    xb = x @ p["wx"].astype(cdt)
    gate = jax.nn.gelu(x @ p["wy"].astype(cdt))
    xb = cns(xb, ("pod", "data"), None, "model")
    conv_state = None if cache is None else cache["conv"]
    xb, new_conv = _conv1d(xb, p["conv"], conv_state)

    if cache is None:
        y, h_last = rglru_scan(p, xb)
        new_cache = None
    elif x.shape[1] == 1:
        y, h_last = rglru_step(p, xb, cache["h"])
        new_cache = {"conv": new_conv, "h": h_last}
    else:  # prefill with state
        y, h_last = rglru_scan(p, xb, h0=cache["h"])
        new_cache = {"conv": new_conv, "h": h_last}

    out = (gate * y) @ p["wo"].astype(cdt)
    return cns(out, ("pod", "data"), None, None), new_cache


def rnn_cache_init(batch: int, cfg, dtype=jnp.float32):
    r = cfg.rnn_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
        "h": jnp.zeros((batch, r), jnp.float32),
    }
