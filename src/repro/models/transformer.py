"""Decoder-only LM over heterogeneous layer patterns with scan-over-cycles.

The layer stack is described by cfg.layer_pattern cycled over num_layers
(e.g. "LG" for gemma2's local/global alternation, "RRL" for
recurrentgemma, "K" for RWKV6, "G" for vanilla).  Full cycles are stacked
and applied with jax.lax.scan so compile time is independent of depth;
remainder layers are unrolled.

Three entry modes share the block code:
  train   — full-sequence forward, no cache, blockwise attention
  prefill — full-sequence forward building a decode cache
  decode  — one token per step against the cache
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv6 as K
from repro.models.sharding import cns


def padded_vocab(cfg: ModelConfig) -> int:
    """Physical vocab rounded up to 256 so the vocab axis always shards over
    the model axis (whisper 51865 / granite 49155 don't divide 16).  Logits
    for pad rows are masked to -inf; labels never reference them."""
    return ((cfg.vocab_size + 255) // 256) * 256


def _mask_pad_logits(logits, cfg: ModelConfig):
    vpad = logits.shape[-1]
    if vpad == cfg.vocab_size:
        return logits
    ids = jnp.arange(vpad)
    return jnp.where(ids >= cfg.vocab_size,
                     jnp.asarray(-1e30, logits.dtype), logits)


# ---------------------------------------------------------------------------
# single block: init / apply / cache
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("G", "L"):
        ffn = M.moe_init(ks[2], cfg) if cfg.moe else L.mlp_init(ks[2], cfg)
        return {
            "ln1": L.norm_init(d),
            "attn": L.attn_init(ks[1], cfg),
            "ln2": L.norm_init(d),
            ("moe" if cfg.moe else "mlp"): ffn,
        }
    if kind == "R":
        return {
            "ln1": L.norm_init(d),
            "rnn": R.rnn_block_init(ks[1], cfg),
            "ln2": L.norm_init(d),
            "mlp": L.mlp_init(ks[2], cfg),
        }
    if kind == "K":
        return {
            "ln1": L.norm_init(d),
            "ln2": L.norm_init(d),
            "rwkv": K.rwkv_init(ks[1], cfg),
        }
    raise ValueError(f"unknown layer kind {kind}")


def cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim()
    if kind == "G":
        s = max_len
        return {"k": jnp.zeros((batch, s, hkv, dh), dtype),
                "v": jnp.zeros((batch, s, hkv, dh), dtype)}
    if kind == "L":
        s = min(max_len, cfg.sliding_window)
        return {"k": jnp.zeros((batch, s, hkv, dh), dtype),
                "v": jnp.zeros((batch, s, hkv, dh), dtype)}
    if kind == "R":
        return R.rnn_cache_init(batch, cfg, dtype)
    if kind == "K":
        return K.rwkv_cache_init(batch, cfg, dtype)
    raise ValueError(kind)


def _ffn_apply(p, x, cfg):
    if cfg.moe:
        return M.moe_apply(p["moe"], x, cfg)
    return L.mlp_apply(p["mlp"], x, cfg)


def _write_prefill_cache(cache_kv, k, v, window: Optional[int]):
    """Write full-sequence K/V into a (possibly rolling) cache buffer."""
    S = k.shape[1]
    W = cache_kv["k"].shape[1]
    if window is None or S <= W:
        kk = cache_kv["k"].at[:, :min(S, W)].set(
            k[:, :min(S, W)].astype(cache_kv["k"].dtype))
        vv = cache_kv["v"].at[:, :min(S, W)].set(
            v[:, :min(S, W)].astype(cache_kv["v"].dtype))
        return {"k": kk, "v": vv}
    # rolling: keep the last W entries at slot = pos % W
    p0 = S - W + jnp.arange(W)
    slots = p0 % W
    kk = cache_kv["k"].at[:, slots].set(k[:, -W:].astype(cache_kv["k"].dtype))
    vv = cache_kv["v"].at[:, slots].set(v[:, -W:].astype(cache_kv["v"].dtype))
    return {"k": kk, "v": vv}


def _write_decode_cache(cache_kv, k1, v1, cache_len, window: Optional[int]):
    """cache_len: scalar or per-batch [B] — per-slot lengths enable the
    continuous-batching serve engine."""
    B, W = cache_kv["k"].shape[0], cache_kv["k"].shape[1]
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    slot = cl % W if window is not None else jnp.minimum(cl, W - 1)
    b = jnp.arange(B)
    kk = cache_kv["k"].at[b, slot].set(k1[:, 0].astype(cache_kv["k"].dtype))
    vv = cache_kv["v"].at[b, slot].set(v1[:, 0].astype(cache_kv["v"].dtype))
    return {"k": kk, "v": vv}


def block_apply(p, x, cfg: ModelConfig, run: RunConfig, kind: str,
                mode: str, cache, cache_len, positions):
    """Apply one block.  Returns (x, new_cache)."""
    window = cfg.sliding_window if kind == "L" else None

    if kind == "K":
        return K.rwkv_block_apply(p["rwkv"], x, cfg, p["ln1"], p["ln2"],
                                  cache=cache if mode != "train" else None)

    if kind == "R":
        h = L.norm_apply(p["ln1"], x, cfg.norm, cfg.norm_eps)
        h, new_rnn = R.rnn_block_apply(
            p["rnn"], h, cfg, cache=cache if mode != "train" else None)
        x = x + h
        h = L.norm_apply(p["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + _ffn_apply(p, h, cfg)
        return x, new_rnn

    # attention block (G / L)
    h = L.norm_apply(p["ln1"], x, cfg.norm, cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], h, cfg, positions, run.attn_shard)
    new_cache = cache
    sdt = jnp.dtype(run.scores_dtype)
    if mode == "train":
        o = L.blockwise_attention(
            q, k, v, causal=True, window=window, softcap=cfg.attn_softcap,
            q_chunk=run.q_chunk, kv_chunk=run.kv_chunk, scores_dtype=sdt)
    elif mode == "prefill":
        o = L.blockwise_attention(
            q, k, v, causal=True, window=window, softcap=cfg.attn_softcap,
            q_chunk=run.q_chunk, kv_chunk=run.kv_chunk, scores_dtype=sdt)
        new_cache = _write_prefill_cache(cache, k, v, window)
    else:  # decode
        new_cache = _write_decode_cache(cache, k, v, cache_len, window)
        if window is not None:
            W = new_cache["k"].shape[1]
            eff_len = jnp.minimum(cache_len + 1, W)
            o = L.decode_attention(q, new_cache["k"], new_cache["v"], eff_len,
                                   window=None, softcap=cfg.attn_softcap)
        else:
            o = L.decode_attention(q, new_cache["k"], new_cache["v"],
                                   cache_len + 1, window=None,
                                   softcap=cfg.attn_softcap)
    x = x + L.attn_out(p["attn"], o, cfg, run.attn_shard)
    h = L.norm_apply(p["ln2"], x, cfg.norm, cfg.norm_eps)
    x = x + _ffn_apply(p, h, cfg)
    return x, new_cache


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LM:
    cfg: ModelConfig
    run: RunConfig = RunConfig()

    # -- structure ------------------------------------------------------
    @property
    def pattern(self) -> str:
        return self.cfg.layer_pattern

    @property
    def n_full_cycles(self) -> int:
        return self.cfg.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> list[str]:
        rem = self.cfg.num_layers % len(self.pattern)
        return list(self.pattern[:rem])

    # -- init -----------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_cyc, k_tail, k_head = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embed": {"tok": (jax.random.normal(
                k_embed, (padded_vocab(cfg), cfg.d_model)) * 0.02
            ).astype(jnp.float32)},
            "final_norm": L.norm_init(cfg.d_model),
        }
        n = self.n_full_cycles
        cycles = {}
        for i, kind in enumerate(self.pattern):
            ki = jax.random.fold_in(k_cyc, i)
            if n > 0:
                cycles[f"{i}{kind}"] = jax.vmap(
                    lambda kk: block_init(kk, cfg, kind)
                )(jax.random.split(ki, n))
        params["cycles"] = cycles
        tail = {}
        for i, kind in enumerate(self.tail_kinds):
            tail[f"{i}{kind}"] = block_init(jax.random.fold_in(k_tail, i), cfg, kind)
        params["tail"] = tail
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(
                k_head, (cfg.d_model, padded_vocab(cfg))) * 0.02
            ).astype(jnp.float32)
        return params

    # -- caches ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        n = self.n_full_cycles

        def stack(kind):
            one = cache_init(cfg, kind, batch, max_len, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)

        cache = {"cycles": {f"{i}{k}": stack(k)
                            for i, k in enumerate(self.pattern) if n > 0},
                 "tail": {f"{i}{k}": cache_init(cfg, k, batch, max_len, dtype)
                          for i, k in enumerate(self.tail_kinds)}}
        return cache

    # -- forward ---------------------------------------------------------
    def _embed(self, params, tokens, extra_embeds):
        cfg = self.cfg
        cdt = jnp.dtype(self.run.compute_dtype)
        x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(cdt)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
        return cns(x, ("pod", "data"), None, None)

    def _stack_forward(self, params, x, mode, cache, cache_len, positions):
        cfg, run = self.cfg, self.run
        pat = self.pattern
        n = self.n_full_cycles

        def cycle_body(x, inp):
            cyc_params, cyc_cache = inp
            new_caches = {}
            for i, kind in enumerate(pat):
                key = f"{i}{kind}"
                c = None if cyc_cache is None else cyc_cache[key]
                x, nc = block_apply(cyc_params[key], x, cfg, run, kind,
                                    mode, c, cache_len, positions)
                new_caches[key] = nc
            return x, new_caches

        body = cycle_body
        if run.remat in ("block", "full") and mode == "train":
            body = jax.checkpoint(cycle_body)

        if n > 0:
            cyc_caches = None if cache is None else cache["cycles"]
            if cache is None:
                def scan_body(x, p):
                    x, _ = body(x, (p, None))
                    return x, None
                x, _ = jax.lax.scan(scan_body, x, params["cycles"])
                new_cyc = None
            else:
                def scan_body(x, pc):
                    p, c = pc
                    x, nc = body(x, (p, c))
                    return x, nc
                x, new_cyc = jax.lax.scan(scan_body, x,
                                          (params["cycles"], cyc_caches))
        else:
            new_cyc = None

        new_tail = {}
        for i, kind in enumerate(self.tail_kinds):
            key = f"{i}{kind}"
            c = None if cache is None else cache["tail"][key]
            x, nc = block_apply(params["tail"][key], x, cfg, run, kind,
                                mode, c, cache_len, positions)
            new_tail[key] = nc

        new_cache = None
        if cache is not None:
            new_cache = {"cycles": new_cyc, "tail": new_tail}
        return x, new_cache

    def hidden(self, params, tokens, extra_embeds=None, mode="train",
               cache=None, cache_len=None, positions=None):
        x = self._embed(params, tokens, extra_embeds)
        S = x.shape[1]
        if positions is None:
            if mode == "decode":
                cl = jnp.asarray(cache_len if cache_len is not None else 0)
                positions = (jnp.broadcast_to(cl, (x.shape[0],))
                             .astype(jnp.int32)[:, None])     # [B, 1]
            else:
                positions = jnp.arange(S)[None, :]
        x, new_cache = self._stack_forward(params, x, mode, cache, cache_len,
                                           positions)
        x = L.norm_apply(params["final_norm"], x, self.cfg.norm, self.cfg.norm_eps)
        return x, new_cache

    def unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"]["tok"].T     # [D, V]
        return params["lm_head"]

    def logits(self, params, hidden):
        cdt = hidden.dtype
        logits = hidden @ self.unembed(params).astype(cdt)
        if self.cfg.logit_softcap:
            logits = jnp.tanh(logits / self.cfg.logit_softcap) * self.cfg.logit_softcap
        logits = _mask_pad_logits(logits, self.cfg)
        return cns(logits, ("pod", "data"), None, "model")

    # -- loss (chunked over sequence, vocab-sharded) ----------------------
    def loss(self, params, tokens, labels, extra_embeds=None):
        h, _ = self.hidden(params, tokens, extra_embeds, mode="train")
        return self.chunked_xent(params, h, labels)

    def chunked_xent(self, params, h, labels):
        """Mean token xent without materializing [B, S, V] at once."""
        B, S, D = h.shape
        chunk = min(self.run.loss_chunk, S)
        n = (S + chunk - 1) // chunk
        pad = n * chunk - S
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        hs = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
        emb = self.unembed(params)
        cap = self.cfg.logit_softcap

        def chunk_loss(carry, inp):
            hc, lc = inp
            logits = (hc @ emb.astype(hc.dtype)).astype(jnp.float32)
            if cap:
                logits = jnp.tanh(logits / cap) * cap
            logits = _mask_pad_logits(logits, self.cfg)
            logits = cns(logits, ("pod", "data"), None, "model")
            lse = jax.nn.logsumexp(logits, axis=-1)
            valid = lc >= 0
            tgt = jnp.take_along_axis(
                logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            nll = (lse - tgt) * valid
            return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (hs, ls))
        return tot / jnp.maximum(cnt, 1.0)

    # -- serving ----------------------------------------------------------
    def prefill(self, params, tokens, cache, extra_embeds=None):
        """Returns (new_cache, last_position_logits)."""
        h, new_cache = self.hidden(params, tokens, extra_embeds,
                                   mode="prefill", cache=cache, cache_len=None)
        last = h[:, -1:]
        return new_cache, self.logits(params, last)

    def decode_step(self, params, token, cache, cache_len):
        """token: [B, 1] -> (new_cache, logits [B, 1, V])."""
        h, new_cache = self.hidden(params, token, mode="decode",
                                   cache=cache, cache_len=cache_len)
        return new_cache, self.logits(params, h)
