"""Encoder-decoder transformer (whisper-style) sharing the layer toolbox.

The audio conv frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings [B, S_enc, D] (what the two conv layers would
emit).  Encoder = bidirectional self-attention blocks; decoder = causal
self-attention + cross-attention + MLP.  Sinusoidal positions throughout
(length-agnostic, so the synthetic 32k/500k shape cells remain lowerable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models.sharding import cns
from repro.models.transformer import _write_prefill_cache, _write_decode_cache


def sinusoidal(positions, d_model, dtype):
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


def xattn_init(key, cfg):
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim()
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], (d, h * dh)),
        "wk": L.dense_init(ks[1], (d, h * dh)),
        "wv": L.dense_init(ks[2], (d, h * dh)),
        "wo": L.dense_init(ks[3], (h * dh, d)),
    }


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.norm_init(cfg.d_model),
        "attn": L.attn_init(ks[0], cfg),
        "ln2": L.norm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg, gated=cfg.mlp_gated),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.norm_init(cfg.d_model),
        "attn": L.attn_init(ks[0], cfg),
        "ln_x": L.norm_init(cfg.d_model),
        "cross": xattn_init(ks[1], cfg),
        "ln2": L.norm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[2], cfg, gated=cfg.mlp_gated),
    }


def _cross_attend(p, x, cfg, run, xk, xv):
    """x: [B, Sq, D]; xk/xv: [B, Se, H, Dh] precomputed encoder projections."""
    B, Sq, _ = x.shape
    h, dh = cfg.num_heads, cfg.resolved_head_dim()
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, Sq, h, dh)
    q = cns(q, None, None, "model", None)
    o = L.blockwise_attention(q, xk, xv, causal=False, softcap=None,
                              q_chunk=run.q_chunk, kv_chunk=run.kv_chunk)
    return (o.reshape(B, Sq, h * dh) @ p["wo"].astype(x.dtype))


@dataclasses.dataclass
class EncDecLM:
    cfg: ModelConfig
    run: RunConfig = RunConfig()

    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        from repro.models.transformer import padded_vocab
        return {
            "embed": {"tok": (jax.random.normal(
                ks[0], (padded_vocab(cfg), cfg.d_model)) * 0.02).astype(jnp.float32)},
            "encoder": jax.vmap(lambda k: _enc_block_init(k, cfg))(
                jax.random.split(ks[1], cfg.encoder_layers)),
            "enc_norm": L.norm_init(cfg.d_model),
            "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg))(
                jax.random.split(ks[2], cfg.num_layers)),
            "final_norm": L.norm_init(cfg.d_model),
        }

    # -- encoder -----------------------------------------------------------
    def encode(self, params, frames):
        """frames: [B, Se, D] precomputed conv-frontend output (stub)."""
        cfg, run = self.cfg, self.run
        cdt = jnp.dtype(run.compute_dtype)
        x = frames.astype(cdt)
        x = x + sinusoidal(jnp.arange(x.shape[1]), cfg.d_model, cdt)[None]
        x = cns(x, ("pod", "data"), None, None)
        positions = jnp.arange(x.shape[1])[None, :]

        def body(x, p):
            h = L.norm_apply(p["ln1"], x, cfg.norm, cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, cfg, positions, run.attn_shard)
            o = L.blockwise_attention(q, k, v, causal=False,
                                      q_chunk=run.q_chunk, kv_chunk=run.kv_chunk)
            x = x + L.attn_out(p["attn"], o, cfg, run.attn_shard)
            h = L.norm_apply(p["ln2"], x, cfg.norm, cfg.norm_eps)
            x = x + L.mlp_apply(p["mlp"], h, cfg)
            return x, None

        if run.remat in ("block", "full"):
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return L.norm_apply(params["enc_norm"], x, cfg.norm, cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Precompute cross-attention K/V per decoder layer: [Ld, B, Se, H, Dh]."""
        cfg = self.cfg
        h, dh = cfg.num_heads, cfg.resolved_head_dim()
        B, Se, _ = enc_out.shape

        def per_layer(p):
            xk = (enc_out @ p["cross"]["wk"].astype(enc_out.dtype)).reshape(B, Se, h, dh)
            xv = (enc_out @ p["cross"]["wv"].astype(enc_out.dtype)).reshape(B, Se, h, dh)
            return xk, xv

        return jax.vmap(per_layer)(params["decoder"])

    # -- decoder -----------------------------------------------------------
    def _dec_forward(self, params, tokens, xkv, mode, cache, cache_len):
        cfg, run = self.cfg, self.run
        cdt = jnp.dtype(run.compute_dtype)
        x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(cdt)
        S = x.shape[1]
        if mode == "decode":
            positions = (jnp.zeros((1, 1), jnp.int32) + cache_len)
        else:
            positions = jnp.arange(S)[None, :]
        x = x + sinusoidal(positions, cfg.d_model, cdt)
        x = cns(x, ("pod", "data"), None, None)

        def body(x, inp):
            p, xk, xv, c = inp
            h = L.norm_apply(p["ln1"], x, cfg.norm, cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, cfg, positions, run.attn_shard)
            nc = c
            if mode == "train":
                o = L.blockwise_attention(q, k, v, causal=True,
                                          q_chunk=run.q_chunk, kv_chunk=run.kv_chunk)
            elif mode == "prefill":
                o = L.blockwise_attention(q, k, v, causal=True,
                                          q_chunk=run.q_chunk, kv_chunk=run.kv_chunk)
                nc = _write_prefill_cache(c, k, v, None)
            else:
                nc = _write_decode_cache(c, k, v, cache_len, None)
                o = L.decode_attention(q, nc["k"], nc["v"], cache_len + 1)
            x = x + L.attn_out(p["attn"], o, cfg, run.attn_shard)
            h = L.norm_apply(p["ln_x"], x, cfg.norm, cfg.norm_eps)
            x = x + _cross_attend(p["cross"], h, cfg, run, xk, xv)
            h = L.norm_apply(p["ln2"], x, cfg.norm, cfg.norm_eps)
            x = x + L.mlp_apply(p["mlp"], h, cfg)
            return x, nc

        if run.remat in ("block", "full") and mode == "train":
            body = jax.checkpoint(body)

        xk_all, xv_all = xkv
        caches = cache["dec"] if cache is not None else jax.tree.map(
            lambda a: None, params["decoder"], is_leaf=lambda _: True)
        if cache is None:
            def scan_body(x, inp):
                p, xk, xv = inp
                x, _ = body(x, (p, xk, xv, None))
                return x, None
            x, _ = jax.lax.scan(scan_body, x, (params["decoder"], xk_all, xv_all))
            new_dec = None
        else:
            def scan_body(x, inp):
                p, xk, xv, c = inp
                x, nc = body(x, (p, xk, xv, c))
                return x, nc
            x, new_dec = jax.lax.scan(
                scan_body, x, (params["decoder"], xk_all, xv_all, caches))
        x = L.norm_apply(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        new_cache = None
        if cache is not None:
            new_cache = {"dec": new_dec, "xkv": xkv}
        return x, new_cache

    # -- public API ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        hkv, dh = cfg.num_heads, cfg.resolved_head_dim()   # decoder is MHA
        ld = cfg.num_layers
        kv = jnp.zeros((ld, batch, max_len, hkv, dh), dtype)
        return {"dec": {"k": kv, "v": kv}, "xkv": None}

    def loss(self, params, tokens, labels, enc_frames):
        enc = self.encode(params, enc_frames)
        xkv = self._cross_kv(params, enc)
        h, _ = self._dec_forward(params, tokens, xkv, "train", None, None)
        from repro.models.transformer import LM
        helper = LM(self.cfg, self.run)
        return helper.chunked_xent(params, h, labels)

    def prefill(self, params, tokens, cache, enc_frames):
        enc = self.encode(params, enc_frames)
        xkv = self._cross_kv(params, enc)
        h, new_cache = self._dec_forward(params, tokens, xkv, "prefill",
                                         {"dec": cache["dec"]}, None)
        logits = self._logits(params, h[:, -1:])
        return new_cache, logits

    def decode_step(self, params, token, cache, cache_len):
        h, new_cache = self._dec_forward(params, token, cache["xkv"], "decode",
                                         {"dec": cache["dec"]}, cache_len)
        return {"dec": new_cache["dec"], "xkv": cache["xkv"]}, self._logits(params, h)

    def _logits(self, params, h):
        from repro.models.transformer import _mask_pad_logits
        logits = h @ params["embed"]["tok"].T.astype(h.dtype)
        logits = _mask_pad_logits(logits, self.cfg)
        return cns(logits, ("pod", "data"), None, "model")
