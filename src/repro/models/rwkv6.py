"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay, plus the squared-ReLU channel mix.

Time mix (per head, Dk = Dv = head size):
    state_t = diag(w_t) state_{t-1} + k_t^T v_t          [Dk, Dv]
    out_t   = r_t (state_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + lora_w(x_t))) in (0,1) data-dependent.

Train/prefill runs a lax.scan over time (baseline; the chunked parallel
form is a §Perf optimization), decode is the single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, norm_apply, norm_init
from repro.models.sharding import cns

LORA_RANK = 32


def rwkv_head_dim(cfg) -> int:
    return 64


def rwkv_init(key, cfg):
    d = cfg.d_model
    dh = rwkv_head_dim(cfg)
    h = d // dh
    ks = jax.random.split(key, 12)
    tm = {
        "mix": jax.random.uniform(ks[0], (5, d)),            # r,k,v,g,w mixes
        "wr": dense_init(ks[1], (d, d)),
        "wk": dense_init(ks[2], (d, d)),
        "wv": dense_init(ks[3], (d, d)),
        "wg": dense_init(ks[4], (d, d)),
        "w_decay": jnp.full((h, dh), -2.0)                    # w0 base decay
        + jax.random.normal(ks[5], (h, dh)) * 0.1,
        "decay_lora_a": dense_init(ks[6], (d, LORA_RANK)),
        "decay_lora_b": dense_init(ks[7], (LORA_RANK, d)) * 0.1,
        "u": jax.random.normal(ks[8], (h, dh)) * 0.5,         # bonus
        "ln_out": norm_init(d),
        "wo": dense_init(ks[9], (d, d)),
    }
    cm = {
        "mix": jax.random.uniform(ks[10], (2, d)),
        "wk": dense_init(ks[11], (d, cfg.d_ff)),
        "wv": dense_init(ks[11], (cfg.d_ff, d)),
        "wr": dense_init(ks[10], (d, d)),
    }
    return {"tmix": tm, "cmix": cm}


def _token_shift(x, last):
    """previous token's activation; last: [B, 1, D] carried state."""
    prev = jnp.concatenate([last.astype(x.dtype), x[:, :-1]], axis=1)
    return prev


def _tm_inputs(p, x, prev):
    xx = prev - x
    mix = p["mix"].astype(x.dtype)
    xr = x + xx * mix[0]
    xk = x + xx * mix[1]
    xv = x + xx * mix[2]
    xg = x + xx * mix[3]
    xw = x + xx * mix[4]
    return xr, xk, xv, xg, xw


def time_mix(p, x, cfg, cache=None):
    """x: [B, S, D]; cache: {"shift": [B,1,D], "state": [B,H,Dk,Dv]} or None."""
    B, S, D = x.shape
    dh = rwkv_head_dim(cfg)
    H = D // dh
    cdt = x.dtype
    last = (jnp.zeros((B, 1, D), cdt) if cache is None else cache["shift"])
    prev = _token_shift(x, last)
    xr, xk, xv, xg, xw = _tm_inputs(p, x, prev)

    r = (xr @ p["wr"].astype(cdt)).reshape(B, S, H, dh)
    k = (xk @ p["wk"].astype(cdt)).reshape(B, S, H, dh)
    v = (xv @ p["wv"].astype(cdt)).reshape(B, S, H, dh)
    g = jax.nn.silu(xg @ p["wg"].astype(cdt))
    lora = jnp.tanh(xw @ p["decay_lora_a"].astype(cdt)) @ p["decay_lora_b"].astype(cdt)
    w = jnp.exp(-jnp.exp(
        (p["w_decay"].reshape(1, 1, H, dh) + lora.reshape(B, S, H, dh))
        .astype(jnp.float32)))                                  # [B,S,H,dh]

    u = p["u"].astype(jnp.float32)
    state0 = (jnp.zeros((B, H, dh, dh), jnp.float32)
              if cache is None else cache["state"])

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                               # [B,H,dh] each
        kv = k_t[..., :, None] * v_t[..., None, :]             # [B,H,dk,dv]
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, out

    rs = r.transpose(1, 0, 2, 3).astype(jnp.float32)
    ks_ = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vs = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    ws = w.transpose(1, 0, 2, 3)
    state, outs = jax.lax.scan(step, state0, (rs, ks_, vs, ws))
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(cdt)

    out = norm_apply(p["ln_out"], out, "rmsnorm", cfg.norm_eps) * g
    out = out @ p["wo"].astype(cdt)
    new_cache = {"shift": x[:, -1:], "state": state}
    return cns(out, ("pod", "data"), None, None), new_cache


def channel_mix(p, x, cfg, cache=None):
    B, S, D = x.shape
    cdt = x.dtype
    last = (jnp.zeros((B, 1, D), cdt) if cache is None else cache["shift"])
    prev = _token_shift(x, last)
    xx = prev - x
    mix = p["mix"].astype(cdt)
    xk = x + xx * mix[0]
    xr = x + xx * mix[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cdt)))
    k = cns(k, ("pod", "data"), None, "model")
    r = jax.nn.sigmoid(xr @ p["wr"].astype(cdt))
    out = r * (k @ p["wv"].astype(cdt))
    return cns(out, ("pod", "data"), None, None), {"shift": x[:, -1:]}


def rwkv_block_apply(p, x, cfg, ln1, ln2, cache=None):
    """Full RWKV block: x + TimeMix(ln1(x)); x + ChannelMix(ln2(x))."""
    tc = None if cache is None else cache["tmix"]
    cc = None if cache is None else cache["cmix"]
    h, new_tc = time_mix(p["tmix"], norm_apply(ln1, x, cfg.norm, cfg.norm_eps),
                         cfg, tc)
    x = x + h
    h, new_cc = channel_mix(p["cmix"], norm_apply(ln2, x, cfg.norm, cfg.norm_eps),
                            cfg, cc)
    x = x + h
    new_cache = None if cache is None else {"tmix": new_tc, "cmix": new_cc}
    if cache is None:
        new_cache = {"tmix": new_tc, "cmix": new_cc}
    return x, new_cache


def rwkv_cache_init(batch: int, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    dh = rwkv_head_dim(cfg)
    h = d // dh
    return {
        "tmix": {"shift": jnp.zeros((batch, 1, d), dtype),
                 "state": jnp.zeros((batch, h, dh, dh), jnp.float32)},
        "cmix": {"shift": jnp.zeros((batch, 1, d), dtype)},
    }
