# LM model stack: layers, attention, MoE, RG-LRU, RWKV6, enc-dec, zoo.
