"""Transformer building blocks: norms, rope, GQA attention (blockwise
online-softmax for train/prefill, cached for decode), gated MLP.

All functions are pure; parameters are plain dicts of jnp arrays.  Compute
dtype is the caller's (bf16 by default), softmax/normalization statistics
in f32.  Activation sharding constraints come from models.sharding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import cns

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size=None):
    fan_in = in_axis_size or shape[-2] if len(shape) >= 2 else shape[0]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std)


def norm_init(dim):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def norm_apply(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(s, cap: Optional[float]):
    if cap is None:
        return s
    return jnp.tanh(s / cap) * cap


# ---------------------------------------------------------------------------
# blockwise attention (train / prefill): online softmax over kv chunks
# ---------------------------------------------------------------------------

def _pad_seq(x, chunk, axis):
    s = x.shape[axis]
    pad = (-s) % chunk
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


def blockwise_attention(
    q: jax.Array,              # [B, Sq, H, Dh]
    k: jax.Array,              # [B, Skv, Hkv, Dh]
    v: jax.Array,              # [B, Skv, Hkv, Dh]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,         # global position of q[0] (prefill continuation)
    scores_dtype=jnp.float32,  # bf16 halves score-block traffic; softmax
    #                            statistics stay f32 (§Perf It5)
) -> jax.Array:
    B, Sq0, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = Dh ** -0.5

    q, Sq = _pad_seq(q, q_chunk, 1)
    k, Skv = _pad_seq(k, kv_chunk, 1)
    v, _ = _pad_seq(v, kv_chunk, 1)
    nq = q.shape[1] // q_chunk
    nk = k.shape[1] // kv_chunk

    qb = (q.reshape(B, nq, q_chunk, Hkv, G, Dh) * scale).astype(q.dtype)
    kb = k.reshape(B, nk, kv_chunk, Hkv, Dh)
    vb = v.reshape(B, nk, kv_chunk, Hkv, Dh)

    q_pos0 = jnp.arange(q_chunk)
    k_pos0 = jnp.arange(kv_chunk)

    # windowed attention only needs kv chunks within [q - window, q]:
    # scan that fixed-size range instead of all nk chunks (§Perf: for
    # gemma2/recurrentgemma local layers this cuts the kv loop from
    # S/kc chunks to (window+qc)/kc + 1).
    nk_eff = nk
    if window is not None and causal:
        nk_eff = min(nk, (window + q_chunk) // kv_chunk + 2)

    def q_step(_, qi):
        qblk = qb[:, qi]                       # [B, qc, Hkv, G, Dh]
        q_pos = q_offset + qi * q_chunk + q_pos0

        def kv_step(carry, rel):
            m, l, o = carry
            if nk_eff != nk:
                raw = qi + (q_offset // kv_chunk) - rel
                ki = jnp.maximum(raw, 0)
                in_range = raw >= 0          # clamped duplicates are masked
            else:
                ki = rel
                in_range = jnp.array(True)
            k_pos = ki * kv_chunk + k_pos0

            # static-shape runtime skip: chunk fully masked -> no compute
            last_q = q_offset + qi * q_chunk + (q_chunk - 1)
            first_q = q_offset + qi * q_chunk
            first_k = ki * kv_chunk
            last_k = ki * kv_chunk + (kv_chunk - 1)
            needed = in_range
            if causal:
                needed = needed & (first_k <= last_q)
            if window is not None:
                needed = needed & (last_k >= first_q - window)

            kblk = jax.lax.dynamic_index_in_dim(kb, ki, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vb, ki, 1, keepdims=False)

            def compute(args):
                m, l, o = args
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qblk, kblk,
                    preferred_element_type=scores_dtype,
                ).astype(jnp.float32)
                s = _softcap(s, softcap)
                mask = k_pos[None, :] <= (q_pos[:, None] if causal else jnp.inf)
                if window is not None:
                    mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
                mask = mask & (k_pos[None, :] < Skv)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                pv = jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vblk,
                    preferred_element_type=jnp.float32,
                )
                o_new = o * corr[..., None] + pv
                return m_new, l_new, o_new

            carry = jax.lax.cond(needed, compute, lambda a: a, (m, l, o))
            return carry, None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, q_chunk, Dh), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk_eff))
        out = o / jnp.maximum(l[..., None], 1e-30)
        # [B, Hkv, G, qc, Dh] -> [B, qc, Hkv*G, Dh]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, Dh)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))   # [nq, B, qc, H, Dh]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, Dh)
    return out[:, :Sq0]


# ---------------------------------------------------------------------------
# decode attention (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,              # [B, 1, H, Dh]
    k_cache: jax.Array,        # [B, S, Hkv, Dh]
    v_cache: jax.Array,
    cache_len: jax.Array,      # [] or [B] valid prefix length (new token incl.)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    B, _, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = Dh ** -0.5
    qg = (q.reshape(B, Hkv, G, Dh) * scale)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = _softcap(s, softcap)
    pos = jnp.arange(S)
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim == 1 else cl[None, None]
    valid = pos[None, :] < cl                      # [B or 1, S]
    if window is not None:
        valid = valid & (pos[None, :] >= cl - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


def seq_sharded_decode_attention(
    q, k_cache, v_cache, cache_len, mesh, seq_axis: str,
    *, softcap: Optional[float] = None,
):
    """Flash-decoding over a sharded KV sequence axis: each shard computes a
    partial (max, sum, out) over its KV slice; merged with pmax/psum.
    Used for long-context decode where one device cannot hold the cache."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map_unchecked

    B, _, H, Dh = q.shape
    S = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    G = H // Hkv
    shard = S // mesh.shape[seq_axis]
    scale = Dh ** -0.5

    def local(q, k, v, cl):
        idx = jax.lax.axis_index(seq_axis)
        qg = q.reshape(B, Hkv, G, Dh) * scale
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                       preferred_element_type=jnp.float32)
        s = _softcap(s, softcap)
        pos = idx * shard + jnp.arange(shard)
        cl = jnp.asarray(cl)
        cl = cl[:, None] if cl.ndim == 1 else cl[None, None]
        valid = pos[None, :] < cl
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = s.max(axis=-1)
        m_glb = jax.lax.pmax(m_loc, seq_axis)
        p = jnp.exp(s - m_glb[..., None])
        l_glb = jax.lax.psum(p.sum(axis=-1), seq_axis)
        o_loc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                           preferred_element_type=jnp.float32)
        o = jax.lax.psum(o_loc, seq_axis) / jnp.maximum(l_glb[..., None], 1e-30)
        return o.reshape(B, 1, H, Dh).astype(q.dtype)

    fn = shard_map_unchecked(
        local, mesh=mesh,
        in_specs=(P(), P(None, seq_axis), P(None, seq_axis), P()),
        out_specs=P(),
    )
    return fn(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# attention block params
# ---------------------------------------------------------------------------

def attn_init(key, cfg):
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, hkv * dh)),
        "wv": dense_init(ks[2], (d, hkv * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qk_norm:
        p["q_ln"] = norm_init(dh)
        p["k_ln"] = norm_init(dh)
    return p


def attn_qkv(p, x, cfg, positions, attn_shard: str = "heads"):
    """Project + rope.  x: [B, S, D] -> q [B,S,H,Dh], k/v [B,S,Hkv,Dh].

    attn_shard="flat" (§Perf It-LM1) constrains the projection *outputs* on
    the flattened H*Dh dim, which always divides the model axis — the
    projections stay tensor-parallel even when the head count doesn't
    divide (qwen3: 40 heads on a 16-wide axis).  XLA reshards at the
    reshape into heads only for the (much cheaper) score computation.
    """
    B, S, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    cdt = x.dtype
    qf = x @ p["wq"].astype(cdt)
    kf = x @ p["wk"].astype(cdt)
    vf = x @ p["wv"].astype(cdt)
    if attn_shard == "flat":
        qf = cns(qf, ("pod", "data"), None, "model")
        kf = cns(kf, ("pod", "data"), None, "model")
        vf = cns(vf, ("pod", "data"), None, "model")
    q = qf.reshape(B, S, h, dh)
    k = kf.reshape(B, S, hkv, dh)
    v = vf.reshape(B, S, hkv, dh)
    if attn_shard == "heads":
        q = cns(q, ("pod", "data"), None, "model", None)
    if cfg.qk_norm:
        q = norm_apply(p["q_ln"], q, cfg.norm, cfg.norm_eps)
        k = norm_apply(p["k_ln"], k, cfg.norm, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p, o, cfg, attn_shard: str = "heads"):
    B, S, h, dh = o.shape
    of = o.reshape(B, S, h * dh)
    if attn_shard == "flat":
        of = cns(of, ("pod", "data"), None, "model")  # row-parallel contraction
    y = of @ p["wo"].astype(o.dtype)
    return cns(y, ("pod", "data"), None, None)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, gated: Optional[bool] = None):
    gated = cfg.mlp_gated if gated is None else gated
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d, f)), "wo": dense_init(ks[1], (f, d))}
    if gated:
        p["wg"] = dense_init(ks[2], (d, f))
    return p


def _act(x, kind):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_apply(p, x, cfg):
    cdt = x.dtype
    hi = x @ p["wi"].astype(cdt)
    hi = cns(hi, ("pod", "data"), None, "model")
    if "wg" in p:
        hi = _act(x @ p["wg"].astype(cdt), cfg.act) * hi
    else:
        hi = _act(hi, cfg.act)
    y = hi @ p["wo"].astype(cdt)
    return cns(y, ("pod", "data"), None, None)
