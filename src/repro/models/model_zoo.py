"""Build a model object (LM or EncDecLM) from a ModelConfig."""
from __future__ import annotations

from repro.configs.base import ModelConfig, RunConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM


def build_model(cfg: ModelConfig, run: RunConfig = RunConfig()):
    if cfg.encoder_layers > 0:
        return EncDecLM(cfg, run)
    return LM(cfg, run)


def param_count(params) -> int:
    import jax
    import numpy as np

    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


def active_param_count(cfg: ModelConfig, params) -> int:
    """MoE-aware: router + top-k experts only (for MODEL_FLOPS = 6*N_active*D)."""
    n = param_count(params)
    if not cfg.moe:
        return n
    # subtract the inactive experts' share of the expert weights
    import jax
    import numpy as np

    expert = 0
    def walk(tree, path=""):
        nonlocal expert
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + "/" + k)
        else:
            if "/moe/" in path and path.rsplit("/", 1)[-1] in ("wi", "wg", "wo"):
                expert += int(np.prod(tree.shape))
    walk(params)
    inactive = expert * (1 - cfg.experts_per_token / max(cfg.num_experts, 1))
    return int(n - inactive)
