"""Elastic rescale: move a train/serve state between meshes of different
size or shape.

Checkpoints store full logical arrays (train.checkpoint), so rescaling is
"restore with the new mesh's shardings".  This module adds the in-memory
variant (device-to-device resharding without a disk round-trip) and the
recipe used by launch/train.py when the world size changes:

    new_shardings = state_shardings(new_mesh)
    state = reshard(state, new_shardings)

The graph engine rescales by re-running stage-2 tile assignment
(partition.assign_tiles) for the new N — tiles are mesh-agnostic.
"""
from __future__ import annotations

import jax


def reshard(tree, new_shardings):
    """Device-put every leaf onto its new sharding (works across meshes)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings,
        is_leaf=lambda x: not isinstance(x, dict),
    )


def rescale_via_checkpoint(ckpt_mgr, step, state, new_shardings):
    """Disk-mediated rescale (what a real job restart does)."""
    ckpt_mgr.save(step, state)
    return ckpt_mgr.restore(step, shardings=new_shardings)
