"""Elastic rescale: move a train/serve state between meshes of different
size or shape.

Checkpoints store full logical arrays (train.checkpoint), so rescaling is
"restore with the new mesh's shardings".  This module adds the in-memory
variant (device-to-device resharding without a disk round-trip) and the
recipe used by launch/train.py when the world size changes:

    new_shardings = state_shardings(new_mesh)
    state = reshard(state, new_shardings)

The graph engine rescales by re-running stage-2 tile assignment
(partition.assign_tiles) for the new N — tiles are mesh-agnostic.  The
multi-process cluster runtime (DESIGN.md §11) adds a warmth-preserving
variant: ``remap_assignment`` resizes an existing per-server tile
assignment to a new server count while keeping every tile that can stay on
its current server there, so surviving servers keep their edge caches hot
across the resize.
"""
from __future__ import annotations

import jax
import numpy as np


def reshard(tree, new_shardings):
    """Device-put every leaf onto its new sharding (works across meshes)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings,
        is_leaf=lambda x: not isinstance(x, dict),
    )


def rescale_via_checkpoint(ckpt_mgr, step, state, new_shardings):
    """Disk-mediated rescale (what a real job restart does)."""
    ckpt_mgr.save(step, state)
    return ckpt_mgr.restore(step, shardings=new_shardings)


def remap_assignment(old: list[list[int]], new_n: int,
                     edges_per_tile) -> list[list[int]]:
    """Resize a per-server tile assignment to ``new_n`` servers,
    maximizing cache warmth (DESIGN.md §11).

    Tiles owned by a surviving server (old rank < ``new_n``) stay put —
    their compressed blobs are already in that server's edge cache.  Tiles
    orphaned by removed servers are placed greedily (largest edge count
    first) onto the least-edge-loaded survivor; only when the cluster
    *grows* do the new empty servers absorb work from the most-loaded
    survivors until no move improves the edge balance (on shrink the
    survivors' own tiles are never touched — that cold-rereading churn is
    exactly what this function exists to avoid).  Deterministic: ties
    break toward lower server rank and lower tile id.
    """
    if new_n < 1:
        raise ValueError("new_n must be >= 1")
    edges = np.asarray(edges_per_tile, dtype=np.int64)
    new = [list(old[s]) if s < len(old) else [] for s in range(new_n)]
    orphans = sorted((t for s in range(new_n, len(old)) for t in old[s]),
                     key=lambda t: (-edges[t], t))
    load = np.array([sum(int(edges[t]) for t in ts) for ts in new])
    for t in orphans:
        d = int(np.argmin(load))
        new[d].append(t)
        load[d] += int(edges[t])
    # growth only: drain the most-loaded survivors into the new empty
    # servers while a move strictly improves the max load
    while new_n > len(old):
        hi, lo = int(np.argmax(load)), int(np.argmin(load))
        movable = sorted(new[hi], key=lambda t: (-edges[t], t))
        best = next((t for t in movable
                     if load[lo] + edges[t] < load[hi]), None)
        if best is None:
            break
        new[hi].remove(best)
        new[lo].append(best)
        load[hi] -= int(edges[best])
        load[lo] += int(edges[best])
    return [sorted(ts) for ts in new]


def handoff_plan(old: list[list[int]], new: list[list[int]],
                 tile_bytes) -> dict:
    """Account the data movement a resize implies (DESIGN.md §12).

    For assignments ``old`` -> ``new`` over the same tile universe,
    returns ``{"moves": [(tile, src_rank, dst_rank)], "bytes": total,
    "per_dst_bytes": {dst_rank: bytes}}`` — one entry per tile whose
    owner changed, costed by ``tile_bytes[tile]`` (on-disk tile bytes:
    the new owner must fault the tile cold while survivors' unchanged
    tiles ride their warm caches; vertex state is replicated, so tiles
    are the only warmth that moves).  Tiles present only in ``new``
    (never owned before) count as moves from src ``-1``."""
    tile_bytes = np.asarray(tile_bytes, dtype=np.int64)
    src = {t: s for s, ts in enumerate(old) for t in ts}
    moves = []
    per_dst: dict[int, int] = {}
    for d, ts in enumerate(new):
        for t in ts:
            s = src.get(t, -1)
            if s != d:
                moves.append((int(t), s, d))
                per_dst[d] = per_dst.get(d, 0) + int(tile_bytes[t])
    return {"moves": moves,
            "bytes": int(sum(int(tile_bytes[t]) for t, _s, _d in moves)),
            "per_dst_bytes": per_dst}
