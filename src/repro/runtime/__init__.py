# Runtime services: fault tolerance, tile scheduling, elastic rescale.
