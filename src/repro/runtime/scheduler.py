"""Tile scheduler: work stealing + straggler mitigation for the GAB engine.

The paper assigns tile i to server ``i mod N`` statically (its stage-2).
At 1000+ nodes two failure modes appear: (a) skewed tiles make some
servers finish late, (b) slow/flaky nodes straggle an entire BSP
superstep.  This module adds, beyond the paper:

  * WorkStealingScheduler — per-server deques; an idle server steals the
    largest pending tile from the most-loaded peer (locality-aware: the
    victim's cache keeps the tile, the thief reads from the shared store).
  * speculative re-execution — tiles still pending after
    ``straggler_factor x`` the median tile time are duplicated onto idle
    servers; BSP tile idempotence (disjoint dst ranges, pure gather/apply)
    makes duplicate completion safe: first writer wins, results identical.
  * rebalance_assignment — the *cluster-runtime* variant (DESIGN.md §11):
    between BSP supersteps, every server process runs this same pure
    function on the same replicated inputs (per-server measured compute
    seconds, shipped in the exchange frame headers) and deterministically
    moves tiles off stragglers, so all servers agree on the next
    superstep's ownership with no coordinator.

Scheduling is host-side (like the paper's MPE main loop); the engine uses
it to order cache fetches + device dispatches.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class TileTask:
    tile_id: int
    est_cost: float            # edges (proxy for runtime)
    started_at: dict = dataclasses.field(default_factory=dict)  # server -> t
    done: bool = False
    result: object = None
    completed_by: Optional[int] = None


class WorkStealingScheduler:
    def __init__(self, assignment: list[list[int]], edges_per_tile,
                 straggler_factor: float = 3.0,
                 enable_speculation: bool = True):
        self.n_servers = len(assignment)
        self.tasks = {}
        self.queues: list[deque] = []
        for s, tids in enumerate(assignment):
            q = deque()
            for t in tids:
                task = TileTask(t, float(edges_per_tile[t]))
                self.tasks[t] = task
                q.append(t)
            self.queues.append(q)
        self.straggler_factor = straggler_factor
        self.enable_speculation = enable_speculation
        self.steals = 0
        self.speculative = 0
        self.durations: list[float] = []

    # -- acquisition ----------------------------------------------------
    def next_tile(self, server: int, now: Optional[float] = None) -> Optional[int]:
        """Next tile for `server`: own queue, else steal, else speculate."""
        now = time.perf_counter() if now is None else now
        q = self.queues[server]
        while q:
            t = q.popleft()
            if not self.tasks[t].done:
                self.tasks[t].started_at[server] = now
                return t
        # steal from the most-loaded peer (largest pending work)
        victim = max(range(self.n_servers),
                     key=lambda s: sum(self.tasks[t].est_cost
                                       for t in self.queues[s]
                                       if not self.tasks[t].done))
        vq = self.queues[victim]
        while vq:
            t = vq.pop()           # steal from the tail (victim works the head)
            if not self.tasks[t].done:
                self.steals += 1
                self.tasks[t].started_at[server] = now
                return t
        if self.enable_speculation:
            t = self._speculative_candidate(server, now)
            if t is not None:
                self.speculative += 1
                self.tasks[t].started_at[server] = now
                return t
        return None

    def _speculative_candidate(self, server: int, now: float) -> Optional[int]:
        if not self.durations:
            return None
        median = float(np.median(self.durations))
        worst, worst_t = None, None
        # lint: allow(GH205): tasks built in ascending tile-id order on every rank
        for t, task in self.tasks.items():
            if task.done or not task.started_at or server in task.started_at:
                continue
            age = now - min(task.started_at.values())
            if age > self.straggler_factor * median and \
                    (worst is None or age > worst):
                worst, worst_t = age, t
        return worst_t

    # -- completion -----------------------------------------------------
    def complete(self, server: int, tile_id: int, result=None,
                 now: Optional[float] = None) -> bool:
        """First completion wins (idempotent tiles).  Returns True if this
        call was the winning one."""
        now = time.perf_counter() if now is None else now
        task = self.tasks[tile_id]
        if task.done:
            return False
        task.done = True
        task.result = result
        task.completed_by = server
        if server in task.started_at:
            self.durations.append(now - task.started_at[server])
        return True

    def all_done(self) -> bool:
        return all(t.done for t in self.tasks.values())

    def pending(self) -> list[int]:
        # lint: allow(GH205): tasks built in ascending tile-id order on every rank
        return [t for t, task in self.tasks.items() if not task.done]

    def stats(self) -> dict:
        return dict(steals=self.steals, speculative=self.speculative,
                    tiles=len(self.tasks))


def rebalance_assignment(
    assignment: list[list[int]],
    edges_per_tile,
    server_seconds: list[float],
    straggler_factor: float = 1.5,
    max_move_fraction: float = 0.5,
) -> Optional[tuple[list[list[int]], int]]:
    """Cross-server tile stealing at BSP-superstep granularity.

    A server whose measured compute time exceeded ``straggler_factor`` x
    the median is a straggler; its tiles are moved — largest pending cost
    first, matching :class:`WorkStealingScheduler`'s steal order — onto
    the servers with the lowest *projected* next-superstep time, until the
    straggler's projection drops under the threshold or
    ``max_move_fraction`` of its tiles have moved.  Projections use each
    server's measured per-edge rate (seconds / currently assigned edges),
    so a server that is slow because its *hardware* is slow keeps
    shedding work rather than reabsorbing it.

    Pure and deterministic: every cluster server calls this with identical
    replicated inputs and derives the identical new assignment (ties break
    toward lower server rank).  Tile movement never changes results —
    tiles own disjoint dst rows and gather/apply is pure.

    Returns (new assignment, tiles moved), or None when no server
    straggled (callers keep the old assignment and skip the churn).
    """
    n = len(assignment)
    if n < 2:
        return None
    secs = np.asarray(server_seconds, dtype=np.float64)
    med = float(np.median(secs))
    if med <= 0.0:
        return None
    threshold = straggler_factor * med
    stragglers = [s for s in range(n) if secs[s] > threshold]
    if not stragglers:
        return None
    new = [list(a) for a in assignment]
    edges = np.asarray(edges_per_tile, dtype=np.float64)
    load = np.array([sum(edges[t] for t in ts) for ts in new])
    # measured per-edge seconds; a server with no tiles inherits the
    # cluster-best rate (it is free capacity, not infinitely fast)
    rate = np.where(load > 0, secs / np.maximum(load, 1.0), np.inf)
    rate = np.where(np.isfinite(rate), rate, rate[np.isfinite(rate)].min())
    moved = 0
    for s in sorted(stragglers):
        budget = max(1, int(len(new[s]) * max_move_fraction))
        moved_s = 0
        order = sorted(new[s], key=lambda t: (-edges[t], t))
        for t in order:
            if load[s] * rate[s] <= threshold or moved_s >= budget:
                break
            proj = load * rate
            proj[s] = np.inf   # never "move" a tile onto the straggler
            d = int(np.argmin(proj))   # argmin ties break to lower rank
            if (load[d] + edges[t]) * rate[d] >= load[s] * rate[s]:
                break          # the move would just create a new straggler
            new[s].remove(t)
            new[d].append(t)
            load[s] -= edges[t]
            load[d] += edges[t]
            moved += 1
            moved_s += 1
    if moved == 0:
        return None
    return new, moved


def simulate_superstep(scheduler: WorkStealingScheduler,
                       server_speed: np.ndarray,
                       tile_cost_fn: Callable[[int], float]) -> dict:
    """Event-driven simulation of one BSP superstep under heterogeneous
    server speeds (used by tests + the straggler benchmark): returns
    makespan + per-server busy time.

    First completion of a duplicated tile wins; a preempted duplicate's
    server simply becomes idle at the winner's completion time (modeling
    the BSP barrier discard)."""
    import heapq

    n = scheduler.n_servers
    busy = np.zeros(n)
    idle: set[int] = set()
    events: list = []          # (end_time, server, tile)
    makespan = 0.0

    def try_dispatch(s: int, now: float) -> bool:
        tile = scheduler.next_tile(s, now=now)
        if tile is None:
            idle.add(s)
            return False
        dt = tile_cost_fn(tile) / server_speed[s]
        busy[s] += dt
        heapq.heappush(events, (now + dt, s, tile))
        idle.discard(s)
        return True

    for s in range(n):
        try_dispatch(s, 0.0)

    def earliest_speculation() -> Optional[float]:
        if not (scheduler.enable_speculation and scheduler.durations and idle):
            return None
        median = float(np.median(scheduler.durations))
        cands = [min(task.started_at.values())
                 + scheduler.straggler_factor * median
                 # lint: allow(GH205): folded with min() below — order-insensitive
                 for task in scheduler.tasks.values()
                 if not task.done and task.started_at
                 and not idle.issubset(set(task.started_at))]
        return min(cands) if cands else None

    while events:
        # idle servers may become speculation-eligible before the next event
        t_spec = earliest_speculation()
        if t_spec is not None and t_spec < events[0][0]:
            for i in sorted(idle):
                try_dispatch(i, t_spec + 1e-9)
        now, s, tile = heapq.heappop(events)
        won = scheduler.complete(s, tile, now=now)
        if won:
            makespan = max(makespan, now)
        try_dispatch(s, now)
        # completion events update median durations; idle servers re-check
        # for newly eligible speculative work
        for i in sorted(idle):
            try_dispatch(i, now)
        if not events and not scheduler.all_done():
            # all runnable work is in flight on slow servers and no event is
            # pending for the idle ones; advance to the earliest time at
            # which speculation becomes eligible
            if scheduler.enable_speculation and scheduler.durations and idle:
                median = float(np.median(scheduler.durations))
                t_next = min(
                    (min(task.started_at.values())
                     + scheduler.straggler_factor * median)
                    for task in scheduler.tasks.values() if not task.done)
                for i in sorted(idle):
                    try_dispatch(i, t_next + 1e-9)
            if not events:
                break
    return dict(makespan=float(makespan), busy=busy.tolist(),
                **scheduler.stats())
