"""Fault-tolerance orchestration: periodic + preemption checkpointing,
crash-consistent resume, and failure-injection hooks for tests.

Works with train.checkpoint.CheckpointManager:
  * save every N steps (async-handoff friendly: state is device_get'd once)
  * SIGTERM/SIGINT => final checkpoint before exit (preemption handling)
  * resume() restores the latest checkpoint and the step counter; the data
    pipeline is step-indexed (train.data), so the token stream continues
    exactly where it left off.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional

from repro.train.checkpoint import CheckpointManager


class FaultTolerantLoop:
    def __init__(self, ckpt: CheckpointManager, save_every: int = 100,
                 on_preempt_save: bool = True):
        self.ckpt = ckpt
        self.save_every = save_every
        self.preempted = False
        self._prev_handlers = {}
        if on_preempt_save:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev_handlers[sig] = signal.signal(sig, self._on_signal)
                except ValueError:     # non-main thread (tests)
                    pass

    def _on_signal(self, signum, frame):
        self.preempted = True

    # ------------------------------------------------------------------
    def resume_or_init(self, init_fn: Callable, shardings=None):
        """(step, state): restore the latest checkpoint or build fresh."""
        latest = self.ckpt.latest_step()
        if latest is not None:
            step, state = self.ckpt.restore(latest, shardings=shardings)
            return step, state
        return 0, init_fn()

    def maybe_save(self, step: int, state, force: bool = False) -> bool:
        if force or self.preempted or (self.save_every and
                                       step % self.save_every == 0 and step > 0):
            self.ckpt.save(step, state)
            return True
        return False

    def should_stop(self) -> bool:
        return self.preempted

    def restore_handlers(self):
        for sig, h in self._prev_handlers.items():
            signal.signal(sig, h)


class FailureInjector:
    """Deterministic failure injection for resilience tests: raises
    SimulatedFailure at the given steps."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.failures = 0

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise SimulatedFailure(f"injected failure at step {step}")


class SimulatedFailure(RuntimeError):
    pass
