"""Fault-tolerance orchestration: periodic + preemption checkpointing,
crash-consistent resume, and failure-injection hooks for tests.

Two consumers share the preemption machinery here:

  * the training loop (FaultTolerantLoop + train.checkpoint): save every
    N steps, SIGTERM/SIGINT => final checkpoint before exit, resume()
    restores the latest checkpoint and the step counter;
  * the graph engine (core.engine + core.checkpoint): a PreemptionGuard
    turns SIGTERM into a flag the engine polls at the BSP barrier — the
    preempted rank writes a superstep checkpoint and raises Preempted,
    exiting cleanly so cluster supervision can resume the run
    (DESIGN.md §12).

Both are context managers that ALWAYS restore the prior signal handlers
on exit, even when the body raises — a leaked handler would redirect a
later test's (or job's) SIGTERM into a stale object.
"""
from __future__ import annotations

import signal
from typing import Callable, Optional

from repro.train.checkpoint import CheckpointManager


class Preempted(RuntimeError):
    """Raised by a preemptible engine after it saved its state in response
    to SIGTERM/SIGINT; ``superstep`` is the boundary the checkpoint
    resumes at."""

    def __init__(self, superstep: int):
        super().__init__(f"preempted: state saved at superstep boundary "
                         f"{superstep}; rerun with resume to continue")
        self.superstep = superstep


class PreemptionGuard:
    """Context manager that latches SIGTERM/SIGINT into ``triggered``.

    Handlers install on ``__enter__`` (or in ``install()``) and the prior
    handlers are restored on ``__exit__`` no matter how the body ends.
    In non-main threads, where ``signal.signal`` is illegal, the guard
    degrades to an inert flag (``triggered`` stays False) — thread-rank
    test clusters run unguarded, real spawned ranks are main-thread."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self.triggered = False
        self._prev: dict = {}

    def _on_signal(self, signum, frame):
        self.triggered = True

    def install(self) -> "PreemptionGuard":
        """Install the latching handlers (idempotent)."""
        for sig in self.signals:
            if sig in self._prev:
                continue
            try:
                self._prev[sig] = signal.signal(sig, self._on_signal)
            except ValueError:      # non-main thread
                pass
        return self

    def restore(self) -> None:
        """Restore every handler this guard replaced (idempotent)."""
        for sig, h in list(self._prev.items()):
            signal.signal(sig, h)
            del self._prev[sig]

    def __enter__(self) -> "PreemptionGuard":
        return self.install()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.restore()
        return False


class FaultTolerantLoop:
    """Periodic + preemption checkpointing for the training loop.

    Use as a context manager so the SIGTERM/SIGINT handlers it installs
    are restored even when the training body raises::

        with FaultTolerantLoop(mgr, save_every=100) as ft:
            step, state = ft.resume_or_init(init_fn)
            ...

    (Bare construction still installs handlers immediately for
    backward compatibility; call ``restore_handlers()`` yourself then.)
    """

    def __init__(self, ckpt: CheckpointManager, save_every: int = 100,
                 on_preempt_save: bool = True):
        self.ckpt = ckpt
        self.save_every = save_every
        self._guard = PreemptionGuard()
        if on_preempt_save:
            self._guard.install()

    @property
    def preempted(self) -> bool:
        """True once SIGTERM/SIGINT arrived (checkpoint at the next
        ``maybe_save`` and stop)."""
        return self._guard.triggered

    def __enter__(self) -> "FaultTolerantLoop":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.restore_handlers()
        return False

    # ------------------------------------------------------------------
    def resume_or_init(self, init_fn: Callable, shardings=None):
        """(step, state): restore the latest checkpoint or build fresh."""
        latest = self.ckpt.latest_step()
        if latest is not None:
            step, state = self.ckpt.restore(latest, shardings=shardings)
            return step, state
        return 0, init_fn()

    def maybe_save(self, step: int, state, force: bool = False) -> bool:
        """Save when due (every ``save_every``), forced, or preempted."""
        if force or self.preempted or (self.save_every and
                                       step % self.save_every == 0 and step > 0):
            self.ckpt.save(step, state)
            return True
        return False

    def should_stop(self) -> bool:
        """True when the loop should checkpoint-and-exit (preemption)."""
        return self.preempted

    def restore_handlers(self):
        """Put back the signal handlers this loop replaced (idempotent;
        the context-manager exit calls this for you)."""
        self._guard.restore()


class FailureInjector:
    """Deterministic failure injection for resilience tests: raises
    SimulatedFailure at the given steps.  (The graph engine's richer
    point-fault layer lives in runtime.faults.)"""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.failures = 0

    def check(self, step: int):
        """Raise SimulatedFailure if ``step`` is an armed failure point."""
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise SimulatedFailure(f"injected failure at step {step}")


class SimulatedFailure(RuntimeError):
    """The injected-failure marker raised by FailureInjector."""
