"""Deterministic fault injection for resilience tests (DESIGN.md §12).

Crash-consistent checkpointing is untestable without a way to crash on
purpose, at a *named* point, repeatably.  This module provides that:

  * a :class:`FaultSpec` names a point — ``site`` (e.g. ``"superstep"``,
    ``"barrier"``, ``"ckpt.pre_rename"``, ``"transport.send"``,
    ``"http_response"`` — the HTTP frontend's response path, where
    ``kind=delay`` simulates a slow reply and ``kind=drop`` a reply lost
    on the wire), an optional superstep/sequence number, an optional
    rank — plus what to do there (``kind``);
  * a :class:`FaultPlan` is a picklable bundle of specs that rides
    through ``EngineConfig``/``ClusterConfig`` into multiprocessing
    ``spawn`` children, so one plan arms every rank of a cluster;
  * a :class:`FaultInjector` is the per-process arm of a plan: hot paths
    call ``check(site, step)`` (no-op unless a spec matches), file
    writers call ``write(...)`` (torn-write aware), transports call
    ``drop(...)``.

Fault kinds:

  ``raise``      raise :class:`InjectedFault` (catchable, in-process tests)
  ``kill``       ``os._exit(137)`` — hard death, skips ``finally``/atexit
                 (simulates a crashed process, not a clean shutdown)
  ``sigkill``    deliver a real ``SIGKILL`` to this process
  ``preempt``    deliver ``SIGTERM`` to this process (spot reclaim drill;
                 the engine's preemption guard turns it into a
                 save-and-exit, see runtime.ft)
  ``delay``      sleep ``delay_seconds`` (straggler/timeout drills)
  ``torn_write`` only via ``write()``: persist the first ``keep_bytes``
                 bytes of the payload, then die per ``then``
  ``drop_frame`` only via ``drop()``: swallow one transport frame
  ``drop``       alias of ``drop_frame`` for non-frame sites (e.g. an
                 HTTP response at ``site=http_response``)

Determinism across restarts: a spec with ``once=True`` (the default)
fires exactly once per *plan*, not per process.  When the plan carries a
``marker_dir`` (any directory that survives the crash — the checkpoint
dir in practice), firing is recorded as a marker file claimed with
``O_CREAT|O_EXCL`` *before* the fault acts, so a respawned rank does not
re-fire the same fault; without a marker_dir the once-set is in-memory
(fine for single-process tests).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Optional


class InjectedFault(RuntimeError):
    """The catchable crash raised by ``kind="raise"`` (and torn writes
    with ``then="raise"``) — distinguishable from real failures."""


KINDS = ("raise", "kill", "sigkill", "preempt", "delay", "torn_write",
         "drop_frame", "drop")

#: the kinds :meth:`FaultInjector.drop` responds to ("drop" is the
#: spelling for non-frame sites like http_response; same semantics)
DROP_KINDS = ("drop_frame", "drop")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One named fault point (see module docstring for the kinds).

    ``superstep=-1`` matches any step, ``rank=-1`` any rank.  ``site``
    is compared exactly against the caller-supplied site string."""

    site: str
    superstep: int = -1
    rank: int = -1
    kind: str = "raise"
    delay_seconds: float = 0.05       # kind="delay"
    keep_bytes: int = 0               # kind="torn_write": surviving prefix
    then: str = "raise"               # torn_write follow-up: raise | kill
    once: bool = True

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")

    def spec_id(self) -> str:
        """Stable identifier used for the once-marker file name."""
        site = self.site.replace(".", "-").replace(os.sep, "-")
        return f"{site}_{self.superstep}_{self.rank}_{self.kind}"


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A picklable bundle of fault specs + the directory where once-markers
    persist across process restarts (``None`` = in-memory markers)."""

    specs: tuple = ()
    marker_dir: Optional[str] = None

    def injector(self, rank: Optional[int] = None) -> "FaultInjector":
        """Arm this plan in the current process as ``rank`` (None = the
        classic single-process engine, which matches any rank spec)."""
        return FaultInjector(self, rank=rank)


def parse_spec(text: str) -> FaultSpec:
    """Parse one CLI ``--inject`` value, e.g.
    ``"rank=1,superstep=2,site=superstep,kind=sigkill"``.

    Keys: site (required), superstep, rank, kind, delay_seconds,
    keep_bytes, then, once."""
    kw: dict = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad --inject fragment {part!r} "
                             "(expected key=value)")
        k, v = part.split("=", 1)
        k = k.strip()
        v = v.strip()
        if k in ("superstep", "rank", "keep_bytes"):
            kw[k] = int(v)
        elif k == "delay_seconds":
            kw[k] = float(v)
        elif k == "once":
            kw[k] = v.lower() in ("1", "true", "yes")
        elif k in ("site", "kind", "then"):
            kw[k] = v
        else:
            raise ValueError(f"unknown --inject key {k!r}")
    if "site" not in kw:
        raise ValueError(f"--inject spec {text!r} needs site=...")
    return FaultSpec(**kw)


def parse_plan(texts, marker_dir: Optional[str] = None) -> Optional[FaultPlan]:
    """Build a FaultPlan from repeated CLI ``--inject`` values (None when
    no spec was given, so callers can pass it straight to configs)."""
    if not texts:
        return None
    return FaultPlan(specs=tuple(parse_spec(t) for t in texts),
                     marker_dir=marker_dir)


class FaultInjector:
    """Per-process arm of a :class:`FaultPlan` (see module docstring).

    Thread-compatible: matching mutates only the once-claim state, which
    is an O_EXCL marker file (cross-process) or an in-memory set guarded
    by the GIL — good enough for the engine's single compute thread."""

    def __init__(self, plan: FaultPlan, rank: Optional[int] = None):
        self.plan = plan
        self.rank = rank
        self.fired: list[str] = []      # spec_ids this injector acted on
        self._mem_claims: set[str] = set()

    # -- hot-path hooks ------------------------------------------------------
    def check(self, site: str, step: int = -1) -> None:
        """Fire any matching non-I/O fault at this point (no-op otherwise).
        ``torn_write``/``drop_frame`` specs never match here — they fire
        through :meth:`write` / :meth:`drop`."""
        spec = self._match(site, step,
                           exclude=("torn_write",) + DROP_KINDS)
        if spec is not None:
            self._act(spec)

    def write(self, path: str, data: bytes, site: str, step: int = -1) -> None:
        """Write ``data`` to ``path`` — unless a ``torn_write`` spec matches
        this point, in which case only ``keep_bytes`` of the payload reach
        the file (flushed + fsynced, so the torn prefix is really on disk)
        before the fault acts per ``spec.then``."""
        spec = self._match(site, step, only=("torn_write",))
        with open(path, "wb") as f:
            if spec is None:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
                return
            f.write(data[: max(spec.keep_bytes, 0)])
            f.flush()
            os.fsync(f.fileno())
        if spec.then == "kill":
            os._exit(137)
        raise InjectedFault(
            f"torn write at {site} (step {step}): kept "
            f"{max(spec.keep_bytes, 0)}/{len(data)} bytes of {path}")

    def drop(self, site: str, step: int = -1) -> bool:
        """True if a ``drop_frame``/``drop`` spec matches this point —
        the caller must then swallow the frame (or response) instead of
        sending it."""
        return self._match(site, step, only=DROP_KINDS) is not None

    # -- matching ------------------------------------------------------------
    def _match(self, site: str, step: int,
               exclude: tuple = (), only: Optional[tuple] = None
               ) -> Optional[FaultSpec]:
        for spec in self.plan.specs:
            if spec.site != site:
                continue
            if only is not None and spec.kind not in only:
                continue
            if spec.kind in exclude:
                continue
            if spec.superstep >= 0 and step >= 0 and spec.superstep != step:
                continue
            if (spec.rank >= 0 and self.rank is not None
                    and spec.rank != self.rank):
                continue
            if not self._claim(spec):
                continue
            self.fired.append(spec.spec_id())
            return spec
        return None

    def _claim(self, spec: FaultSpec) -> bool:
        """Claim the right to fire ``spec`` (False if a once-spec already
        fired — here, in a previous process, or on a peer sharing the
        marker_dir for a rank=-1 spec).  Claimed BEFORE acting so hard
        kills can't re-fire after a supervised restart."""
        if not spec.once:
            return True
        sid = spec.spec_id()
        if self.plan.marker_dir is not None:
            os.makedirs(self.plan.marker_dir, exist_ok=True)
            path = os.path.join(self.plan.marker_dir, sid + ".fired")
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                return False
            os.close(fd)
            return True
        if sid in self._mem_claims:
            return False
        self._mem_claims.add(sid)
        return True

    # -- actions -------------------------------------------------------------
    def _act(self, spec: FaultSpec) -> None:
        if spec.kind == "raise":
            raise InjectedFault(
                f"injected fault at {spec.site} "
                f"(superstep {spec.superstep}, rank {spec.rank})")
        if spec.kind == "kill":
            os._exit(137)
        if spec.kind == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
            time.sleep(60)     # pragma: no cover - death is asynchronous
        if spec.kind == "preempt":
            os.kill(os.getpid(), signal.SIGTERM)
            return
        if spec.kind == "delay":
            time.sleep(spec.delay_seconds)
            return
        raise AssertionError(f"unhandled kind {spec.kind}")  # pragma: no cover
