"""Data pipeline: deterministic synthetic stream + binary-corpus reader,
with background prefetch.

Determinism contract (fault tolerance): batch(step) is a pure function of
(seed, step), so restart-from-checkpoint resumes the exact stream without
any pipeline state in the checkpoint.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


class SyntheticLM:
    """Zipf-ish synthetic token stream (power-law ids like real corpora)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        v = self.cfg.vocab_size
        # zipf via inverse-cdf on a pareto-ish tail, clipped to vocab
        u = rng.random((self.batch, self.seq + 1))
        toks = np.minimum((u ** -1.2).astype(np.int64), v - 1).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "vision":
            ft = self.cfg.frontend_tokens
            out["patch_embeds"] = rng.normal(
                size=(self.batch, ft, self.cfg.d_model)).astype(np.float32)
            out["tokens"] = out["tokens"][:, : self.seq - ft]
            lab = np.full((self.batch, self.seq), -1, np.int32)
            lab[:, ft:] = toks[:, 1: self.seq - ft + 1]
            out["labels"] = lab
        if self.cfg.encoder_layers > 0:
            out["enc_frames"] = rng.normal(
                size=(self.batch, self.seq // 2, self.cfg.d_model)
            ).astype(np.float32)
        return out


class BinCorpus:
    """Memory-mapped flat token file (uint16/uint32); window sampling is a
    pure function of (seed, step) for deterministic resume."""

    def __init__(self, path: str, cfg: ModelConfig, batch: int, seq: int,
                 dtype=np.uint16, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        n = len(self.tokens) - self.seq - 1
        starts = rng.integers(0, n, self.batch)
        toks = np.stack([np.asarray(self.tokens[s: s + self.seq + 1])
                         for s in starts]).astype(np.int32)
        toks %= self.cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch of batch_at(step) for step = start..∞."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            b = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
