"""Optimizers (AdamW, Adafactor-lite) + LR schedules + gradient transforms.

Plain-pytree implementations (no optax in this environment).  Optimizer
state shardings are derived in launch/mesh.py via
sharding.opt_state_spec_from_param (ZeRO-1: m/v sharded over the data
axis on top of the param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# GraphH-style gradient compression: top-k sparsification + error feedback
# (the paper's hybrid dense/sparse broadcast applied to DP gradient exchange)
# ---------------------------------------------------------------------------

def ef_init(params) -> dict:
    return {"residual": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def topk_compress(grads, ef_state, density: float = 0.01):
    """Keep the top `density` fraction of each gradient tensor (by |g|),
    accumulate the rest into the error-feedback residual.

    Returns (sparse grads, new ef state, stats with measured wire ratio):
    on a cluster the sparse tensors are what crosses the network (as
    (idx, val) pairs — GraphH's sparse mode), so wire bytes scale with
    density * (1 + idx overhead) instead of 1.0.
    """
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        flat = jnp.abs(acc.reshape(-1))
        k = max(1, int(density * flat.shape[0]))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(acc) >= thresh
        sent = jnp.where(mask, acc, 0.0)
        resid = acc - sent
        return sent.astype(g.dtype), resid, mask.mean()

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef_state["residual"])
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    mean_density = jnp.mean(jnp.stack([o[2] for o in outs]))
    # wire model: dense = 4B/elem; sparse = density * (4B idx + 4B val)
    wire_ratio = mean_density * 2.0
    return new_g, {"residual": new_r}, {"density": mean_density,
                                        "wire_ratio": wire_ratio}
