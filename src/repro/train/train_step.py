"""Train step builder: microbatched grad accumulation, remat, optional
gradient compression, mesh-aware shardings.

``build_train_step`` returns (step_fn, init_state_fn) where step_fn is
jit-compiled with explicit in/out shardings when a mesh is given — the
same function the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models import sharding as shd
from repro.models.model_zoo import build_model
from repro.train import optimizer as opt


def model_loss_fn(model, cfg: ModelConfig):
    """Uniform loss entry point across families."""
    def loss_fn(params, batch):
        if cfg.encoder_layers > 0:
            return model.loss(params, batch["tokens"], batch["labels"],
                              batch["enc_frames"])
        if cfg.frontend == "vision":
            return model.loss(params, batch["tokens"], batch["labels"],
                              extra_embeds=batch["patch_embeds"])
        return model.loss(params, batch["tokens"], batch["labels"])
    return loss_fn


def _microbatch(batch, n: int, i: int):
    def slc(x):
        b = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)
    return jax.tree.map(slc, batch)


def build_train_step(
    cfg: ModelConfig,
    run: RunConfig,
    opt_cfg: opt.OptConfig = opt.OptConfig(),
    mesh: Optional[Mesh] = None,
    rules: Optional[shd.Rules] = None,
    donate: bool = True,
):
    """Returns (jitted step, init_fn, shardings dict)."""
    model = build_model(cfg, run)
    loss_fn = model_loss_fn(model, cfg)
    use_ef = run.grad_compression == "topk"

    def raw_step(state, batch):
        params = state["params"]
        nmb = run.microbatch

        def one_micro(i, acc):
            mb = _microbatch(batch, nmb, i) if nmb > 1 else batch
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            return (acc[0] + loss,
                    jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 acc[1], grads))

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        loss_sum, grads = jax.lax.fori_loop(0, nmb, one_micro, (0.0, zero))
        loss = loss_sum / nmb
        grads = jax.tree.map(lambda g: g / nmb, grads)

        if run.grad_compression == "bf16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32),
                                 grads)
        stats_extra = {}
        ef_state = state.get("ef")
        if use_ef:
            grads, ef_state, cstats = opt.topk_compress(grads, ef_state)
            stats_extra = cstats

        new_params, opt_state, stats = opt.adamw_update(
            params, grads, state["opt"], opt_cfg)
        new_state = {"params": new_params, "opt": opt_state,
                     "step": state["step"] + 1}
        if use_ef:
            new_state["ef"] = ef_state
        stats = {**stats, **stats_extra, "loss": loss}
        return new_state, stats

    def wrapped_step(state, batch):
        if mesh is None:
            return raw_step(state, batch)
        with shd.use_mesh(mesh, rules):
            return raw_step(state, batch)

    def init_state(key):
        params = model.init(key)
        state = {"params": params, "opt": opt.adamw_init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if use_ef:
            state["ef"] = opt.ef_init(params)
        return state

    if mesh is None:
        return jax.jit(wrapped_step, donate_argnums=(0,) if donate else ()), \
            init_state, None

    # --- mesh-aware shardings -------------------------------------------
    rules = rules or shd.Rules(dp_axes=tuple(a for a in ("pod", "data")
                                             if a in mesh.axis_names),
                               fsdp=run.sharding_mode == "fsdp",
                               zero1=run.zero1)
    shapes = jax.eval_shape(init_state, jax.random.key(0))
    pspecs = state_specs(shapes, rules, mesh)
    state_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                   is_leaf=lambda x: isinstance(x, P))
    batch_sharding = NamedSharding(mesh, P(rules.dp))
    step = jax.jit(
        wrapped_step,
        in_shardings=(state_shardings, batch_sharding),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    return step, init_state, dict(state=state_shardings, batch=batch_sharding,
                                  rules=rules, specs=pspecs)


def state_specs(state_shapes, rules: shd.Rules, mesh=None):
    """PartitionSpec tree for the full train state."""
    param_sp = shd.param_specs(state_shapes["params"], rules, mesh)

    def opt_sp(spec, shape_leaf):
        return shd.opt_state_spec_from_param(spec, rules, shape_leaf.shape, mesh)

    def map_opt():
        return jax.tree.map(opt_sp, param_sp, state_shapes["params"],
                            is_leaf=lambda x: isinstance(x, P))

    out = {"params": param_sp,
           "opt": {"m": map_opt(), "v": map_opt(), "step": P()},
           "step": P()}
    if "ef" in state_shapes:
        out["ef"] = {"residual": map_opt()}
    return out
