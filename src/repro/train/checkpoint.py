"""Fault-tolerant, mesh-elastic checkpointing.

Design (DESIGN.md §5):
  * every leaf of the train state is written as a full logical array
    (<flat-path>.npy, optionally zstd-compressed), so a checkpoint is
    independent of the mesh it was written from;
  * writes go to ``<dir>/step_<n>.tmp`` and are atomically renamed —
    a reader can never observe a torn checkpoint (crash-safe);
  * ``LATEST`` is a one-line pointer file, also atomically replaced;
  * restore takes target shardings and device_puts each leaf, so the
    same checkpoint restores onto 1 device or a 512-chip mesh (elastic
    rescale = save on mesh A, restore on mesh B);
  * keep-last-k garbage collection.

On a real multi-host pod, process 0 writes metadata and each host writes
its addressable shards; the single-process layout here is the degenerate
case of that protocol (noted, not stubbed: the API takes shardings).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

from repro.compat import zstd_compress, zstd_decompress


_EMPTY = "__empty_dict__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:
            # keep empty-dict nodes: pytree STRUCTURE matters to pjit
            out[prefix + _EMPTY] = np.zeros((0,), np.int8)
            return out
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        if parts[-1] == _EMPTY:
            d = root
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            continue
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, compress: bool = False):
        self.dir = directory
        self.keep = keep
        self.compress = compress
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, extra_meta: Optional[dict] = None) -> str:
        flat = _flatten(state)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        meta = {"step": step, "leaves": {}, "extra": extra_meta or {}}
        for path, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            meta["leaves"][path] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
            fn = os.path.join(tmp, path.replace("/", "_") + ".npy")
            if self.compress:
                blob = zstd_compress(arr.tobytes(order="C"), level=3)
                with open(fn + ".zst", "wb") as f:
                    f.write(blob)
            else:
                np.save(fn, arr)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        self._write_latest(step)
        self._gc()
        return final

    def _write_latest(self, step: int) -> None:
        tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
        os.replace(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if os.path.exists(p):
            with open(p) as f:
                s = int(f.read().strip())
            if os.path.isdir(os.path.join(self.dir, f"step_{s:08d}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None,
                like=None) -> tuple[int, Any]:
        """Restore (step, state).  ``shardings``: optional pytree of
        NamedShardings (elastic reshard); ``like``: optional pytree whose
        dtypes/shapes validate the load."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        flat = {}
        for path, info in meta["leaves"].items():
            fn = os.path.join(d, path.replace("/", "_") + ".npy")
            if os.path.exists(fn + ".zst"):
                with open(fn + ".zst", "rb") as f:
                    raw = zstd_decompress(f.read())
                arr = np.frombuffer(raw, dtype=np.dtype(info["dtype"])).reshape(
                    info["shape"]).copy()
            else:
                arr = np.load(fn)
            if path.endswith(_EMPTY):
                flat[path] = arr            # structural marker, not data
                continue
            sh = flat_sh.get(path)
            sh = sh if hasattr(sh, "devices") or hasattr(sh, "mesh") else None
            flat[path] = jax.device_put(arr, sh) if sh is not None else arr
        state = _unflatten(flat)
        return step, state
