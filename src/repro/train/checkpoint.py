"""Fault-tolerant, mesh-elastic checkpointing.

Design (DESIGN.md §5):
  * every leaf of the train state is written as a full logical array
    (<flat-path>.npy, optionally zstd-compressed), so a checkpoint is
    independent of the mesh it was written from;
  * writes go to ``<dir>/step_<n>.tmp`` and are atomically renamed —
    a reader can never observe a torn checkpoint (crash-safe);
  * ``LATEST`` is a one-line pointer file, also atomically replaced;
  * restore takes target shardings and device_puts each leaf, so the
    same checkpoint restores onto 1 device or a 512-chip mesh (elastic
    rescale = save on mesh A, restore on mesh B);
  * keep-last-k garbage collection.

On a real multi-host pod, process 0 writes metadata and each host writes
its addressable shards; the single-process layout here is the degenerate
case of that protocol (noted, not stubbed: the API takes shardings).
"""
from __future__ import annotations

import io
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

from repro.compat import zstd_compress, zstd_decompress


_EMPTY = "__empty_dict__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:
            # keep empty-dict nodes: pytree STRUCTURE matters to pjit
            out[prefix + _EMPTY] = np.zeros((0,), np.int8)
            return out
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        if parts[-1] == _EMPTY:
            d = root
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            continue
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


class CheckpointManager:
    """Atomic keep-last-k checkpoints (see module docstring).

    ``fault`` optionally arms a ``runtime.faults.FaultInjector`` at the
    named crash points inside the save path (``ckpt.mid_write`` between
    leaves, ``ckpt.leaf`` on each leaf's bytes, ``ckpt.pre_rename``
    before the publish rename, ``ckpt.latest`` on the LATEST tmp write,
    ``ckpt.pre_latest`` before the LATEST replace) — the crash-atomicity
    tests drive every one of them and assert a reader never observes a
    torn checkpoint."""

    def __init__(self, directory: str, keep: int = 3, compress: bool = False,
                 fault=None):
        self.dir = directory
        self.keep = keep
        self.compress = compress
        self.fault = fault
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def _check(self, site: str, step: int) -> None:
        if self.fault is not None:
            self.fault.check(site, step)

    def _write_bytes(self, path: str, data: bytes, site: str,
                     step: int) -> None:
        """One file write, routed through the fault injector so a spec can
        tear it (persist a prefix, then die) at a named point."""
        if self.fault is not None:
            self.fault.write(path, data, site, step)
        else:
            # lint: allow(GH301): callers always pass paths inside the staged tmp dir
            with open(path, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _tmp_dir(self, step: int) -> str:
        """Staging dir name.  ``.tmp`` never matches the ``step_(\\d+)``
        reader regex, so a crash mid-stage leaves garbage, never a
        half-readable checkpoint."""
        return self._step_dir(step) + ".tmp"

    def _stage(self, step: int, state, extra_meta: Optional[dict]
               ) -> tuple[str, dict]:
        """Write every leaf into a fresh staging dir; returns (tmp, meta).
        Nothing is visible to readers until :meth:`_finalize` renames."""
        flat = _flatten(state)
        tmp = self._tmp_dir(step)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        meta = {"step": step, "leaves": {}, "extra": extra_meta or {}}
        for path, leaf in flat.items():
            self._check("ckpt.mid_write", step)
            arr = np.asarray(jax.device_get(leaf))
            meta["leaves"][path] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
            fn = os.path.join(tmp, path.replace("/", "_") + ".npy")
            if self.compress:
                blob = zstd_compress(arr.tobytes(order="C"), level=3)
                self._write_bytes(fn + ".zst", blob, "ckpt.leaf", step)
            else:
                bio = io.BytesIO()
                np.save(bio, arr)
                self._write_bytes(fn, bio.getvalue(), "ckpt.leaf", step)
        return tmp, meta

    def _finalize(self, step: int, tmp: str, meta: dict) -> str:
        """Write meta.json, atomically publish the staged dir, repoint
        LATEST, garbage-collect old checkpoints."""
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        self._check("ckpt.pre_rename", step)
        final = self._publish(step, tmp)
        self._write_latest(step)
        self._gc()
        return final

    def _publish(self, step: int, tmp: str) -> str:
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        return final

    def save(self, step: int, state, extra_meta: Optional[dict] = None) -> str:
        """Write one checkpoint: stage every leaf, then atomically publish
        (tmp-dir rename) and repoint LATEST.  Crash-safe at every point —
        a reader sees either the previous checkpoint or this one, whole."""
        tmp, meta = self._stage(step, state, extra_meta)
        return self._finalize(step, tmp, meta)

    def _write_latest(self, step: int) -> None:
        # pid-suffixed tmp: concurrent writers (multi-rank graph saves)
        # must not truncate each other's staging file mid-replace
        tmp = os.path.join(self.dir, f"LATEST.tmp.{os.getpid()}")
        self._write_bytes(tmp, str(step).encode(), "ckpt.latest", step)
        self._check("ckpt.pre_latest", step)
        os.replace(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if os.path.exists(p):
            try:
                with open(p) as f:
                    s = int(f.read().strip())
            except ValueError:
                s = None    # unreadable pointer: fall back to the dir scan
            if s is not None and os.path.isdir(
                    os.path.join(self.dir, f"step_{s:08d}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None,
                like=None) -> tuple[int, Any]:
        """Restore (step, state).  ``shardings``: optional pytree of
        NamedShardings (elastic reshard); ``like``: optional pytree whose
        dtypes/shapes validate the load."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        flat = {}
        for path, info in meta["leaves"].items():
            fn = os.path.join(d, path.replace("/", "_") + ".npy")
            if os.path.exists(fn + ".zst"):
                with open(fn + ".zst", "rb") as f:
                    raw = zstd_decompress(f.read())
                arr = np.frombuffer(raw, dtype=np.dtype(info["dtype"])).reshape(
                    info["shape"]).copy()
            else:
                arr = np.load(fn)
            if path.endswith(_EMPTY):
                flat[path] = arr            # structural marker, not data
                continue
            sh = flat_sh.get(path)
            sh = sh if hasattr(sh, "devices") or hasattr(sh, "mesh") else None
            flat[path] = jax.device_put(arr, sh) if sh is not None else arr
        state = _unflatten(flat)
        return step, state
