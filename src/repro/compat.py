"""Optional-dependency shims.

``zstandard`` is the preferred payload codec (fast, good ratios) but is not
part of the Python stdlib and may be absent from minimal containers.  The
stdlib ``zlib`` is the drop-in fallback — fittingly, the codec the GraphH
paper itself used for its edge-cache ladder (§III-D-2: snappy/zlib; see
DESIGN.md §3).  Level semantics map 1:1 (higher = slower, smaller).

Streams are self-describing: ``zstd_decompress`` sniffs the zstd frame magic
vs the zlib header, so a store written with one codec is readable whenever
that codec is importable, regardless of which codec is the current default.
"""
from __future__ import annotations

import zlib

try:  # pragma: no cover - environment-dependent
    import zstandard as _zstd
except ModuleNotFoundError:  # pragma: no cover
    _zstd = None

HAVE_ZSTD = _zstd is not None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def zstd_compress(data: bytes, level: int = 3) -> bytes:
    """Compress with zstd when available, else zlib at the same level."""
    if _zstd is not None:
        return _zstd.ZstdCompressor(level=level).compress(data)
    return zlib.compress(data, min(max(level, 1), 9))


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions:
    the entry point moved (jax.experimental -> jax.shard_map) and the
    kwarg was renamed (check_rep -> check_vma).  jax is imported lazily so
    jax-free consumers of this module stay jax-free."""
    import jax

    try:
        sm = jax.shard_map
    except AttributeError:  # jax < 0.6
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:  # jax < 0.6
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def zstd_decompress(data: bytes) -> bytes:
    """Decompress a blob produced by :func:`zstd_compress` (either codec)."""
    if data[:4] == _ZSTD_MAGIC:
        if _zstd is None:
            raise RuntimeError(
                "blob is zstd-compressed but the 'zstandard' module is not "
                "installed (pip install zstandard, or rebuild the store)"
            )
        return _zstd.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)
