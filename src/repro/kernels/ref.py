"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(contrib: jax.Array, dst: jax.Array, num_segments: int) -> jax.Array:
    """Sum contrib ``[E(, Q)]`` by dst ``[E]`` into ``[R(, Q)]``."""
    return jax.ops.segment_sum(contrib, dst, num_segments=num_segments)


def segment_min(contrib: jax.Array, dst: jax.Array, num_segments: int) -> jax.Array:
    """Min of contrib ``[E(, Q)]`` by dst ``[E]`` into ``[R(, Q)]``
    (+inf when empty)."""
    return jax.ops.segment_min(contrib, dst, num_segments=num_segments)


def segment_max(contrib: jax.Array, dst: jax.Array, num_segments: int) -> jax.Array:
    """Max of contrib ``[E(, Q)]`` by dst ``[E]`` into ``[R(, Q)]``
    (-inf when empty)."""
    return jax.ops.segment_max(contrib, dst, num_segments=num_segments)


def compact(mask: jax.Array, values: jax.Array, capacity: int,
            fill_index: int | None = None) -> tuple[jax.Array, jax.Array]:
    """First-`capacity` indices where mask ``[V]`` is set (ascending) and
    their values ``[V]``, as ``([K], [K])`` with K = capacity.

    Unused slots hold (fill_index, 0).  fill_index defaults to len(mask).
    """
    n = mask.shape[0]
    fill = n if fill_index is None else fill_index
    (idx,) = jnp.nonzero(mask, size=capacity, fill_value=fill)
    vals = jnp.where(idx < n, values[jnp.minimum(idx, n - 1)], 0)
    return idx.astype(jnp.int32), vals
