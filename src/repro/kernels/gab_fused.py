"""Fused Pallas gather→combine→apply kernel (DESIGN.md §14).

The unfused path runs the GAB hot loop as separate XLA dispatches with HBM
round-trips between them: gather materializes ``contrib [E, Q]``, the
one-hot kernel reduces it, then apply/updated-mask run as follow-up
elementwise ops over the row block.  This kernel fuses the whole chain:

  * the per-edge message is computed *inside* the kernel from streamed
    source values (``contrib = src·a + b`` — every shipped vertex program
    is an affine gather, see :class:`FusedSpec`),
  * edge blocks stream HBM→VMEM through an explicit two-slot
    double-buffered DMA (the pipelined engine's overlap idea pushed down
    to kernel granularity: block i+1 copies while block i computes),
  * the output row block stays resident in a VMEM accumulator across the
    whole edge contraction (grid is 1-D over row blocks; the edge loop is
    a ``fori_loop`` inside the kernel),
  * apply (damped affine update / min-max relaxation) and the per-
    ``(vertex, query)`` updated mask are computed in-kernel before the
    single write-back of the row block.

Per row block of ``BR`` rows the kernel reads ``E × (Q + #streams)`` f32
lanes and writes ``BR × Q`` twice (values + mask) — the contrib array,
the accumulator round-trip, and the mask pass never touch HBM.

Bit-identity contract: with equal ``(BE, BR)`` the accumulation order is
exactly the unfused one-hot kernel's (identity-init, ascending edge
blocks, the same ``dot_general``/masked-select per block), and the apply
formulas mirror ``core/apps.py`` term-for-term.  The one caveat is the
apply's multiply-add: XLA may contract the *unfused* path's
``alpha*base + beta*accum`` into an FMA (it does on CPU whenever the row
offset is traced, and deletes ``optimization_barrier``/bitcast pins that
would prevent it), while this kernel computes it with two roundings.
FMA and two-rounding provably coincide when both products are exactly
representable in f32 — true for min/max applies (no multiply-add) and
for power-of-two affine coefficients — so every shipped app is
bit-identical to the unfused path except PageRank/PPR at
non-power-of-two damping, where the divergence is bounded by the last
ulp of the apply.  tests/test_gab_fused.py asserts the exact cases with
``array_equal`` and the dampened ones at float tolerance; DESIGN.md §14
records the full analysis.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gab_gather import (  # noqa: F401  (re-exported defaults)
    DEFAULT_BLOCK_E,
    DEFAULT_BLOCK_R,
    SUBLANES,
    _IDENTITY,
    _pad_axis,
)


@dataclasses.dataclass(frozen=True)
class FusedSpec:
    """Static description of a vertex program's gather/apply for fusion.

    Gather (per edge ``e``, query ``q``):
        ``contrib[q, e] = src[q, e] (· a[e]) (+ edge_val[e]) (+ add_const)``
    where ``a[e] = src_aux[scale_aux][e] · edge_val[e]`` is computed by the
    caller.  Covers every shipped app: PageRank/PPR scale by the shared
    1/out-degree factor, SSSP/landmarks add the edge weight, BFS adds 1.

    Apply (per row ``r``, query ``q``), on the block-resident accumulator:
        ``affine``: ``new = alpha · base + beta · accum`` (``base`` is the
        ``base_aux`` dst rows, or the implicit 1.0 — damped PageRank/PPR)
        ``min``/``max``: ``new = min/max(old, accum)`` (relaxation merge)

    The updated mask follows ``VertexProgram.updated_mask``: exact ``!=``
    when ``update_tol == 0`` else ``|new - old| > update_tol``.
    """

    combine: str                      # "sum" | "min" | "max"
    scale_aux: str | None = None      # src-aux name; a = aux[src] * edge_val
    add_edge: bool = False            # contrib += edge_val
    add_const: float | None = None    # contrib += const (BFS hop increment)
    apply: str = "min"                # "affine" | "min" | "max"
    alpha: float = 0.0                # affine: new = alpha*base + beta*accum
    beta: float = 1.0
    base_aux: str | None = None       # dst-aux name for base; None -> 1.0
    update_tol: float = 0.0


def _kernel(spec: FusedSpec, block_e: int, block_r: int, n_eblocks: int,
            nr_ref, *refs):
    """Grid = (num_row_blocks,).  Streams every edge block through a 2-slot
    VMEM scratch with overlapped DMA, accumulating into ``acc``; applies the
    vertex update + mask once at the end and writes the row block back."""
    # unpack the spec-dependent ref list: HBM streams, row-blocked ins/outs,
    # then scratch (the wrapper builds the same order)
    it = iter(refs)
    dst_hbm = next(it)
    src_hbm = next(it)
    a_hbm = next(it) if spec.scale_aux else None
    b_hbm = next(it) if spec.add_edge else None
    old_ref = next(it)
    base_ref = next(it) if spec.base_aux else None
    new_ref = next(it)
    upd_ref = next(it)
    acc = next(it)
    dst_s = next(it)
    src_s = next(it)
    a_s = next(it) if spec.scale_aux else None
    b_s = next(it) if spec.add_edge else None
    sem = next(it)

    j = pl.program_id(0)
    qp = src_s.shape[1]
    combine = spec.combine

    streams = [(dst_hbm, dst_s, 0), (src_hbm, src_s, 1)]
    if a_s is not None:
        streams.append((a_hbm, a_s, 2))
    if b_s is not None:
        streams.append((b_hbm, b_s, 3))

    def copies(i, slot):
        return [pltpu.make_async_copy(
            hbm.at[:, pl.ds(i * block_e, block_e)], scr.at[slot],
            sem.at[slot, s]) for hbm, scr, s in streams]

    def start(i, slot):
        for cp in copies(i, slot):
            cp.start()

    def wait(i, slot):
        for cp in copies(i, slot):
            cp.wait()

    acc[...] = jnp.full_like(acc, _IDENTITY[combine])
    start(0, 0)

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n_eblocks)
        def _prefetch():
            start(i + 1, jax.lax.rem(i + 1, 2))

        wait(i, slot)
        src = src_s[slot]                       # [qp, BE]
        contrib = src
        if a_s is not None:
            contrib = contrib * a_s[slot]       # [1, BE] broadcast over qp
        if b_s is not None:
            contrib = contrib + b_s[slot]
        if spec.add_const is not None:
            contrib = contrib + jnp.float32(spec.add_const)

        dst = dst_s[slot][0]                    # [BE] local row ids
        rows = j * block_r + jax.lax.broadcasted_iota(
            jnp.int32, (block_e, block_r), 1)
        hit = dst[:, None] == rows              # [BE, BR]

        if combine == "sum":
            h = hit.astype(contrib.dtype)
            part = jax.lax.dot_general(
                contrib, h,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )                                   # [qp, BR] on the MXU
            acc[...] += part
        else:
            ident = jnp.asarray(_IDENTITY[combine], dtype=contrib.dtype)
            sel = jnp.where(hit[None, :, :], contrib[:, :, None], ident)
            red = (jnp.min(sel, axis=1) if combine == "min"
                   else jnp.max(sel, axis=1))
            cur = acc[...]
            acc[...] = (jnp.minimum(cur, red) if combine == "min"
                        else jnp.maximum(cur, red))
        return 0

    jax.lax.fori_loop(0, n_eblocks, body, 0)

    # ---- fused apply + updated mask on the resident row block -----------
    accum = acc[...]                            # [qp, BR]
    old = old_ref[...]
    if spec.apply == "affine":
        alpha = jnp.float32(spec.alpha)
        beta = jnp.float32(spec.beta)
        if base_ref is not None:
            new = alpha * base_ref[...] + beta * accum
        else:
            new = alpha + beta * accum
    elif spec.apply == "min":
        new = jnp.minimum(old, accum)
    else:
        new = jnp.maximum(old, accum)

    local = j * block_r + jax.lax.broadcasted_iota(
        jnp.int32, (qp, block_r), 1)
    valid = local < nr_ref[0]
    new = jnp.where(valid, new, old)
    if spec.update_tol > 0.0:
        upd = jnp.abs(new - old) > jnp.float32(spec.update_tol)
    else:
        upd = new != old
    new_ref[...] = new
    upd_ref[...] = jnp.logical_and(valid, upd).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "row_cap", "block_e", "block_r", "interpret"),
)
def gab_fused(
    spec: FusedSpec,
    src_vals: jax.Array,          # [E] or [E, Q] pre-gathered source values
    a: jax.Array | None,          # [E] gather scale, or None
    b: jax.Array | None,          # [E] gather additive term, or None
    dst_local: jax.Array,         # [E] local dst row ids (padding == row_cap)
    old: jax.Array,               # [row_cap] or [row_cap, Q] current rows
    base: jax.Array | None,       # [row_cap(, Q)] affine base rows, or None
    num_rows: jax.Array,          # scalar int32 (<= row_cap)
    row_cap: int,
    block_e: int = DEFAULT_BLOCK_E,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One fused Gather+Apply tile step.

    Shapes: src_vals ``[E(, Q)]``, a/b/dst_local ``[E]``, old/base
    ``[R(, Q)]`` with R = row_cap.

    Returns ``(new [row_cap(, Q)], updated [row_cap(, Q)] bool)`` with the
    exact semantics of ``core/gab.tile_gather_apply``'s reduce+apply+mask
    tail: rows at or beyond ``num_rows`` keep ``old`` and are not-updated.
    Padding edges (``dst_local == row_cap``) reduce into the sink row,
    which lives past the returned slice — identical discard semantics to
    the unfused ``num_segments = row_cap + 1`` convention.
    """
    assert src_vals.ndim in (1, 2) and old.ndim == src_vals.ndim
    squeeze = src_vals.ndim == 1
    sv = src_vals[:, None] if squeeze else src_vals      # [E, Q]
    ov = old[:, None] if squeeze else old                # [row_cap, Q]
    bv = None if base is None else (base[:, None] if squeeze else base)
    e, q = sv.shape
    e_pad = max(-(-e // block_e) * block_e, block_e)
    r_pad = max(-(-row_cap // block_r) * block_r, block_r)
    q_pad = max(-(-q // SUBLANES) * SUBLANES, SUBLANES)
    n_eblocks = e_pad // block_e

    def prep_edge(x, fill=0.0):
        return _pad_axis(x.astype(jnp.float32)[None, :], e_pad, fill, axis=1)

    def prep_rows(x):
        xt = _pad_axis(x.astype(jnp.float32).T, r_pad, 0.0, axis=1)
        return _pad_axis(xt, q_pad, 0.0, axis=0)         # [qp, r_pad]

    # [Q, E] layout (edges on lanes); kernel-side edge padding routes to the
    # out-of-range row r_pad so it never hits a one-hot lane.
    src_p = _pad_axis(_pad_axis(sv.astype(jnp.float32).T, e_pad, 0.0, axis=1),
                      q_pad, 0.0, axis=0)
    dst_p = _pad_axis(dst_local.astype(jnp.int32), e_pad,
                      jnp.int32(r_pad))[None, :]

    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    rowblk = pl.BlockSpec((q_pad, block_r), lambda j: (0, j))
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM), hbm, hbm]
    inputs = [jnp.asarray(num_rows, jnp.int32).reshape(1), dst_p, src_p]
    if spec.scale_aux:
        in_specs.append(hbm)
        inputs.append(prep_edge(a))
    if spec.add_edge:
        in_specs.append(hbm)
        inputs.append(prep_edge(b))
    in_specs.append(rowblk)
    inputs.append(prep_rows(ov))
    if spec.base_aux:
        in_specs.append(rowblk)
        inputs.append(prep_rows(bv))

    scratch = [
        pltpu.VMEM((q_pad, block_r), jnp.float32),       # resident accumulator
        pltpu.VMEM((2, 1, block_e), jnp.int32),          # dst double-buffer
        pltpu.VMEM((2, q_pad, block_e), jnp.float32),    # src double-buffer
    ]
    n_streams = 2
    if spec.scale_aux:
        scratch.append(pltpu.VMEM((2, 1, block_e), jnp.float32))
        n_streams += 1
    if spec.add_edge:
        scratch.append(pltpu.VMEM((2, 1, block_e), jnp.float32))
        n_streams += 1
    scratch.append(pltpu.SemaphoreType.DMA((2, n_streams)))

    new_p, upd_p = pl.pallas_call(
        functools.partial(_kernel, spec, block_e, block_r, n_eblocks),
        grid=(r_pad // block_r,),
        in_specs=in_specs,
        out_specs=[rowblk, rowblk],
        out_shape=[jax.ShapeDtypeStruct((q_pad, r_pad), jnp.float32),
                   jax.ShapeDtypeStruct((q_pad, r_pad), jnp.float32)],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*inputs)

    new = new_p[:q, :row_cap].astype(old.dtype).T
    upd = upd_p[:q, :row_cap].astype(bool).T
    if squeeze:
        return new[:, 0], upd[:, 0]
    return new, upd
