"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (Pallas executes
the kernel body with the XLA CPU backend); on a real TPU set
REPRO_PALLAS_INTERPRET=0 (or rely on the backend auto-detect) to compile
with Mosaic.  The one-hot compaction path needs indices < 2^24 (f32 lane
exactness) and falls back to the jnp oracle beyond that.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import compact as _compact
from repro.kernels import gab_gather as _gg
from repro.kernels import ref as _ref


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    # TPU compiles with Mosaic, GPU with Triton; only CPU (and anything
    # else without a Pallas lowering) needs the interpreter.
    return jax.default_backend() not in ("tpu", "gpu")


def _needs_exact_fallback(contrib: jax.Array) -> bool:
    """True when the f32 round-trip inside the kernel could lose bits.

    The one-hot kernel computes in f32, which represents integers exactly
    only up to 2^24.  Same guard shape as the compaction path below: decide
    statically from dtype (int8/int16 always fit; wider ints may not).
    """
    return (jnp.issubdtype(contrib.dtype, jnp.integer)
            and contrib.dtype.itemsize >= 4)


def segment_sum(contrib: jax.Array, dst: jax.Array, num_segments: int,
                block_e: int = _gg.DEFAULT_BLOCK_E,
                block_r: int = _gg.DEFAULT_BLOCK_R) -> jax.Array:
    """Sum-reduce contrib ``[E]`` or ``[E, Q]`` by dst ``[E]`` into
    ``[R]`` / ``[R, Q]`` rows (R = num_segments)."""
    if _needs_exact_fallback(contrib):
        return _ref.segment_sum(contrib, dst, num_segments)
    return _gg.segment_reduce_pallas(
        contrib, dst, num_segments, combine="sum",
        block_e=block_e, block_r=block_r, interpret=_interpret(),
    )


def segment_min(contrib: jax.Array, dst: jax.Array, num_segments: int,
                block_e: int = _gg.DEFAULT_BLOCK_E,
                block_r: int = _gg.DEFAULT_BLOCK_R) -> jax.Array:
    """Min-reduce contrib ``[E]`` or ``[E, Q]`` by dst ``[E]`` into
    ``[R]`` / ``[R, Q]`` rows (+inf for empty segments)."""
    if _needs_exact_fallback(contrib):
        return _ref.segment_min(contrib, dst, num_segments)
    return _gg.segment_reduce_pallas(
        contrib, dst, num_segments, combine="min",
        block_e=block_e, block_r=block_r, interpret=_interpret(),
    )


def segment_max(contrib: jax.Array, dst: jax.Array, num_segments: int,
                block_e: int = _gg.DEFAULT_BLOCK_E,
                block_r: int = _gg.DEFAULT_BLOCK_R) -> jax.Array:
    """Max-reduce contrib ``[E]`` or ``[E, Q]`` by dst ``[E]`` into
    ``[R]`` / ``[R, Q]`` rows (-inf for empty segments)."""
    if _needs_exact_fallback(contrib):
        return _ref.segment_max(contrib, dst, num_segments)
    return _gg.segment_reduce_pallas(
        contrib, dst, num_segments, combine="max",
        block_e=block_e, block_r=block_r, interpret=_interpret(),
    )


def compact(mask: jax.Array, values: jax.Array, capacity: int,
            block: int = _compact.DEFAULT_BLOCK,
            fill_index: int | None = None) -> tuple[jax.Array, jax.Array]:
    """First-`capacity` set indices of mask ``[V]`` (ascending) and their
    values ``[V]``, as ``([K], [K])`` with K = capacity."""
    if mask.shape[0] >= (1 << 24):
        return _ref.compact(mask, values, capacity, fill_index)
    return _compact.compact_pallas(
        mask, values, capacity, block=block,
        interpret=_interpret(), fill_index=fill_index,
    )
