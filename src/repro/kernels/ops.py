"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (Pallas executes
the kernel body with the XLA CPU backend); on a real TPU set
REPRO_PALLAS_INTERPRET=0 (or rely on the backend auto-detect) to compile
with Mosaic.  The one-hot compaction path needs indices < 2^24 (f32 lane
exactness) and falls back to the jnp oracle beyond that.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import compact as _compact
from repro.kernels import gab_gather as _gg
from repro.kernels import ref as _ref


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def segment_sum(contrib: jax.Array, dst: jax.Array, num_segments: int,
                block_e: int = _gg.DEFAULT_BLOCK_E,
                block_r: int = _gg.DEFAULT_BLOCK_R) -> jax.Array:
    return _gg.segment_reduce_pallas(
        contrib, dst, num_segments, combine="sum",
        block_e=block_e, block_r=block_r, interpret=_interpret(),
    )


def segment_min(contrib: jax.Array, dst: jax.Array, num_segments: int,
                block_e: int = _gg.DEFAULT_BLOCK_E,
                block_r: int = _gg.DEFAULT_BLOCK_R) -> jax.Array:
    return _gg.segment_reduce_pallas(
        contrib, dst, num_segments, combine="min",
        block_e=block_e, block_r=block_r, interpret=_interpret(),
    )


def segment_max(contrib: jax.Array, dst: jax.Array, num_segments: int,
                block_e: int = _gg.DEFAULT_BLOCK_E,
                block_r: int = _gg.DEFAULT_BLOCK_R) -> jax.Array:
    return _gg.segment_reduce_pallas(
        contrib, dst, num_segments, combine="max",
        block_e=block_e, block_r=block_r, interpret=_interpret(),
    )


def compact(mask: jax.Array, values: jax.Array, capacity: int,
            block: int = _compact.DEFAULT_BLOCK,
            fill_index: int | None = None) -> tuple[jax.Array, jax.Array]:
    if mask.shape[0] >= (1 << 24):
        return _ref.compact(mask, values, capacity, fill_index)
    return _compact.compact_pallas(
        mask, values, capacity, block=block,
        interpret=_interpret(), fill_index=fill_index,
    )
