"""Pallas TPU kernel for the GAB gather hot loop (paper §III-C).

The per-tile segment reduction ``out[r] = ⊕_{e: dst[e]=r} contrib[e]`` is
the SpMV-shaped inner loop of every GraphH superstep.  A CPU/GPU CSR walk
(pointer chasing) has no good TPU analogue, so we *re-shape the irregular
reduction into dense systolic work* (DESIGN.md §3/§4):

  sum monoid:  per (row-block j, edge-block i) grid step, build the one-hot
               matrix ``H[e, r] = (dst[e] == j*BR + r)`` in VMEM and
               accumulate ``contrib @ H`` on the MXU — each edge block
               costs Q x BE x BR MACs, turning gather-scatter into matmul.
  min/max:     same tiling, but a masked VPU reduction over the edge axis
               (select + min), since min-plus has no MXU form.

Multi-query axis (DESIGN.md §9): ``contrib`` may be ``[E]`` or ``[E, Q]``
(Q batched program instances sharing one edge pass).  Internally the
contrib block is laid out ``[Q, BE]`` so the sum monoid contracts
``[Q, BE] x [BE, BR] -> [Q, BR]`` — the Q=1 rank-1 matvec becomes a real
GEMM at Q>1 and MXU utilization rises with the batch for free (H is built
once per block regardless of Q).

Block sizes default to (BE, BR) = (512, 256): H is 512x256 f32 = 512 KB of
VMEM, contrib block Q x 2 KB, out block Q x 1 KB — comfortably inside the
~16 MB v5e VMEM budget with double buffering up to Q ~ few hundred (the
min/max select materializes [Q, BE, BR]; shrink BE/BR for very large Q).
All dims are multiples of 128 for MXU/lane alignment.  The edge-block axis
is the innermost grid dimension so the output row block stays resident
across the whole contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 512
DEFAULT_BLOCK_R = 256
SUBLANES = 8  # f32 tiles are (8, 128): the second-minor dim must be a multiple

_IDENTITY = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _kernel(dst_ref, contrib_ref, out_ref, *, block_r: int, combine: str):
    """Grid = (num_row_blocks, num_edge_blocks); edge axis innermost."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _IDENTITY[combine])

    dst = dst_ref[0, :]                    # [BE] int32 (global row ids)
    c = contrib_ref[...]                   # [Q, BE]
    j = pl.program_id(0)
    be = dst.shape[0]
    # rows covered by this output block: j*BR + [0, BR)
    rows = j * block_r + jax.lax.broadcasted_iota(jnp.int32, (be, block_r), 1)
    hit = dst[:, None] == rows             # [BE, BR] one-hot (padding misses all)

    if combine == "sum":
        h = hit.astype(c.dtype)
        acc = jax.lax.dot_general(
            c, h,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                   # [Q, BR] on the MXU
        out_ref[...] += acc.astype(out_ref.dtype)
    else:
        ident = jnp.asarray(_IDENTITY[combine], dtype=c.dtype)
        sel = jnp.where(hit[None, :, :], c[:, :, None], ident)   # [Q, BE, BR]
        red = jnp.min(sel, axis=1) if combine == "min" else jnp.max(sel, axis=1)
        cur = out_ref[...]
        out_ref[...] = (jnp.minimum(cur, red) if combine == "min"
                        else jnp.maximum(cur, red))


def _pad_axis(x: jax.Array, size: int, fill, axis: int = 0) -> jax.Array:
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    shape = list(x.shape)
    shape[axis] = pad
    return jnp.concatenate([x, jnp.full(shape, fill, dtype=x.dtype)], axis=axis)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "combine", "block_e", "block_r", "interpret"),
)
def segment_reduce_pallas(
    contrib: jax.Array,
    dst: jax.Array,
    num_segments: int,
    combine: str = "sum",
    block_e: int = DEFAULT_BLOCK_E,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = True,
) -> jax.Array:
    """Segment-reduce ``contrib`` by ``dst`` into ``num_segments`` buckets.

    ``contrib`` is ``[E]`` (returns ``[num_segments]``) or ``[E, Q]``
    (returns ``[num_segments, Q]``).  Shapes are padded to block multiples;
    padded edges use an out-of-range dst so they never hit a one-hot lane —
    an edge block made entirely of padding contributes only identities.
    dtype follows ``contrib``.
    """
    assert contrib.ndim in (1, 2) and dst.ndim == 1
    assert contrib.shape[0] == dst.shape[0]
    squeeze = contrib.ndim == 1
    cq = contrib[:, None] if squeeze else contrib     # [E, Q]
    e, q = cq.shape
    e_pad = max(((e + block_e - 1) // block_e) * block_e, block_e)
    r_pad = max(((num_segments + block_r - 1) // block_r) * block_r, block_r)
    # Q rides the sublane dim of every block: Mosaic rejects block shapes
    # whose second-minor dim is not a multiple of the 8-sublane tile, so pad
    # Q up and slice on return.  Padded query rows carry the identity and
    # never reach the caller.
    q_pad = max(((q + SUBLANES - 1) // SUBLANES) * SUBLANES, SUBLANES)

    # [Q, E] layout: the edge axis lands on TPU lanes, Q on sublanes.
    contrib_p = _pad_axis(cq.astype(jnp.float32).T, e_pad, 0.0, axis=1)
    contrib_p = _pad_axis(contrib_p, q_pad, _IDENTITY[combine], axis=0)
    dst_p = _pad_axis(dst.astype(jnp.int32), e_pad, jnp.int32(r_pad))[None, :]

    grid = (r_pad // block_r, e_pad // block_e)
    out = pl.pallas_call(
        functools.partial(_kernel, block_r=block_r, combine=combine),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_e), lambda j, i: (0, i)),   # dst
            pl.BlockSpec((q_pad, block_e), lambda j, i: (0, i)),   # contrib
        ],
        out_specs=pl.BlockSpec((q_pad, block_r), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((q_pad, r_pad), jnp.float32),
        interpret=interpret,
    )(dst_p, contrib_p)
    out = out[:q, :num_segments].astype(contrib.dtype)
    return out[0] if squeeze else out.T
