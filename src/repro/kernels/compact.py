"""Pallas TPU kernel: stream compaction for the sparse broadcast payload
(paper §III-D-3 — "convert a dense array into a list of indices and values").

Produces the first-K (index, value) pairs where ``mask`` is set, in
ascending index order — the wire format of GraphH's sparse communication
mode.

TPU adaptation: compaction is a scatter, which Mosaic dislikes; we reuse
the one-hot MXU trick from gab_gather.  Within each block of B elements:

  pos[e]   = exclusive prefix count of mask     (VPU cumsum)
  buf[p]   = Σ_e x[e] * mask[e] * (pos[e] == p) (MXU matmul — exact select,
                                                 positions are unique)

and the block's compacted buffer is stored at the running global offset
(dynamic-start, static-size store).  Grid steps execute sequentially on
TPU, so later blocks harmlessly overwrite the padding of earlier ones.

Exactness bound: indices are routed through f32 lanes, so this kernel
requires num_elements < 2^24; ops.py falls back to the jnp oracle above
that (checked at trace time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _kernel(offs_ref, mask_ref, val_ref, idx_out_ref, val_out_ref,
            *, block: int, fill: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        idx_out_ref[...] = jnp.full_like(idx_out_ref, fill)
        val_out_ref[...] = jnp.zeros_like(val_out_ref)

    m = mask_ref[0, :].astype(jnp.float32)          # [B] 0/1
    v = val_ref[0, :]                               # [B]
    csum = jnp.cumsum(m)
    pos = (csum - m).astype(jnp.int32)              # exclusive prefix
    count = csum[-1].astype(jnp.int32)

    gid = b * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)[0]
    # one-hot select matrix H[e, p] = mask[e] & (pos[e] == p)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    h = ((pos[:, None] == lanes) & (m[:, None] > 0)).astype(jnp.float32)

    def select(x):
        return jax.lax.dot_general(
            x.astype(jnp.float32)[None, :], h,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[0]                                        # [B]

    buf_val = select(v)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)[0]
    buf_idx = jnp.where(slot < count,
                        select(gid).astype(jnp.int32),
                        jnp.int32(fill))

    off = offs_ref[0, b]
    # row index as a 1-wide dslice: plain-int indexers trip newer jax's
    # interpret-mode discharge rule
    pl.store(idx_out_ref, (pl.dslice(0, 1), pl.dslice(off, block)),
             buf_idx[None, :])
    pl.store(val_out_ref, (pl.dslice(0, 1), pl.dslice(off, block)),
             buf_val.astype(val_out_ref.dtype)[None, :])


@functools.partial(
    jax.jit, static_argnames=("capacity", "block", "interpret", "fill_index")
)
def compact_pallas(
    mask: jax.Array,
    values: jax.Array,
    capacity: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
    fill_index: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """First-`capacity` set indices of mask ``[V]`` (ascending) and their
    values ``[V]``, as ``([K], [K])`` with K = capacity.

    Caller guarantees popcount(mask) <= capacity (comm.sparse_capacity does).
    """
    n = mask.shape[0]
    fill = n if fill_index is None else fill_index
    n_pad = max(((n + block - 1) // block) * block, block)
    pad = n_pad - n
    mask_p = jnp.concatenate(
        [mask.astype(jnp.int32), jnp.zeros((pad,), jnp.int32)])[None, :]
    val_p = jnp.concatenate(
        [values.astype(jnp.float32), jnp.zeros((pad,), jnp.float32)])[None, :]

    nblocks = n_pad // block
    counts = jnp.sum(mask_p.reshape(nblocks, block), axis=1)
    offs = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    out_len = ((capacity + block - 1) // block) * block + block
    offs = jnp.minimum(offs, out_len - block)[None, :]   # clamp: no overflow

    idx, val = pl.pallas_call(
        functools.partial(_kernel, block=block, fill=fill),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, nblocks), lambda b: (0, 0)),   # offsets (resident)
            pl.BlockSpec((1, block), lambda b: (0, b)),     # mask
            pl.BlockSpec((1, block), lambda b: (0, b)),     # values
        ],
        out_specs=[
            pl.BlockSpec((1, out_len), lambda b: (0, 0)),   # full, revisited
            pl.BlockSpec((1, out_len), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, out_len), jnp.int32),
            jax.ShapeDtypeStruct((1, out_len), jnp.float32),
        ],
        interpret=interpret,
    )(offs, mask_p, val_p)
    return idx[0, :capacity], val[0, :capacity].astype(values.dtype)
