"""Batched serving engine with continuous batching (slot refill).

Requests carry their own prompt/length; the engine keeps B cache slots:

  * waves of prefill fill empty slots (per-slot prefill, KV inserted into
    the batched cache — decoder-only archs), per-slot cache_len vector;
  * one decode step advances every active slot;
  * finished slots (EOS or max_new) are refilled from the queue.

Recurrent-state archs (R/K layers) and enc-dec run in wave mode (equal
prompt lengths per wave) — noted limitation of slot insertion for
stateful layers is handled by per-slot state insertion as well (states
have a batch axis too), so they also support refill.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models.model_zoo import build_model


@dataclasses.dataclass
class Request:
    """One serving request: prompt token ids plus decode limits."""

    rid: int
    prompt: np.ndarray           # [L] int32
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    """Finished request: generated tokens + prefill/decode wall time."""

    rid: int
    tokens: list
    prefill_s: float = 0.0
    decode_s: float = 0.0


def _insert_slot(batched, single, slot: int):
    """Insert a 1-batch cache pytree into slot `slot` of a batched cache."""
    def leaf(path, full, one):
        top = path[0].key if hasattr(path[0], "key") else str(path[0])
        axis = 1 if top in ("cycles", "dec", "xkv") else 0
        idx = [slice(None)] * full.ndim
        idx[axis] = slot
        src_idx = [slice(None)] * one.ndim
        src_idx[axis] = 0
        return full.at[tuple(idx)].set(one[tuple(src_idx)])

    return jax.tree_util.tree_map_with_path(leaf, batched, single)


class ServeEngine:
    """Continuous-batching engine over a fixed pool of cache slots:
    per-slot prefill fills empty slots, one decode step advances every
    active slot, finished slots refill from the queue (module docstring).
    Single-threaded — callers serialize access themselves."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params,
                 slots: int = 4, max_len: int = 512,
                 cache_dtype=jnp.float32):
        self.cfg = cfg
        self.run = run
        self.model = build_model(cfg, run)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = self.model.init_cache(slots, max_len, cache_dtype)
        self.single_cache_fn = lambda: self.model.init_cache(1, max_len, cache_dtype)
        self._prefill1 = jax.jit(
            lambda p, c, t: self.model.prefill(p, t, c))
        self._decode = jax.jit(
            lambda p, c, t, cl: self.model.decode_step(p, t, c, cl))
        self.cache_len = np.zeros(slots, np.int32)
        self.active = np.zeros(slots, bool)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_out: list[list] = [[] for _ in range(slots)]
        self.stats = dict(prefill_calls=0, decode_steps=0, tokens=0)

    # ------------------------------------------------------------------
    def _fill_slot(self, slot: int, req: Request) -> None:
        t0 = time.perf_counter()
        sc = self.single_cache_fn()
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        sc, logits = self._prefill1(self.params, sc, toks)
        self.cache = _insert_slot(self.cache, sc, slot)
        nxt = self._sample(logits[0, -1], req, step=0)
        self.cache_len[slot] = len(req.prompt)
        self.active[slot] = True
        self.slot_req[slot] = req
        self.slot_out[slot] = [int(nxt)]
        self.stats["prefill_calls"] += 1
        self._prefill_s = time.perf_counter() - t0

    def _sample(self, logits, req: Request, step: int):
        """Sample the next token; ``step`` is this request's decode-step
        counter, so the (rid, step) seed pair is fresh every step but a
        rerun of the same request reproduces the same sequence."""
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        p = jax.nn.softmax(logits / req.temperature)
        return int(np.random.default_rng((req.rid, step)).choice(
            len(p), p=np.asarray(p, dtype=np.float64) / float(np.sum(p))))

    def _slot_done(self, slot: int) -> bool:
        req = self.slot_req[slot]
        out = self.slot_out[slot]
        if len(out) >= req.max_new_tokens:
            return True
        if req.eos_id is not None and out and out[-1] == req.eos_id:
            return True
        if self.cache_len[slot] + len(out) >= self.max_len - 1:
            return True
        return False

    # ------------------------------------------------------------------
    def run_requests(self, requests: list[Request]) -> list[Completion]:
        """Serve ``requests`` to completion with slot refill; completions
        are returned in finish order, not submission order."""
        queue = list(requests)
        done: list[Completion] = []
        completions: dict[int, Completion] = {}

        while queue or self.active.any():
            # refill empty slots (continuous batching)
            for s in range(self.slots):
                if not self.active[s] and queue:
                    req = queue.pop(0)
                    self._fill_slot(s, req)
                    completions[req.rid] = Completion(req.rid, [],
                                                      prefill_s=self._prefill_s)
            if not self.active.any():
                break

            # one decode step for every slot (inactive slots decode garbage,
            # results discarded — the batched step is a single jit call)
            last = np.zeros((self.slots, 1), np.int32)
            for s in range(self.slots):
                if self.active[s]:
                    last[s, 0] = self.slot_out[s][-1]
            t0 = time.perf_counter()
            cl = jnp.asarray(self.cache_len + np.maximum(
                np.array([len(o) for o in self.slot_out]) - 1, 0), jnp.int32)
            self.cache, logits = self._decode(
                self.params, self.cache, jnp.asarray(last), cl)
            dt = time.perf_counter() - t0
            self.stats["decode_steps"] += 1

            for s in range(self.slots):
                if not self.active[s]:
                    continue
                req = self.slot_req[s]
                nxt = self._sample(logits[s, -1], req,
                                   step=len(self.slot_out[s]))
                self.slot_out[s].append(int(nxt))
                completions[req.rid].decode_s += dt / max(self.active.sum(), 1)
                self.stats["tokens"] += 1
                if self._slot_done(s):
                    comp = completions[req.rid]
                    comp.tokens = list(self.slot_out[s])
                    done.append(comp)
                    self.active[s] = False
                    self.slot_req[s] = None
        return done
