"""JSON-over-HTTP frontend for the graph-query service (DESIGN.md §16).

Stdlib-only (``http.server.ThreadingHTTPServer`` — no new dependencies,
same constraint as the ast-only lint suite).  One :class:`HttpFrontend`
wraps a running :class:`~repro.serve.graph_service.GraphService`:

  ``POST /v1/query``          body ``{"app", "seed", "deadline_ms"?,
                              "tenant"?}`` → the ticket as JSON (``rid``
                              is the handle for later polls); a result-
                              cache hit comes back already ``done``
  ``GET  /v1/query/<rid>``    ticket status + latency split; finished
                              tickets carry the exact [V] result column
                              (base64 of the raw little-endian bytes —
                              JSON floats would not round-trip bits)
  ``GET  /v1/stats``          service + per-tenant + cache + HTTP counters
  ``GET  /healthz``           ``200 ok`` / ``503 draining``

Error semantics: every malformed request — non-JSON body, unknown app,
out-of-range or non-integer seed, absurd deadline, bad tenant label —
yields a structured ``4xx`` ``{"error": ...}`` and never crashes the
handler thread; unexpected handler exceptions come back as structured
``500``s.  Once the service drains (SIGTERM), ``POST /v1/query`` and
``/healthz`` return **503** with ``Retry-After`` so load balancers back
off, while ``GET /v1/query/<rid>`` keeps answering — clients collect
in-flight results during the drain window.

Fault injection (runtime.faults): the response path is a named site —
``site=http_response`` with ``kind=delay`` sleeps before writing,
``kind=drop`` closes the connection without a response (a lost reply on
the wire).  Dropped responses mutate nothing: the ticket registry is
keyed by ``rid``, so a client retry of the same rid observes the
completed result.

Request handling runs on ``ThreadingHTTPServer``'s per-connection
threads; everything they touch is either per-request local, the
service's own thread-safe surface (``submit``/``get``/
``stats_snapshot``), or :class:`HttpFrontend` counters under its lock
(``_guarded_by``, enforced by tools/analyze.py).
"""
from __future__ import annotations

import base64
import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

import numpy as np

from repro.serve.graph_service import (DEFAULT_TENANT, SERVABLE,
                                       GraphService, QueryTicket)

#: request bodies past this are rejected with 413 (tickets are tiny)
MAX_BODY_BYTES = 1 << 20
#: deadlines outside (0, MAX_DEADLINE_MS] are structured 400s
MAX_DEADLINE_MS = 86_400_000.0
#: tenant labels: printable, non-empty, bounded
MAX_TENANT_LEN = 64


class BadRequest(ValueError):
    """Raised by request validation; the handler maps it to a structured
    4xx response (``.status`` defaults to 400)."""

    def __init__(self, message: str, status: int = 400):
        super().__init__(message)
        self.status = status


def encode_array(a: np.ndarray) -> dict:
    """JSON-safe exact encoding of an array: dtype + shape + base64 of
    the raw little-endian bytes (bit-exact round-trip, unlike JSON
    floats)."""
    a = np.ascontiguousarray(a)
    return dict(dtype=str(a.dtype), shape=list(a.shape),
                data_b64=base64.b64encode(a.tobytes()).decode("ascii"))


def decode_array(d: dict) -> np.ndarray:
    """Inverse of :func:`encode_array` (shared by tests and clients)."""
    raw = base64.b64decode(d["data_b64"])
    return np.frombuffer(raw, dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]).copy()


def ticket_json(t: QueryTicket) -> dict:
    """The wire form of one ticket: identity, status, latency split, and
    — once finished — the exact result column."""
    finished = t.status in ("done", "timeout", "failed")
    out = dict(rid=t.rid, app=t.app, seed=t.seed, tenant=t.tenant,
               status=t.status, cache_hit=t.cache_hit,
               supersteps=t.supersteps)
    if finished:
        out.update(
            queue_ms=t.queue_wait_s * 1e3,
            service_ms=t.service_s * 1e3,
            total_ms=t.total_s * 1e3,
            result=(encode_array(t.result) if t.result is not None
                    else None),
        )
    return out


def parse_query_body(raw: bytes, num_vertices: int) -> dict:
    """Validate a ``POST /v1/query`` body; returns submit() kwargs.

    Everything a client can get wrong is a :class:`BadRequest` — the
    handler thread must survive arbitrary bytes here."""
    if len(raw) > MAX_BODY_BYTES:
        raise BadRequest(f"body exceeds {MAX_BODY_BYTES} bytes", 413)
    try:
        body = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise BadRequest(f"body is not valid JSON: {e}") from e
    if not isinstance(body, dict):
        raise BadRequest("body must be a JSON object")
    app = body.get("app")
    if not isinstance(app, str) or app not in SERVABLE:
        raise BadRequest(f"app must be one of {', '.join(SERVABLE)}; "
                         f"got {app!r}")
    seed = body.get("seed")
    if isinstance(seed, bool) or not isinstance(seed, int):
        raise BadRequest(f"seed must be an integer vertex id; got "
                         f"{seed!r}")
    if not 0 <= seed < num_vertices:
        raise BadRequest(f"seed {seed} outside [0, {num_vertices}) "
                         "for this graph")
    deadline_ms = body.get("deadline_ms")
    deadline_s: Optional[float] = None
    if deadline_ms is not None:
        if (isinstance(deadline_ms, bool)
                or not isinstance(deadline_ms, (int, float))
                or not math.isfinite(deadline_ms)
                or not 0 < deadline_ms <= MAX_DEADLINE_MS):
            raise BadRequest(
                f"deadline_ms must be a finite number in "
                f"(0, {MAX_DEADLINE_MS:g}]; got {deadline_ms!r}")
        deadline_s = float(deadline_ms) / 1e3
    tenant = body.get("tenant", DEFAULT_TENANT)
    if (not isinstance(tenant, str) or not tenant
            or len(tenant) > MAX_TENANT_LEN or not tenant.isprintable()):
        raise BadRequest("tenant must be a non-empty printable string "
                         f"of at most {MAX_TENANT_LEN} chars; got "
                         f"{tenant!r}")
    return dict(app=app, seed=seed, deadline_s=deadline_s, tenant=tenant)


class _Handler(BaseHTTPRequestHandler):
    """Per-connection request handler (``frontend`` is bound by the
    :class:`HttpFrontend` that instantiates the server)."""

    frontend: "HttpFrontend" = None      # type: ignore[assignment]
    server_version = "graphh-serve/1"

    # -- plumbing ----------------------------------------------------------
    def log_message(self, fmt, *args):   # noqa: D102 - stdlib signature
        pass                             # no per-request stderr chatter

    def _count(self, key: str) -> None:
        fe = self.frontend
        with fe._lock:
            fe.http_stats[key] = fe.http_stats.get(key, 0) + 1

    def _send_json(self, status: int, payload: dict,
                   retry_after: Optional[int] = None) -> None:
        """Serialize + send one JSON response, honoring the
        ``http_response`` fault site (delay sleeps here; drop closes the
        connection with nothing written — the client must retry)."""
        fe = self.frontend
        if fe.fault is not None:
            fe.fault.check("http_response")
            if fe.fault.drop("http_response"):
                self._count("dropped_responses")
                self.close_connection = True
                return
        body = json.dumps(payload).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        if retry_after is not None:
            self.send_header("Retry-After", str(retry_after))
        self.end_headers()
        self.wfile.write(body)
        if status >= 500:
            self._count("errors_5xx")
        elif status >= 400:
            self._count("errors_4xx")

    def _guarded(self, fn) -> None:
        """Run one route; any uncaught exception becomes a structured 500
        instead of killing the handler thread silently."""
        self._count("requests")
        try:
            fn()
        except (BrokenPipeError, ConnectionResetError):
            self.close_connection = True     # client went away mid-write
        except Exception as e:               # noqa: BLE001 - last resort
            try:
                self._send_json(500, dict(error=f"internal error: "
                                                f"{type(e).__name__}: {e}"))
            except Exception:                # noqa: BLE001 - socket gone
                self.close_connection = True

    # -- routes ------------------------------------------------------------
    def do_POST(self) -> None:               # noqa: N802 - stdlib naming
        """``POST /v1/query`` — validate, submit, return the ticket."""
        self._guarded(self._post_query)

    def do_GET(self) -> None:                # noqa: N802 - stdlib naming
        """``GET /v1/query/<rid>`` | ``/v1/stats`` | ``/healthz``."""
        self._guarded(self._get)

    def _post_query(self) -> None:
        if self.path.rstrip("/") != "/v1/query":
            self._send_json(404, dict(error=f"no such endpoint "
                                            f"{self.path!r}"))
            return
        svc = self.frontend.service
        try:
            length = int(self.headers.get("Content-Length") or 0)
        except ValueError:
            self._send_json(400, dict(error="bad Content-Length"))
            return
        raw = self.rfile.read(min(length, MAX_BODY_BYTES + 1))
        try:
            kw = parse_query_body(raw, svc.num_vertices)
        except BadRequest as e:
            self._send_json(e.status, dict(error=str(e)))
            return
        try:
            t = svc.submit(**kw)
        except RuntimeError:
            # draining: load balancers must back off (503 + Retry-After)
            self._count("refused_503")
            self._send_json(503, dict(error="service is draining — "
                                            "not admitting"),
                            retry_after=1)
            return
        except ValueError as e:
            self._send_json(400, dict(error=str(e)))
            return
        self._send_json(200, ticket_json(t))

    def _get(self) -> None:
        svc = self.frontend.service
        path = self.path.rstrip("/") or "/"
        if path == "/healthz":
            if svc.draining:
                self._send_json(503, dict(status="draining"),
                                retry_after=1)
            else:
                self._send_json(200, dict(status="ok"))
            return
        if path == "/v1/stats":
            snap = svc.stats_snapshot()
            snap["http"] = self.frontend.counters()
            snap["latency"] = svc.latency_summary()
            self._send_json(200, snap)
            return
        if path.startswith("/v1/query/"):
            rid_str = path[len("/v1/query/"):]
            try:
                rid = int(rid_str)
            except ValueError:
                self._send_json(400, dict(error=f"rid must be an "
                                                f"integer; got {rid_str!r}"))
                return
            t = svc.get(rid)
            if t is None:
                self._send_json(404, dict(error=f"unknown rid {rid}"))
                return
            self._send_json(200, ticket_json(t))
            return
        self._send_json(404, dict(error=f"no such endpoint {self.path!r}"))


class HttpFrontend:
    """Threaded HTTP server bound to one :class:`GraphService` (module
    docstring).  ``port=0`` binds an ephemeral port (``self.port`` holds
    the real one).  ``fault`` is an optional
    :class:`~repro.runtime.faults.FaultInjector` armed at the
    ``http_response`` site."""

    #: lock discipline, enforced by tools/analyze.py --check locks
    _guarded_by = {"http_stats": "_lock"}

    def __init__(self, service: GraphService, *, host: str = "127.0.0.1",
                 port: int = 0, fault=None):
        self.service = service
        self.fault = fault
        self._lock = threading.Lock()
        self.http_stats: dict = dict(requests=0, errors_4xx=0,
                                     errors_5xx=0, refused_503=0,
                                     dropped_responses=0)
        fe = self

        class _Bound(_Handler):
            frontend = fe

        self.server = ThreadingHTTPServer((host, int(port)), _Bound)
        self.server.daemon_threads = True
        self.host, self.port = self.server.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> str:
        """Base URL clients should hit."""
        return f"http://{self.host}:{self.port}"

    def counters(self) -> dict:
        """Copy of the HTTP-layer counters (under the lock)."""
        with self._lock:
            return dict(self.http_stats)

    def start(self) -> "HttpFrontend":
        """Serve on a daemon thread; returns self (chainable)."""
        self._thread = threading.Thread(target=self.server.serve_forever,
                                        name="graph-http", daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop accepting, close the listening socket, join the server
        thread.  Idempotent."""
        self.server.shutdown()
        self.server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
