"""Serve step builders: jit-compiled prefill and decode functions with
mesh-aware shardings — these are what decode_* / long_* dry-run cells lower.

KV-cache shardings: batch over dp axes, kv-heads over "model" (GSPMD pads
when head counts don't divide — noted in DESIGN.md).  For long-context
cells the per-layer global KV cache can instead be sharded over the
*sequence* axis ("seq_shard_decode"), pairing with the flash-decoding
attention in models.layers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models import sharding as shd
from repro.models.model_zoo import build_model


def cache_pspec(path: str, ndim: int, rules: shd.Rules,
                seq_shard: bool = False) -> P:
    """KV leaves: [n_cyc?, B, S, Hkv, Dh]; rnn/rwkv states: [.., B, ...]."""
    dp = rules.dp
    if ndim >= 4:          # kv cache (maybe with leading stack dim)
        spec = [None] * ndim
        spec[-4] = dp
        if seq_shard:
            spec[-3] = rules.tp_axis
        else:
            spec[-2] = rules.tp_axis
        return P(*spec)
    if ndim >= 2:          # recurrent states [.., B, ...]
        spec = [None] * ndim
        if ndim == 2:
            spec[0] = dp
        else:
            spec[-3 if ndim >= 3 else 0] = dp
        return P(*spec)
    return P()


def _cache_shardings(cache_shapes, mesh, rules, seq_shard=False):
    def leaf(path, x):
        p = shd._path_str(path)
        ndim = len(x.shape)
        if p.endswith("k") or p.endswith("v"):
            sp = cache_pspec(p, ndim, rules, seq_shard)
        else:
            # recurrent state leaves: shard the batch dim
            spec = [None] * ndim
            bidx = 1 if ndim >= 3 else 0   # stacked [n_cyc, B, ...] vs [B, ...]
            spec[bidx] = rules.dp
            sp = P(*spec)
        return NamedSharding(mesh, shd.sanitize_spec(sp, x.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def build_serve_fns(
    cfg: ModelConfig,
    run: RunConfig,
    mesh: Optional[Mesh] = None,
    max_len: int = 2048,
    batch: int = 1,
    cache_dtype=jnp.bfloat16,
):
    """Returns dict with jitted prefill/decode fns + shardings + cache init."""
    model = build_model(cfg, run)
    rules = None
    if mesh is not None:
        rules = shd.Rules(
            dp_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
            fsdp=run.sharding_mode == "fsdp", zero1=False)

    def init_cache():
        return model.init_cache(batch, max_len, cache_dtype)

    def prefill(params, cache, batch_inputs):
        ctx = shd.use_mesh(mesh, rules) if mesh is not None else _null()
        with ctx:
            if cfg.encoder_layers > 0:
                return model.prefill(params, batch_inputs["tokens"], cache,
                                     batch_inputs["enc_frames"])
            return model.prefill(params, batch_inputs["tokens"], cache,
                                 extra_embeds=batch_inputs.get("patch_embeds"))

    def decode(params, cache, token, cache_len):
        ctx = shd.use_mesh(mesh, rules) if mesh is not None else _null()
        with ctx:
            return model.decode_step(params, token, cache, cache_len)

    if mesh is None:
        return dict(model=model, init_cache=init_cache,
                    prefill=jax.jit(prefill), decode=jax.jit(decode),
                    shardings=None, rules=None)

    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = shd.param_specs(pshapes, rules, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    cshapes = jax.eval_shape(init_cache)
    cshard = _cache_shardings(cshapes, mesh, rules, run.seq_shard_decode)
    dp = rules.dp
    # batch=1 (long-context) can't shard over dp -> replicate tokens/cache B
    dp_ok = batch % shd.axis_size(mesh, dp) == 0
    tok_shard = NamedSharding(mesh, P(dp) if dp_ok else P())
    rep = NamedSharding(mesh, P())

    in_batch_shardings = {"tokens": tok_shard}
    if cfg.encoder_layers > 0:
        in_batch_shardings["enc_frames"] = NamedSharding(mesh, P(dp) if dp_ok else P())
    if cfg.frontend == "vision":
        in_batch_shardings["patch_embeds"] = NamedSharding(mesh, P(dp) if dp_ok else P())

    # decode consumes the *prefilled* cache, whose structure can be richer
    # than init_cache (whisper adds cross-attention K/V at prefill time).
    if cfg.encoder_layers > 0:
        enc_len = max_len // 2
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((batch, max_len - 1), jnp.int32),
            "enc_frames": jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model),
                                               jnp.bfloat16),
        }
        full_cache_shapes = jax.eval_shape(
            lambda p, c, b: prefill(p, c, b)[0], pshapes, cshapes, batch_shapes)
        dec_cshard = _cache_shardings(full_cache_shapes, mesh, rules,
                                      run.seq_shard_decode)
    else:
        dec_cshard = cshard

    prefill_j = jax.jit(
        prefill,
        in_shardings=(pshard, cshard, in_batch_shardings),
        donate_argnums=(1,),
    )
    decode_j = jax.jit(
        decode,
        in_shardings=(pshard, dec_cshard, tok_shard, rep),
        donate_argnums=(1,),
    )
    return dict(model=model, init_cache=init_cache, prefill=prefill_j,
                decode=decode_j,
                shardings=dict(params=pshard, cache=cshard,
                               dec_cache=dec_cshard, specs=pspecs),
                rules=rules)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
