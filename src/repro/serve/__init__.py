"""Serving substrate: prefill/decode step builders + batched request
engine + the online graph-query service."""
