# Serving substrate: prefill/decode step builders + batched request engine.
