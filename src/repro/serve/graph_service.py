"""Online graph-query serving over step-driven engine sessions
(DESIGN.md §13; beyond the GraphH paper, which is batch-only).

The batch engine already retires converged query columns mid-run;
:class:`~repro.core.engine.EngineSession` adds the inverse — splicing
fresh queries into the freed ``[V, Q]`` slots at a barrier.  This module
turns that pair into a long-running service:

  * ``submit(app, seed)`` (any thread) queues a query and returns a
    :class:`QueryTicket`;
  * the serve loop (ONE driver thread — run ``serve()`` in the main
    thread so the SIGTERM guard is live) opens one engine session per
    app family, steps the live sessions round-robin, and admits queued
    queries at barriers under a **batched admission policy**: wait until
    ``min_fill`` queries are queued (amortizing the all-dirty superstep
    an admission forces) but never past ``max_wait_s``;
  * per-query **deadlines**: a live query past its deadline is drained
    at the next barrier — its ticket finishes with status ``timeout``
    and the partial column as the result;
  * per-query **latency accounting**: queue wait, service time, total,
    and the superstep count (identical to a fresh single-query run's,
    by the admission-equivalence invariant);
  * **graceful drain** on SIGTERM (or ``request_drain()``): admission
    stops, in-flight queries either run to convergence
    (``drain_mode="finish"``) or the sessions checkpoint with their
    per-slot query lineage (``drain_mode="checkpoint"``, resumable via
    ``resume=True``), then ``serve()`` returns — exit 0.

Sessions are ephemeral: when a session finishes (everything converged,
nothing queued for its app) it is finalized and discarded; the next
submit for that app opens a fresh one.  Engines — and their edge-tile
caches, skip filters, interval bookkeeping — persist for the service
lifetime, so a new session starts with warm caches.

Multi-tenant fairness (DESIGN.md §16): every submit carries a ``tenant``
label, pending queries queue **per tenant**, and each admit-at-barrier
selects across the backlogged tenants by **weighted deficit round-robin**
— tenant ``t`` earns ``weight[t]`` credit per round and spends one credit
per admitted query, so over any sustained backlog the admitted shares
track the configured weights within one query and a hot tenant can never
starve the others.  Idle tenants bank no credit (their deficit resets),
so fairness is work-conserving.

Result cache: with a :class:`ResultCache` attached, a submit whose
``(app, seed, graph fingerprint)`` was served before returns the cached
column immediately — ``status="done"``, ``cache_hit=True``, no ``[V, Q]``
slot consumed, no admission barrier.  Only converged (``done``) results
are cached; deadline-drained partials never are.  The fingerprint
(:meth:`~repro.graphio.formats.TileStore.fingerprint`) keys the cache to
the preprocessed graph bytes, so one cache instance may safely front
several services over different graphs.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Optional

import numpy as np

from repro.core.apps import APPS
from repro.core.engine import EngineConfig, OutOfCoreEngine
from repro.runtime.ft import PreemptionGuard

#: app families the service accepts: batched [V, Q] programs only (the
#: admission protocol splices query columns; 1-D programs have none)
SERVABLE = ("ppr", "msbfs", "landmarks")

#: tenant label used when a submit does not name one
DEFAULT_TENANT = "default"


def parse_tenants(spec: str) -> dict[str, float]:
    """Parse a CLI tenant-weight spec, e.g. ``"alice:3,bob:1"`` (a bare
    name means weight 1).  Weights must be positive."""
    out: dict[str, float] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if ":" in part:
            name, w = part.split(":", 1)
            weight = float(w)
        else:
            name, weight = part, 1.0
        name = name.strip()
        if not name:
            raise ValueError(f"--tenants: empty tenant name in {spec!r}")
        if not weight > 0:
            raise ValueError(f"--tenants: weight for {name!r} must be "
                             f"positive, got {weight:g}")
        out[name] = weight
    if not out:
        raise ValueError(f"--tenants: no tenants in {spec!r}")
    return out


class ResultCache:
    """Exact, thread-safe LRU result cache for served queries.

    Keys are ``(app, seed, graph_fingerprint)`` — the fingerprint scopes
    entries to one preprocessed graph, so a shared cache never serves a
    result across differing graphs.  Values are the frozen [V] column and
    its superstep count; ``get`` returns defensive copies, so a hit is
    bit-identical to the cold execution that populated it and immune to
    caller mutation."""

    #: lock discipline, enforced by tools/analyze.py --check locks
    _guarded_by = {"_entries": "_lock", "hits": "_lock", "misses": "_lock"}

    def __init__(self, capacity: int = 256):
        self.capacity = max(1, int(capacity))
        self._lock = threading.Lock()
        self._entries: dict = {}          # key -> (values [V], supersteps)
        self.hits = 0
        self.misses = 0

    def get(self, app: str, seed: int, fingerprint: str):
        """``(values [V], supersteps)`` for a hit (fresh copies), else
        None; counts the hit/miss either way."""
        key = (app, int(seed), fingerprint)
        with self._lock:
            hit = self._entries.pop(key, None)
            if hit is None:
                self.misses += 1
                return None
            self._entries[key] = hit      # re-insert = LRU touch
            self.hits += 1
            values, supersteps = hit
            return values.copy(), supersteps

    def put(self, app: str, seed: int, fingerprint: str,
            values: np.ndarray, supersteps: int) -> None:
        """Insert one converged result (the caller promises exactness —
        drained partials must not be cached); evicts LRU past capacity."""
        key = (app, int(seed), fingerprint)
        with self._lock:
            self._entries.pop(key, None)
            self._entries[key] = (np.asarray(values).copy(),
                                  int(supersteps))
            while len(self._entries) > self.capacity:
                self._entries.pop(next(iter(self._entries)))

    def snapshot(self) -> dict:
        """Hit/miss/entry counters (stats surface)."""
        with self._lock:
            return dict(hits=self.hits, misses=self.misses,
                        entries=len(self._entries),
                        capacity=self.capacity)


@dataclasses.dataclass
class QueryTicket:
    """One submitted query's lifecycle + latency record.

    ``status``: queued → running → done | timeout (``failed`` when the
    service shut down before the query could finish).  ``result`` holds
    the query's [V] value column once finished (partial values for
    timeouts).  Times are ``time.perf_counter()`` seconds.
    """

    rid: int
    app: str
    seed: int
    tenant: str = DEFAULT_TENANT
    deadline_s: Optional[float] = None
    cache_hit: bool = False
    submitted_s: float = 0.0
    status: str = "queued"
    gq: int = -1                     # global qid inside the app's session
    admitted_s: float = 0.0
    finished_s: float = 0.0
    supersteps: int = -1
    result: Optional[np.ndarray] = None
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    @property
    def queue_wait_s(self) -> float:
        """Seconds from submit to admission at a barrier."""
        return max(0.0, self.admitted_s - self.submitted_s)

    @property
    def service_s(self) -> float:
        """Seconds from admission to retirement (or drain)."""
        return max(0.0, self.finished_s - self.admitted_s)

    @property
    def total_s(self) -> float:
        """Submit-to-finish latency — what the client observes."""
        return max(0.0, self.finished_s - self.submitted_s)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the query finished (done/timeout/failed)."""
        return self._event.wait(timeout)


class GraphService:
    """Long-running graph-query service over one TileStore (module
    docstring).  ``cfg`` is the engine config template; the service
    forces ``preemptible=False`` (it owns the SIGTERM guard — the engine
    must not race it for the handlers) and fans out per-app checkpoint
    directories under ``cfg.checkpoint_dir`` when one is set."""

    # ``_wake`` is a Condition wrapping ``_lock`` — either name guards.
    # ``_sessions``/``completed`` are serve-thread-owned by design and
    # deliberately undeclared.
    _guarded_by = {
        "_pending": ("_lock", "_wake"),
        "_live": ("_lock", "_wake"),
        "_tickets": ("_lock", "_wake"),
        "_deficit": ("_lock", "_wake"),
        "_next_rid": ("_lock", "_wake"),
        "_draining": ("_lock", "_wake"),
        "_stopped": ("_lock", "_wake"),
        "stats": ("_lock", "_wake"),
        "tenant_stats": ("_lock", "_wake"),
    }

    def __init__(self, store, cfg: EngineConfig, *,
                 q_slots: int = 8,
                 min_fill: int = 1,
                 max_wait_s: float = 0.05,
                 default_deadline_s: Optional[float] = None,
                 max_supersteps: int = 200,
                 drain_mode: str = "finish",
                 resume: bool = False,
                 tenants: Optional[dict] = None,
                 result_cache=None):
        if drain_mode not in ("finish", "checkpoint"):
            raise ValueError(f"drain_mode {drain_mode!r}")
        if drain_mode == "checkpoint" and not cfg.checkpoint_dir:
            raise ValueError("drain_mode='checkpoint' needs a "
                             "cfg.checkpoint_dir")
        self.store = store
        self.cfg = dataclasses.replace(cfg, preemptible=False,
                                       resume=resume)
        self.q_slots = max(1, int(q_slots))
        self.min_fill = max(1, int(min_fill))
        self.max_wait_s = float(max_wait_s)
        self.default_deadline_s = default_deadline_s
        self.max_supersteps = int(max_supersteps)
        self.drain_mode = drain_mode
        #: configured tenant -> weight map (None = every tenant weight 1);
        #: unknown tenants are admitted at weight 1, never rejected
        self.tenants = dict(tenants) if tenants else None
        if self.tenants and any(w <= 0 for w in self.tenants.values()):
            raise ValueError("tenant weights must be positive")
        #: exact result cache (shared ResultCache, an int capacity, or None)
        if isinstance(result_cache, int):
            result_cache = (ResultCache(result_cache) if result_cache > 0
                            else None)
        self.cache: Optional[ResultCache] = result_cache
        self.fingerprint = store.fingerprint()
        self.num_vertices = int(store.load_plan().num_vertices)
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        #: pending queues keyed app -> tenant -> FIFO ticket list
        self._pending: dict[str, dict[str, list[QueryTicket]]] = {}
        #: deficit-round-robin credit, keyed app -> tenant
        self._deficit: dict[str, dict[str, float]] = {}
        self._live: dict[str, dict[int, QueryTicket]] = {}
        self._tickets: dict[int, QueryTicket] = {}
        self._engines: dict[str, OutOfCoreEngine] = {}
        self._sessions: dict = {}
        self._next_rid = 0
        self._draining = False
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        self.guard = PreemptionGuard()
        self.completed: list[QueryTicket] = []
        self.stats = dict(submitted=0, done=0, timeout=0, failed=0,
                          refused=0, cache_hits=0, cache_misses=0,
                          supersteps=0, sessions_opened=0)
        #: per-tenant submitted/admitted/done counters (fairness audit)
        self.tenant_stats: dict[str, dict] = {}
        if resume and cfg.checkpoint_dir:
            self._resume_sessions()

    # -- client surface ----------------------------------------------------
    def submit(self, app: str, seed: int,
               deadline_s: Optional[float] = None,
               tenant: str = DEFAULT_TENANT) -> QueryTicket:
        """Queue one query (thread-safe); returns its ticket.  A result-
        cache hit resolves the ticket immediately (``cache_hit=True``, no
        slot consumed).  Raises ``RuntimeError`` once the service is
        draining — clients must back off (HTTP maps this to 503), and the
        refusal is counted so ``submitted == done + timeout + failed +
        refused`` holds at drain."""
        if app not in SERVABLE:
            raise ValueError(f"app {app!r} not servable (batched apps "
                             f"only: {', '.join(SERVABLE)})")
        seed = int(seed)
        if not 0 <= seed < self.num_vertices:
            raise ValueError(f"seed {seed} outside [0, "
                             f"{self.num_vertices}) for this graph")
        tenant = str(tenant) or DEFAULT_TENANT
        hit = (self.cache.get(app, seed, self.fingerprint)
               if self.cache is not None else None)
        with self._lock:
            self.stats["submitted"] += 1
            ts = self.tenant_stats.setdefault(
                tenant, dict(submitted=0, admitted=0, done=0, refused=0))
            ts["submitted"] += 1
            if self._draining or self._stopped:
                self.stats["refused"] += 1
                ts["refused"] += 1
                raise RuntimeError("service is draining — not admitting")
            now = time.perf_counter()
            t = QueryTicket(rid=self._next_rid, app=app, seed=seed,
                            tenant=tenant,
                            deadline_s=(deadline_s if deadline_s is not None
                                        else self.default_deadline_s),
                            submitted_s=now)
            self._next_rid += 1
            self._tickets[t.rid] = t
            if hit is not None:
                values, supersteps = hit
                t.cache_hit = True
                t.status = "done"
                t.admitted_s = t.finished_s = now
                t.result = values
                t.supersteps = supersteps
                self.stats["done"] += 1
                self.stats["cache_hits"] += 1
                ts["done"] += 1
                self.completed.append(t)
                t._event.set()
                return t
            if self.cache is not None:
                self.stats["cache_misses"] += 1
            self._pending.setdefault(app, {}).setdefault(
                tenant, []).append(t)
            self._wake.notify()
        return t

    def get(self, rid: int) -> Optional[QueryTicket]:
        """Look up a ticket by request id (thread-safe); None if unknown —
        the HTTP frontend's GET /v1/query/<rid> backend."""
        with self._lock:
            return self._tickets.get(int(rid))

    def request_drain(self) -> None:
        """Begin graceful drain (what SIGTERM triggers): stop admitting,
        finish or checkpoint in-flight work, then ``serve()`` returns."""
        with self._lock:
            self._draining = True
            self._wake.notify()

    # -- serve loop --------------------------------------------------------
    def serve(self) -> None:
        """Run the serve loop until drained.  Call from the MAIN thread
        for live SIGTERM handling (``PreemptionGuard`` is inert
        elsewhere); background use goes through ``start()`` +
        ``request_drain()``."""
        with self.guard:
            try:
                while True:
                    if self.guard.triggered:
                        with self._lock:
                            self._draining = True
                    if self._tick():
                        break
            finally:
                self._shutdown()

    def start(self) -> threading.Thread:
        """Run ``serve()`` on a daemon thread (benchmarks/tests; SIGTERM
        latching is inert off-main-thread — use ``request_drain()``)."""
        self._thread = threading.Thread(target=self.serve,
                                        name="graph-serve", daemon=True)
        self._thread.start()
        return self._thread

    def join(self, timeout: Optional[float] = None) -> None:
        """Wait for the ``start_background()`` serve thread to exit."""
        if self._thread is not None:
            self._thread.join(timeout)

    def _weight(self, tenant: str) -> float:
        """Configured DRR weight; unknown tenants serve at weight 1."""
        return float((self.tenants or {}).get(tenant, 1.0))

    def _pending_count(self, app: str) -> int:
        """Queued tickets for ``app`` across tenants (under the lock)."""
        return sum(len(q) for q in self._pending.get(app, {}).values())

    def _oldest_pending_s(self, app: str) -> float:
        """Earliest submit time still queued for ``app`` (under the
        lock); +inf when nothing is queued."""
        heads = [q[0].submitted_s
                 for q in self._pending.get(app, {}).values() if q]
        return min(heads) if heads else float("inf")

    def _drr_take(self, app: str, k: int) -> list:
        """Select up to ``k`` pending tickets for ``app`` by weighted
        deficit round-robin across its tenants (module docstring); called
        under the lock.  Each round every backlogged tenant — cycled in
        sorted order, so selection is deterministic — earns ``weight``
        credit and spends one credit per admitted query; a tenant whose
        queue empties forfeits its banked credit.  Terminates: credit
        strictly grows per round while a tenant is backlogged, so any
        positive weight admits within ``ceil(1/weight)`` rounds."""
        queues = self._pending.get(app)
        if not queues:
            return []
        deficit = self._deficit.setdefault(app, {})
        batch: list = []
        while len(batch) < k:
            backlogged = sorted(t for t, q in queues.items() if q)
            if not backlogged:
                break
            for t in list(deficit):
                if not queues.get(t):
                    del deficit[t]          # idle tenants bank no credit
            for tenant in backlogged:
                deficit[tenant] = (deficit.get(tenant, 0.0)
                                   + self._weight(tenant))
                q = queues[tenant]
                while q and deficit[tenant] >= 1.0 and len(batch) < k:
                    batch.append(q.pop(0))
                    deficit[tenant] -= 1.0
        return batch

    def _admit_batch(self, app: str, sess, batch) -> None:
        """Splice a DRR-selected batch into the session's free slots and
        mark the tickets running (under the lock)."""
        if not batch:
            return
        gqs = sess.admit([t.seed for t in batch])
        now = time.perf_counter()
        for t, g in zip(batch, gqs):
            t.gq = g
            t.status = "running"
            t.admitted_s = now
            self._live[app][g] = t
            self.tenant_stats.setdefault(
                t.tenant,
                dict(submitted=0, admitted=0, done=0,
                     refused=0))["admitted"] += 1

    def _tick(self) -> bool:
        """One serve-loop iteration; True when fully drained."""
        now = time.perf_counter()
        with self._lock:
            draining = self._draining
            if draining and self.drain_mode == "checkpoint":
                return True    # _shutdown checkpoints the live sessions
            # open sessions for apps whose batching policy fired
            for app in list(self._pending):
                if not self._pending_count(app) or draining:
                    continue
                if app not in self._sessions:
                    sess = self._open_session(app)
                    if sess is not None:
                        continue    # first batch rode the open
                sess = self._sessions.get(app)
                if sess is None:
                    continue
                free = max(0, self.q_slots - len(sess.active_queries))
                queued = self._pending_count(app)
                if free and (queued >= self.min_fill
                             or now - self._oldest_pending_s(app)
                             >= self.max_wait_s):
                    self._admit_batch(app, sess, self._drr_take(app, free))
            # deadline sweep: drain live queries past their deadline
            for app, live in self._live.items():
                overdue = [t.gq for t in live.values()
                           if t.deadline_s is not None
                           and t.status == "running"
                           and now - t.submitted_s > t.deadline_s]
                if overdue and app in self._sessions:
                    self._sessions[app].drain(overdue)
            idle = not self._sessions
        if idle:
            if draining:
                return True    # _shutdown fails whatever is still queued
            with self._wake:
                self._wake.wait(timeout=self.max_wait_s)
            return False
        # step every live session once, round-robin (outside the lock:
        # submit() stays responsive during a superstep)
        for app in list(self._sessions):
            sess = self._sessions[app]
            st = sess.step()
            with self._lock:
                self.stats["supersteps"] += 1
            self._finish(app, sess, st.retired_queries, "done")
            self._finish(app, sess, st.drained_queries, "timeout")
            if sess.finished:
                self._close_session(app, sess)
        return False

    def _open_session(self, app: str):
        """Open a session for ``app`` seeded with a DRR-selected batch
        (the initial batch needs no admission barrier — it IS the
        program).  Called under the lock."""
        batch = self._drr_take(app, self.q_slots)
        if not batch:
            return None
        eng = self._engine(app)
        prog = APPS[app]().with_queries([t.seed for t in batch])
        sess = eng.open_session(prog, q_slots=self.q_slots,
                                max_supersteps=self.max_supersteps)
        self._sessions[app] = sess
        self._live.setdefault(app, {})
        self.stats["sessions_opened"] += 1
        now = time.perf_counter()
        for gq, t in zip(sess.active_queries, batch):
            t.gq = gq
            t.status = "running"
            t.admitted_s = now
            self._live[app][gq] = t
            self.tenant_stats.setdefault(
                t.tenant,
                dict(submitted=0, admitted=0, done=0,
                     refused=0))["admitted"] += 1
        return sess

    def _engine(self, app: str) -> OutOfCoreEngine:
        """The service-lifetime engine for ``app`` (edge caches and skip
        filters stay warm across sessions)."""
        eng = self._engines.get(app)
        if eng is None:
            cfg = self.cfg
            if cfg.checkpoint_dir:
                cfg = dataclasses.replace(
                    cfg, checkpoint_dir=os.path.join(cfg.checkpoint_dir,
                                                     app))
            eng = self._engines[app] = OutOfCoreEngine(self.store, cfg)
        return eng

    def _finish(self, app: str, sess, gqs, status: str) -> None:
        """Finalize tickets whose columns froze at the last barrier;
        converged (``done``) results populate the cache — drained
        partials never do."""
        if not gqs:
            return
        now = time.perf_counter()
        with self._lock:
            for g in gqs:
                t = self._live.get(app, {}).pop(int(g), None)
                if t is None:       # resumed column with no local ticket
                    continue
                t.status = status
                t.finished_s = now
                t.result = sess.query_result(t.gq)
                t.supersteps = sess.query_supersteps(t.gq)
                self.completed.append(t)
                self.stats[status] += 1
                if status == "done":
                    self.tenant_stats.setdefault(
                        t.tenant,
                        dict(submitted=0, admitted=0, done=0,
                             refused=0))["done"] += 1
                    if self.cache is not None:
                        self.cache.put(t.app, t.seed, self.fingerprint,
                                       t.result, t.supersteps)
                t._event.set()

    def _close_session(self, app: str, sess) -> None:
        """Finalize a finished session; any columns still live at
        max_supersteps finish as timeouts with their partial values."""
        stranded = tuple(sess.active_queries)
        sess.result()
        self._finish(app, sess, stranded, "timeout")
        sess.close()
        del self._sessions[app]

    # -- drain / resume ----------------------------------------------------
    def _shutdown(self) -> None:
        """Drain epilogue: finish or checkpoint in-flight sessions, fail
        whatever is still queued, wake all waiters."""
        if self.drain_mode == "checkpoint":
            for app, sess in list(self._sessions.items()):
                if self._engines[app].ckpt is not None:
                    sess.checkpoint()
                sess.close()
                del self._sessions[app]
            # live tickets stay unresolved here by design: the resumed
            # service re-registers them from the manifest lineage
            with self._lock:
                for live in self._live.values():
                    for t in live.values():
                        t.status = "failed"
                        self.stats["failed"] += 1
                        t._event.set()
                    live.clear()
        else:
            while self._sessions:
                for app in list(self._sessions):
                    sess = self._sessions[app]
                    st = sess.step()
                    with self._lock:
                        self.stats["supersteps"] += 1
                    self._finish(app, sess, st.retired_queries, "done")
                    self._finish(app, sess, st.drained_queries, "timeout")
                    if sess.finished:
                        self._close_session(app, sess)
        with self._lock:
            self._stopped = True
            for tenant_queues in self._pending.values():
                for queue in tenant_queues.values():
                    for t in queue:
                        t.status = "failed"
                        self.stats["failed"] += 1
                        t._event.set()
                    queue.clear()

    def _resume_sessions(self) -> None:
        """Reopen checkpointed serving sessions (drain_mode='checkpoint'
        shutdown): per-app subdirs of ``cfg.checkpoint_dir`` holding a
        non-final boundary are restored, and their live columns get
        synthetic tickets rebuilt from the manifest's query lineage."""
        root = self.cfg.checkpoint_dir
        for app in SERVABLE:
            if not os.path.isdir(os.path.join(root, app)):
                continue
            eng = self._engine(app)
            if eng.ckpt is None:
                continue
            peek = eng.ckpt.peek_manifest()
            if peek is None or peek[1].get("final"):
                continue
            lineage = {int(g): int(s) for g, s in
                       (peek[1].get("queries") or {}).items()}
            live = [int(g) for g in peek[1].get("active_q") or []]
            prog = APPS[app]().with_queries(
                [lineage.get(g, 0) for g in live] or [0])
            sess = eng.open_session(prog, q_slots=self.q_slots,
                                    max_supersteps=self.max_supersteps)
            self._sessions[app] = sess
            self._live.setdefault(app, {})
            self.stats["sessions_opened"] += 1
            now = time.perf_counter()
            for gq in sess.active_queries:
                t = QueryTicket(rid=self._next_rid, app=app,
                                seed=lineage.get(gq, -1),
                                submitted_s=now, status="running", gq=gq,
                                admitted_s=now)
                self._next_rid += 1
                self.stats["submitted"] += 1
                self._tickets[t.rid] = t
                self._live[app][gq] = t
        # resume applies to the restore pass only: later sessions on the
        # same engines must start fresh, not re-load a stale checkpoint
        self.cfg = dataclasses.replace(self.cfg, resume=False)
        for eng in self._engines.values():
            eng.cfg = dataclasses.replace(eng.cfg, resume=False)

    # -- reporting ---------------------------------------------------------
    @property
    def draining(self) -> bool:
        """True once drain began (healthz turns 503, submits refuse)."""
        with self._lock:
            return self._draining or self._stopped

    def stats_snapshot(self) -> dict:
        """Consistent copy of the service/tenant/cache counters plus the
        drain flag — the HTTP ``/v1/stats`` payload backend."""
        with self._lock:
            snap = dict(
                stats=dict(self.stats),
                tenants={t: dict(d) for t, d in self.tenant_stats.items()},
                draining=self._draining or self._stopped,
                pending={app: self._pending_count(app)
                         for app in self._pending},
                fingerprint=self.fingerprint,
            )
        snap["cache"] = (self.cache.snapshot()
                        if self.cache is not None else None)
        return snap

    def latency_summary(self) -> dict:
        """p50/p99 total latency + component means over completed
        queries (the bench's and runbook's one-stop report)."""
        done = [t for t in self.completed if t.status == "done"]
        with self._lock:
            timeouts = self.stats["timeout"]
        if not done:
            return dict(count=0, timeouts=timeouts)
        tot = np.asarray([t.total_s for t in done])
        return dict(
            count=len(done),
            timeouts=timeouts,
            p50_ms=float(np.percentile(tot, 50) * 1e3),
            p99_ms=float(np.percentile(tot, 99) * 1e3),
            mean_queue_ms=float(np.mean([t.queue_wait_s for t in done])
                                * 1e3),
            mean_service_ms=float(np.mean([t.service_s for t in done])
                                  * 1e3),
            mean_supersteps=float(np.mean([t.supersteps for t in done])),
        )
